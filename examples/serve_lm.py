"""Batched serving example: prefill a batch of prompts and decode with the
slot engine (the decode path the dry-run decode_32k cells lower).

  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import common
from repro.models import transformer as T
from repro.serve import ServeEngine

cfg = get_config("qwen2-1.5b").smoke()
params = common.materialize(T.lm_shapes(cfg), jax.random.PRNGKey(0))
eng = ServeEngine(cfg, params, cache_len=96, temperature=0.0)

rng = np.random.default_rng(0)
prompts = rng.integers(2, cfg.vocab, size=(8, 24), dtype=np.int32)

t0 = time.time()
out = eng.generate(prompts, max_new=32)
dt = time.time() - t0
print(f"batch=8 prompt=24 -> +32 tokens in {dt:.1f}s "
      f"({out.size/dt:.1f} tok/s incl. compile)")
t0 = time.time()
out = eng.generate(prompts, max_new=32)
dt = time.time() - t0
print(f"warm: {out.size/dt:.1f} tok/s")
print("first sequence:", out[0][:12].tolist())
