"""Continuous-batching serving example: submit a ragged backlog of requests
to the slot scheduler and drain it — slots freed at EOS/max_new refill from
the queue mid-decode, with per-slot positions over a paged KV cache.

  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import common
from repro.models import transformer as T
from repro.serve import ServeEngine

cfg = get_config("qwen2-1.5b").smoke()
params = common.materialize(T.lm_shapes(cfg), jax.random.PRNGKey(0))
eng = ServeEngine(cfg, params, cache_len=96, n_slots=4, temperature=0.0)

# ragged backlog: 8 requests, mixed prompt lengths and budgets, 4 slots
rng = np.random.default_rng(0)
reqs = [(rng.integers(2, cfg.vocab, size=(n,), dtype=np.int32), m)
        for n, m in [(24, 32), (8, 4), (16, 48), (12, 8),
                     (24, 16), (6, 40), (16, 12), (10, 24)]]

t0 = time.time()
rids = [eng.submit(p, max_new=m) for p, m in reqs]
res = eng.drain()
dt = time.time() - t0
n_tok = sum(len(res[r]) for r in rids)
print(f"{len(reqs)} ragged requests over {eng.n_slots} slots -> "
      f"{n_tok} tokens in {dt:.1f}s ({n_tok/dt:.1f} tok/s incl. compile)")

t0 = time.time()
for p, m in reqs:
    eng.submit(p, max_new=m)
res = eng.drain()
dt = time.time() - t0
print(f"warm: {n_tok/dt:.1f} tok/s")

# the batched API is a thin wrapper over submit()/drain()
out = eng.generate(np.stack([reqs[0][0], reqs[4][0]]), max_new=12)
print("first sequence:", out[0].tolist())
