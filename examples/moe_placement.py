"""The paper's technique as a framework feature: place MoE experts on EP
shards with the constrained hypergraph partitioner, minimizing all-to-all
fan-out under a distinct-inbound-route budget.

  PYTHONPATH=src python examples/moe_placement.py
"""
import dataclasses

from repro.configs import get_config
from repro.core import planner

cfg = get_config("deepseek-v2-236b")
cfg = dataclasses.replace(
    cfg, moe=dataclasses.replace(cfg.moe, n_experts=64, top_k=6))

out = planner.plan_expert_placement(cfg, n_shards=8, seed=0, theta=6)
rep = out["report"]
print("experts: 64, EP shards: 8 (8 experts/shard)")
print(f"routing-group connectivity (all-to-all spans):")
print(f"  identity placement : {rep['connectivity_identity']:.0f}")
print(f"  partitioned        : {rep['connectivity']:.0f}")
print(f"  reduction          : {rep['a2a_reduction']:.2f}x")
print(f"shard loads valid: {rep['size_ok']} (max {rep['max_size']})")
print("expert -> slot permutation (first 16):", out["perm"][:16].tolist())
