"""The paper's technique as a framework feature: place MoE experts on EP
shards with the constrained hypergraph partitioner, minimizing all-to-all
fan-out under a distinct-inbound-route budget — then *re-place* them as the
routing load shifts, using the streaming repartitioner (incremental
`GraphDelta` + warm refine-only solve) instead of a cold solve per window.

  PYTHONPATH=src python examples/moe_placement.py
"""
import dataclasses
import time

import numpy as np

from repro.configs import get_config
from repro.core import metrics, planner

cfg = get_config("deepseek-v2-236b")
cfg = dataclasses.replace(
    cfg, moe=dataclasses.replace(cfg.moe, n_experts=64, top_k=6))
N_SHARDS = 8


def shifted_trace(trace: np.ndarray, frac: float, seed: int) -> np.ndarray:
    """Shifting load: resample ``frac`` of the token rows from a freshly
    seeded router sample — most co-activation sets persist (their observed
    frequencies drift), a few vanish, a few appear."""
    rng = np.random.default_rng(seed)
    idx = rng.choice(len(trace), size=int(len(trace) * frac), replace=False)
    out = trace.copy()
    out[idx] = planner.synth_routing_trace(cfg, n_tokens=len(idx),
                                           seed=seed)[: len(idx)]
    return out


# ---- window 0: cold solve ---------------------------------------------------
trace0 = planner.synth_routing_trace(cfg, seed=0)
t0 = time.perf_counter()
out = planner.plan_expert_placement(cfg, n_shards=N_SHARDS, trace=trace0,
                                    theta=6)
t_cold = time.perf_counter() - t0
rep = out["report"]
print(f"experts: 64, EP shards: {N_SHARDS} (8 experts/shard)")
print("routing-group connectivity (all-to-all spans):")
print(f"  identity placement : {rep['connectivity_identity']:.0f}")
print(f"  partitioned        : {rep['connectivity']:.0f}")
print(f"  reduction          : {rep['a2a_reduction']:.2f}x")
print(f"shard loads valid: {rep['size_ok']} (max {rep['max_size']})")
print("expert -> slot permutation (first 16):", out["perm"][:16].tolist())
print(f"cold solve: {t_cold:.3f}s ({out['n_levels']} V-cycle levels)")

# ---- windows 1..3: the load shifts; re-place warm ---------------------------
print("\nshifting load (10% of tokens re-routed per window):")
trace = trace0
for window in range(1, 4):
    trace = shifted_trace(trace, frac=0.10, seed=window)
    prev_parts = out["parts"]
    t0 = time.perf_counter()
    out = planner.replan_expert_placement(cfg, out, n_shards=N_SHARDS,
                                          trace=trace, theta=6)
    t_warm = time.perf_counter() - t0
    rep = out["report"]
    # before/after on the SAME (shifted) graph: cost of keeping the stale
    # placement vs the warm re-refined one
    stale = metrics.connectivity(out["graph"], prev_parts)
    print(f"  window {window}: mode={out['mode']:<6} "
          f"{t_warm:.3f}s vs cold {t_cold:.3f}s "
          f"({t_cold / max(t_warm, 1e-9):.1f}x faster), "
          f"connectivity {stale:.0f} -> {rep['connectivity']:.0f}, "
          f"loads valid: {rep['size_ok']}")
