"""The paper's headline application: map an SNN onto neuromorphic cores
with bounded neurons/core (Omega) and bounded distinct inbound axons/core
(Delta), minimizing spike traffic (connectivity). Compares against the
paper's three sequential baselines.

  PYTHONPATH=src python examples/partition_snn.py [--nodes 600]
"""
import argparse

from repro.baselines import (onepass_partition, overlap_partition,
                             sequential_multilevel)
from repro.core import generate, metrics
from repro.core.partitioner import partition


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=400)
    ap.add_argument("--omega", type=int, default=32)
    ap.add_argument("--delta", type=int, default=128)
    args = ap.parse_args()

    hg = generate.snn_layered(n_layers=5, width=args.nodes // 5, fanout=10,
                              seed=7)
    print("SNN hypergraph:", hg.stats())
    om, dl = args.omega, args.delta

    res = partition(hg, omega=om, delta=dl, theta=8)
    print(f"\n{'method':10s} {'conn':>9s} {'parts':>6s} {'valid':>6s} "
          f"{'time':>8s}")
    print(f"{'ours':10s} {res.connectivity:9.0f} {res.n_parts:6d} "
          f"{str(res.audit['size_ok'] and res.audit['inbound_ok']):>6s} "
          f"{res.timings['total']:7.1f}s")
    for name, fn in (("seq-ml", sequential_multilevel),
                     ("overlap", overlap_partition),
                     ("onepass", onepass_partition)):
        parts, info = fn(hg, om, dl)
        aud = metrics.audit(hg, parts, om, dl)
        print(f"{name:10s} {aud['connectivity']:9.0f} {aud['n_parts']:6d} "
              f"{str(aud['size_ok'] and aud['inbound_ok']):>6s} "
              f"{info['time']:7.1f}s")


if __name__ == "__main__":
    main()
