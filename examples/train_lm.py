"""End-to-end training driver: trains a qwen2-family model on the synthetic
pipeline with checkpointing + auto-resume. Defaults to a ~10M-param model
for a few hundred steps (CPU-tractable); ``--full-100m`` scales the width to
~100M params (same code path, longer wall clock).

  PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.train.loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_config("qwen2-1.5b")
    if args.full_100m:
        cfg = cfg.scaled(n_layers=12, d_model=768, n_heads=12, n_kv=4,
                         d_head=64, d_ff=2048, vocab=32768, max_seq=2048,
                         q_chunk=256, k_chunk=256)
    else:
        cfg = cfg.scaled(n_layers=6, d_model=256, n_heads=8, n_kv=4,
                         d_head=32, d_ff=1024, vocab=8192, max_seq=2048,
                         q_chunk=128, k_chunk=128)
    from repro.models.common import param_count
    from repro.models.transformer import lm_shapes
    print(f"model: {param_count(lm_shapes(cfg))/1e6:.1f}M params")

    res = train(cfg, steps=args.steps, global_batch=args.batch,
                seq_len=args.seq, ckpt_dir=args.ckpt_dir, ckpt_every=50,
                resume=args.resume, log_every=10, deadline_s=600)
    print("loss curve:")
    for s, l in res.losses:
        print(f"  step {s:5d}  loss {l:.4f}")
    print(f"done: {res.steps} steps in {res.wall_s:.0f}s")
    assert res.losses[-1][1] < res.losses[0][1], "loss must decrease"


if __name__ == "__main__":
    main()
