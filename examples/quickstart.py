"""Quickstart: partition a hypergraph under size + distinct-inbound
constraints with the GPU->TPU multi-level partitioner.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import generate, metrics
from repro.core.partitioner import partition

# a small small-world SNN-like hypergraph (1 axon h-edge per neuron)
hg = generate.snn_smallworld(n_nodes=300, fanout=8, seed=1)
print("hypergraph:", hg.stats())

# Omega: max neurons per core; Delta: max distinct inbound axons per core
res = partition(hg, omega=32, delta=96, theta=8)

print(f"partitions : {res.n_parts}")
print(f"levels     : {res.n_levels}")
print(f"connectivity (total cut cost): {res.connectivity:.0f}")
print(f"constraints valid: size={res.audit['size_ok']} "
      f"inbound={res.audit['inbound_ok']}")
print(f"wall: {res.timings['total']:.1f}s "
      f"(coarsen {res.timings['coarsen']:.1f}s, "
      f"refine {res.timings['refine']:.1f}s)")
