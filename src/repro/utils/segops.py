"""Segmented / sorting primitives shared across the partitioner.

These are the TPU-side analogues of the CUB device primitives the paper
relies on (device radix sort, segmented prefix sums, atomics-based argmax):

* multi-key lexicographic sort        -> ``jax.lax.sort(..., num_keys=k)``
* segmented inclusive/exclusive scan  -> ``segmented_scan`` (associative_scan
  over (carry-flag, value) pairs)
* atomic lexicographic max            -> ``segment_argmax`` (two-pass
  segment_max with an id tie-break, larger id wins — matching the paper's
  deterministic claim resolution)

All functions are jit-safe with static shapes; invalid lanes are expected to
be masked by the caller with sentinel keys that sort to the end.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

INT_SENTINEL = jnp.int32(2**31 - 1)


def segment_sum(data: jax.Array, seg: jax.Array, num: int) -> jax.Array:
    return jax.ops.segment_sum(data, seg, num_segments=num)


def segment_max(data: jax.Array, seg: jax.Array, num: int) -> jax.Array:
    return jax.ops.segment_max(data, seg, num_segments=num)


def segment_min(data: jax.Array, seg: jax.Array, num: int) -> jax.Array:
    return jax.ops.segment_min(data, seg, num_segments=num)


def f32_sort_key(x: jax.Array) -> jax.Array:
    """Monotonic float32 -> uint32 mapping (total order, NaN-free inputs)."""
    b = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    mask = jnp.where(b >> 31 != 0, jnp.uint32(0xFFFFFFFF), jnp.uint32(0x80000000))
    return b ^ mask


def segment_argmax(
    values: jax.Array,
    ids: jax.Array,
    seg: jax.Array,
    num: int,
    valid: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Per-segment (max value, id) with *larger id winning ties*.

    Mirrors the paper's atomic lexicographic max over ``(score, id)`` tuples.
    Returns ``(maxval[num], argid[num])``; empty segments give
    ``(-inf, -1)``.
    """
    neg = jnp.float32(-jnp.inf)
    v = values.astype(jnp.float32)
    if valid is not None:
        v = jnp.where(valid, v, neg)
    mx = jax.ops.segment_max(v, seg, num_segments=num)
    mx = jnp.where(jnp.isneginf(mx), neg, mx)
    hit = v == mx[seg]
    if valid is not None:
        hit = hit & valid
    arg = jax.ops.segment_max(jnp.where(hit, ids, -1), seg, num_segments=num)
    return mx, arg


def segmented_scan(values: jax.Array, starts: jax.Array, reverse: bool = False) -> jax.Array:
    """Inclusive segmented prefix-sum.

    ``starts[i]`` is True where a new segment begins (data must be grouped by
    segment — i.e. pre-sorted by segment key, as in the paper's events
    pipeline).
    """
    flags = starts.astype(values.dtype)

    def combine(a, b):
        af, av = a
        bf, bv = b
        return jnp.maximum(af, bf), jnp.where(bf > 0, bv, av + bv)

    _, out = jax.lax.associative_scan(combine, (flags, values), reverse=reverse)
    return out


def segment_starts_from_sorted(keys: Sequence[jax.Array]) -> jax.Array:
    """Boolean 'new segment starts here' flags from sorted key columns."""
    k0 = keys[0]
    n = k0.shape[0]
    diff = jnp.zeros((n,), dtype=bool).at[0].set(True)
    for k in keys:
        d = jnp.concatenate([jnp.ones((1,), bool), k[1:] != k[:-1]])
        diff = diff | d
    return diff


def sort_by(keys: Sequence[jax.Array], payloads: Sequence[jax.Array]):
    """Stable lexicographic sort of payloads by key columns."""
    ops = list(keys) + list(payloads)
    out = jax.lax.sort(ops, num_keys=len(keys), is_stable=True)
    return out[: len(keys)], out[len(keys):]


def compact_flags(flags: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Positions for stream-compaction: returns (dest_idx, total_count).

    ``dest_idx[i]`` is the output slot for element ``i`` if ``flags[i]``,
    else undefined. ``total_count`` is the number of surviving elements.
    """
    f = flags.astype(jnp.int32)
    pos = jnp.cumsum(f) - f
    return pos, jnp.sum(f)


def scatter_compact(
    data: jax.Array, flags: jax.Array, out_size: int, fill
) -> tuple[jax.Array, jax.Array]:
    """Stream-compact ``data[flags]`` into a fresh array of ``out_size``."""
    pos, cnt = compact_flags(flags)
    out = jnp.full((out_size,) + data.shape[1:], fill, dtype=data.dtype)
    idx = jnp.where(flags, pos, out_size)  # out-of-range drops
    out = out.at[idx].set(data, mode="drop")
    return out, cnt


def offsets_from_counts(counts: jax.Array) -> jax.Array:
    """CSR offsets [n+1] from per-segment counts [n]."""
    return jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)])


def rows_from_offsets(offsets: jax.Array, total: int, num_rows: int) -> jax.Array:
    """Expand CSR offsets to a per-element row-id array of length ``total``.

    Elements beyond ``offsets[num_rows_actual]`` (padding) get row id
    == num_rows (one past the end), so they can be masked / dropped by
    segment ops.
    """
    marks = jnp.zeros((total + 1,), jnp.int32)
    n = offsets.shape[0] - 1
    marks = marks.at[offsets[1:]].add(1, mode="drop")
    rows = jnp.cumsum(marks)[:total]
    return jnp.minimum(rows, num_rows)


def searchsorted_segmented(
    sorted_vals: jax.Array,
    seg_off_lo: jax.Array,
    seg_off_hi: jax.Array,
    queries: jax.Array,
    n_iters: int,
) -> jax.Array:
    """For each query i, binary-search ``queries[i]`` in
    ``sorted_vals[seg_off_lo[i]:seg_off_hi[i]]``; returns the global index of
    the first element == query (callers guarantee presence), else hi.

    This is the vectorized analogue of the paper's per-thread binary search
    into shared-memory histogram bins.
    """
    lo = seg_off_lo
    hi = seg_off_hi

    def body(_, lh):
        lo, hi = lh
        mid = (lo + hi) // 2
        v = sorted_vals[jnp.clip(mid, 0, sorted_vals.shape[0] - 1)]
        go_right = v < queries
        return jnp.where(go_right, mid + 1, lo), jnp.where(go_right, hi, mid)

    lo, hi = jax.lax.fori_loop(0, n_iters, body, (lo, hi))
    return lo
