"""Segmented / sorting primitives shared across the partitioner.

These are the TPU-side analogues of the CUB device primitives the paper
relies on (device radix sort, segmented prefix sums, atomics-based argmax):

* multi-key lexicographic sort        -> ``jax.lax.sort(..., num_keys=k)``
* segmented inclusive/exclusive scan  -> ``segmented_scan`` (associative_scan
  over (carry-flag, value) pairs)
* atomic lexicographic max            -> ``segment_argmax`` (two-pass
  segment_max with an id tie-break, larger id wins — matching the paper's
  deterministic claim resolution)

All functions are jit-safe with static shapes; invalid lanes are expected to
be masked by the caller with sentinel keys that sort to the end.

``ShardCtx`` extends the same primitives across a mesh axis inside
``shard_map``: contiguous lane-striping for the pins/pairs-sized loops,
``psum``-combined dense segment reductions (no data all-gathers),
cross-shard segmented-scan carries (``sharded_segmented_scan``), and — the
piece that used to be the one gathered compromise — a distributed stable
multi-key sort. ``ShardCtx.sort_by`` runs the sample sort of
``repro.dist.sort``: per-shard local ``lax.sort``, splitters from a gathered
O(nshards^2 * oversample) regular sample (never the full key columns),
static-shape ``all_to_all`` exchanges with counts psum'd/all-gathered into
send/recv offsets, and a threaded global-rank tie key that makes the result
bit-identical to the gathered stable ``lax.sort``. The stripe-boundary
helpers (``edge_prev``/``edge_next``/``starts_from_sorted``/``cumsum``/
``unstripe``) let consumers of the sorted stripes (segment starts, group
closings, compactions) run stripe-local with scalar boundary exchanges.
With ``axis=None`` every helper degrades to the exact single-device
computation, so the coarsening/refinement pipelines are written once and
run identically in both modes.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp

INT_SENTINEL = jnp.int32(2**31 - 1)


def round_up(x: int, m: int) -> int:
    """Smallest multiple of ``m`` >= max(x, 1) — the static tile/pad-size
    helper shared by the Pallas kernel wrappers (``kernels/*/ops.py``) and
    the stripe-tile layouts."""
    return ((max(x, 1) + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Mesh-axis shard context for segment pipelines under ``shard_map``.

    ``axis=None`` (the default) is the single-device identity: ``lanes``
    covers everything, ``psum``/``gather``/``stripe`` are no-ops and
    ``segmented_scan`` has a zero carry. Frozen + hashable so it can ride in
    jit static arguments.
    """

    axis: str | None = None
    nshards: int = 1
    # opt-in: float reductions that would gather lane columns for bit-exact
    # stripe-order accumulation (eta, matching sum0) may instead combine
    # per-shard dense partials with `psum_compensated` (Neumaier two-sum in
    # shard order): O(dense) traffic, ~1 ulp of the true sum, but not
    # bit-identical to the single-device order.
    compensated: bool = False
    # the hypergraph's pins-sized storage arrays (edge_pins / node_edges /
    # node_is_in, see `dist.graph.ShardedHypergraph`) arrive in the
    # shard_map body as this shard's contiguous lane stripe instead of a
    # replicated full-length copy; `gread`/`gfull` pick the matching access
    # path so the pipelines are written once for both layouts.
    graph_striped: bool = False

    def index(self) -> jax.Array:
        if self.axis is None:
            return jnp.int32(0)
        return jax.lax.axis_index(self.axis).astype(jnp.int32)

    def psum(self, x: jax.Array) -> jax.Array:
        """Combine per-shard partial dense reductions (the all-gather-free
        segment reduction: dense outputs travel, never the lanes).

        Exact for integer / integer-valued partials only: float32 addition
        is not associative, so float partial sums combined by psum can drift
        from the single-device accumulation order by an ulp (enough to flip
        a downstream argmax). Bit-exact float reductions must instead gather
        their lane columns in stripe order (= global lane order) and reduce
        replicated — see `core.coarsen.score_slots` / `core.matching`."""
        if self.axis is None:
            return x
        return jax.lax.psum(x, self.axis)

    def pmax(self, x: jax.Array) -> jax.Array:
        """Cross-shard elementwise max of per-shard dense reductions. Unlike
        a float psum this is exact in any combine order (max is associative
        and commutative over totally ordered floats)."""
        if self.axis is None:
            return x
        return jax.lax.pmax(x, self.axis)

    def pmax_pair(self, values: jax.Array, ids: jax.Array
                  ) -> tuple[jax.Array, jax.Array]:
        """Cross-shard lexicographic (value, id) max, larger id breaking
        ties — the distributed form of ``segment_argmax``'s deterministic
        claim resolution. ``values``/``ids`` are per-shard dense winners
        (e.g. one per segment); empty shards contribute ``(-inf, -1)``.
        Exact: both passes are pure maxes, no float addition involved."""
        if self.axis is None:
            return values, ids
        v = jax.lax.pmax(values, self.axis)
        i = jax.lax.pmax(jnp.where(values == v, ids, -1), self.axis)
        return v, i

    def lanes(self, total: int) -> tuple[jax.Array, jax.Array]:
        """(global lane ids, in-range mask) for this shard's contiguous
        stripe of ``total`` lanes (ceil-divided; the tail shard may own
        out-of-range padding lanes, masked False)."""
        per = -(-total // max(self.nshards, 1))
        t = self.index() * per + jnp.arange(per, dtype=jnp.int32)
        return t, t < total

    def take(self, x: jax.Array, lanes: jax.Array, ok: jax.Array,
             fill) -> jax.Array:
        """``x[lanes]`` with padding / out-of-range lanes masked to
        ``fill`` — the standard stripe-local gather from a replicated array
        for ``lanes, ok = self.lanes(total)`` (clip keeps the tail shard's
        padding lanes in-bounds)."""
        return jnp.where(ok, x[jnp.clip(lanes, 0, x.shape[0] - 1)], fill)

    def gread(self, arr: jax.Array, t: jax.Array, ok: jax.Array,
              fill) -> jax.Array:
        """Own-stripe read of a pins-sized *graph storage* array at this
        shard's lanes ``t, ok = self.lanes(total)``. With ``graph_striped``
        (inside ``dist.partition``'s shard_map over a memory-sharded
        ``dist.graph.ShardedHypergraph``) ``arr`` already *is* this shard's
        local stripe, so the read is the local array masked to ``fill``;
        otherwise it is the standard stripe-local gather from the
        replicated full-length array (``take``). Bit-identical either way:
        the striped storage holds exactly the replicated array's values at
        this shard's lane positions (sentinel-padded past ``total``)."""
        if self.graph_striped and self.axis is not None:
            return jnp.where(ok, arr, fill)
        return self.take(arr, t, ok, fill)

    def gfull(self, arr: jax.Array) -> jax.Array:
        """Full pins-sized column from graph storage — the *documented
        transient* for arbitrary-position reads (only ``build_pairs``: the
        pair expansion joins two arbitrary pin slots of ``edge_pins``, an
        access no lane striping can serve). With ``graph_striped`` this
        rebuilds the full column via ``unstripe`` (psum of disjoint stripe
        scatters — bit-preserving), live only for the duration of the
        expansion; the persistent storage stays O(pins / shards). Without
        striped storage the array is already full-length and is returned
        as-is."""
        if self.graph_striped and self.axis is not None:
            return self.unstripe(arr)
        return arr

    def rows(self, offsets: jax.Array, t: jax.Array, total: int,
             num_rows: int) -> jax.Array:
        """CSR row ids for this shard's lanes ``t`` (`rows_from_offsets`
        semantics: padding lanes map to ``num_rows``). Sharded mode binary-
        searches only the stripe's lanes — O(P/S log E) per device instead
        of materializing the full O(P) expansion everywhere."""
        if self.axis is None:
            return rows_from_offsets(offsets, total, num_rows)
        r = jnp.searchsorted(offsets, t, side="right").astype(jnp.int32) - 1
        return jnp.minimum(r, num_rows)

    def psum_stripe(self, x: jax.Array) -> jax.Array:
        """Reduce-scatter: psum a dense per-lane vector (length =
        lanes-per-shard * nshards) and keep only this shard's stripe —
        1/nshards the payload of a full psum when the consumer only reads
        its own lanes. Identity (the stripe is everything) on one device."""
        if self.axis is None:
            return x
        return jax.lax.psum_scatter(x, self.axis, scatter_dimension=0,
                                    tiled=True)

    def gather(self, x: jax.Array) -> jax.Array:
        """Concatenate all shards' stripes (in shard order). Since the
        distributed sample sort landed, no sort call site gathers its key
        columns anymore; this remains for the bit-exact float reductions
        (eta / matching sum0 lane columns gathered in stripe order — see
        ``psum_compensated`` for the O(dense) alternative) and for tests."""
        if self.axis is None:
            return x
        g = jax.lax.all_gather(x, self.axis)
        return g.reshape((-1,) + g.shape[2:])

    def unstripe(self, x: jax.Array) -> jax.Array:
        """Replicate a stripe-laid-out array: each shard scatters its stripe
        into a zeros-filled full-length array at its offset and the disjoint
        partials psum (every lane has exactly one contributor). The
        psum-combine dual of ``gather`` for sorted / compacted results whose
        consumer needs the whole array. Floats travel as bitcast int32 so
        the combine is bit-preserving (a float psum would turn -0.0 into
        +0.0 and may re-sign NaNs); bools as int32."""
        if self.axis is None:
            return x
        per = x.shape[0]
        if x.dtype == jnp.bool_:
            xi = x.astype(jnp.int32)
        elif x.dtype in (jnp.float32, jnp.uint32):
            xi = jax.lax.bitcast_convert_type(x, jnp.int32)
        else:
            xi = x
        full = jnp.zeros((per * self.nshards,) + x.shape[1:], xi.dtype)
        full = jax.lax.dynamic_update_slice_in_dim(
            full, xi, self.index() * per, 0)
        full = jax.lax.psum(full, self.axis)
        if x.dtype == jnp.bool_:
            return full != 0
        if x.dtype in (jnp.float32, jnp.uint32):
            return jax.lax.bitcast_convert_type(full, x.dtype)
        return full

    def edge_prev(self, x: jax.Array, fill) -> jax.Array:
        """Previous element's value in global stripe order: ``out[i] =
        x[i-1]`` within the stripe, ``out[0]`` = the previous shard's last
        element (``fill`` on the globally first shard). The boundary
        exchange is one scalar all-gather — never the data."""
        first = jnp.full((1,), fill, x.dtype)
        if self.axis is None:
            return jnp.concatenate([first, x[:-1]])
        lasts = jax.lax.all_gather(x[-1], self.axis)   # [nshards]
        i = self.index()
        prev = jnp.where(i > 0, lasts[jnp.maximum(i - 1, 0)], first[0])
        return jnp.concatenate([prev[None], x[:-1]])

    def edge_next(self, x: jax.Array, fill) -> jax.Array:
        """Next element's value in global stripe order (mirror of
        ``edge_prev``): ``out[-1]`` = the next shard's first element
        (``fill`` on the globally last shard)."""
        last = jnp.full((1,), fill, x.dtype)
        if self.axis is None:
            return jnp.concatenate([x[1:], last])
        firsts = jax.lax.all_gather(x[0], self.axis)   # [nshards]
        i = self.index()
        nxt = jnp.where(i < self.nshards - 1,
                        firsts[jnp.minimum(i + 1, self.nshards - 1)], last[0])
        return jnp.concatenate([x[1:], nxt[None]])

    def starts_from_sorted(self, keys: Sequence[jax.Array]) -> jax.Array:
        """``segment_starts_from_sorted`` over stripe-laid-out sorted key
        columns: each stripe's first element compares against the previous
        stripe's last (scalar boundary exchange), and the globally first
        element is always a start."""
        if self.axis is None:
            return segment_starts_from_sorted(keys)
        n = keys[0].shape[0]
        start = jnp.zeros((n,), bool).at[0].set(self.index() == 0)
        for k in keys:
            start = start | (k != self.edge_prev(k, k[0]))
        return start

    def cumsum(self, x: jax.Array) -> jax.Array:
        """Cross-shard inclusive cumsum over stripe layout (one-segment
        ``segmented_scan``); dtype-preserving, carries exchange two scalars
        per shard."""
        out, _ = self.segmented_scan(x, jnp.zeros(x.shape, bool))
        return out

    def sort_by(self, keys: Sequence[jax.Array],
                payloads: Sequence[jax.Array], *,
                striped_in: bool = False, striped_out: bool = False):
        """Stable lexicographic multi-key sort across the shard axis — the
        distributed sample sort of ``repro.dist.sort``, bit-identical to
        gathering the columns and running the stable ``lax.sort`` (a
        threaded global-rank tie key makes every extended key unique, so
        the bucketed order *is* the stable order).

        ``striped_in``: columns are this shard's stripe of the global
        (concatenation-order) columns; otherwise they are replicated
        full-length columns, striped internally. ``striped_out``: return
        this shard's stripe of the sorted order; otherwise the full sorted
        columns are rebuilt on every shard via ``unstripe`` (psum of
        disjoint stripes — the only all-to-every traffic, and only when a
        replicated consumer asks for it). Only O(nshards^2 * oversample)
        splitter-sample keys are ever gathered; payload data moves through
        static-shape all_to_all exchanges sized O(len/nshards).

        With ``axis=None`` (or replicated columns whose length does not
        tile the shard count) this degrades to the exact single-device
        ``sort_by``."""
        keys = list(keys)
        payloads = list(payloads)
        if self.axis is None:
            return sort_by(keys, payloads)
        from repro.dist import sort as dist_sort
        if not striped_in:
            length = keys[0].shape[0]
            if length % self.nshards or length < self.nshards:
                return sort_by(keys, payloads)  # replicated, still exact
            keys = [self.stripe(k) for k in keys]
            payloads = [self.stripe(p) for p in payloads]
        ks, ps = dist_sort.sample_sort_stripes(self, keys, payloads)
        if not striped_out:
            ks = [self.unstripe(k) for k in ks]
            ps = [self.unstripe(p) for p in ps]
        return tuple(ks), tuple(ps)

    def psum_compensated(self, x: jax.Array) -> jax.Array:
        """Neumaier-compensated cross-shard float sum of per-shard dense
        partials, folded in shard order. O(dense) traffic like ``psum``
        (vs the O(lanes) stripe-order column gather that bit-exact float
        reductions use) and deterministic for a fixed mesh, but NOT
        bit-identical to the single-device lane-order accumulation — the
        compensation bounds the error to ~1 ulp of the true sum instead.
        Opt-in via ``ShardCtx(compensated=True)`` for the eta / matching
        sum0 reductions when exact single-device parity is not required."""
        if self.axis is None:
            return x
        parts = jax.lax.all_gather(x.astype(jnp.float32), self.axis)

        def step(carry, v):
            s, c = carry
            t = s + v
            c = c + jnp.where(jnp.abs(s) >= jnp.abs(v),
                              (s - t) + v, (v - t) + s)
            return (t, c), None

        zero = jnp.zeros(x.shape, jnp.float32)
        (tot, comp), _ = jax.lax.scan(step, (zero, zero), parts)
        return tot + comp

    def stripe(self, x: jax.Array) -> jax.Array:
        """This shard's contiguous stripe of a replicated array whose length
        divides ``nshards`` (gathered-sorted arrays always do)."""
        if self.axis is None:
            return x
        per = x.shape[0] // self.nshards
        return jax.lax.dynamic_slice_in_dim(x, self.index() * per, per)

    def stripe_start(self, length: int) -> jax.Array:
        """Global offset of this shard's stripe of a length-``length``
        replicated array."""
        return self.index() * (length // max(self.nshards, 1))

    def segmented_scan(self, values: jax.Array, starts: jax.Array
                       ) -> tuple[jax.Array, jax.Array]:
        """Cross-shard segmented scan over stripe-laid-out data; returns
        ``(values, carry_in)`` — see ``sharded_segmented_scan``."""
        return sharded_segmented_scan(values, starts, self.axis)


def segment_sum(data: jax.Array, seg: jax.Array, num: int) -> jax.Array:
    return jax.ops.segment_sum(data, seg, num_segments=num)


def segment_max(data: jax.Array, seg: jax.Array, num: int) -> jax.Array:
    return jax.ops.segment_max(data, seg, num_segments=num)


def segment_min(data: jax.Array, seg: jax.Array, num: int) -> jax.Array:
    return jax.ops.segment_min(data, seg, num_segments=num)


def f32_sort_key(x: jax.Array) -> jax.Array:
    """Monotone float32 -> uint32 key reproducing ``lax.sort``'s float key
    order *including its canonicalization*: -0.0 and +0.0 map to the same
    key, and every NaN (any sign or payload) maps to one canonical key that
    sorts after +inf — exactly ``lax``'s ``_canonicalize_float_for_sort``
    contract. Uint32 ``<``/``==`` on these keys therefore agree bit-for-bit
    with a float ``lax.sort`` (ties fall through to later key columns /
    stability), which is what lets the distributed sample sort
    (``repro.dist.sort``) bucket float key columns by splitter comparison
    without ever diverging from the gathered sort. The mapping is
    deliberately non-injective on the canonicalized classes, so callers that
    need the original float bits back must thread the column as a payload.
    """
    x = x.astype(jnp.float32)
    x = jnp.where(x == 0.0, jnp.float32(0.0), x)      # -0.0 == +0.0
    x = jnp.where(jnp.isnan(x), jnp.float32(jnp.nan), x)  # one canonical NaN
    b = jax.lax.bitcast_convert_type(x, jnp.uint32)
    mask = jnp.where(b >> 31 != 0, jnp.uint32(0xFFFFFFFF), jnp.uint32(0x80000000))
    return b ^ mask


def segment_argmax(
    values: jax.Array,
    ids: jax.Array,
    seg: jax.Array,
    num: int,
    valid: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Per-segment (max value, id) with *larger id winning ties*.

    Mirrors the paper's atomic lexicographic max over ``(score, id)`` tuples.
    Returns ``(maxval[num], argid[num])``; empty segments give
    ``(-inf, -1)``.
    """
    neg = jnp.float32(-jnp.inf)
    v = values.astype(jnp.float32)
    if valid is not None:
        v = jnp.where(valid, v, neg)
    mx = jax.ops.segment_max(v, seg, num_segments=num)
    mx = jnp.where(jnp.isneginf(mx), neg, mx)
    hit = v == mx[seg]
    if valid is not None:
        hit = hit & valid
    arg = jax.ops.segment_max(jnp.where(hit, ids, -1), seg, num_segments=num)
    return mx, arg


def scan_combine(a, b):
    """Monoid for segmented prefix-sums over (start-flag, value) pairs.

    Associative; identity is ``(0, 0)``. Shared by the in-device
    ``segmented_scan`` and the cross-shard carry fold in
    ``sharded_segmented_scan`` so both paths sum in exactly the same order
    within an element's segment.
    """
    af, av = a
    bf, bv = b
    return jnp.maximum(af, bf), jnp.where(bf > 0, bv, av + bv)


def segmented_scan(values: jax.Array, starts: jax.Array, reverse: bool = False) -> jax.Array:
    """Inclusive segmented prefix-sum.

    ``starts[i]`` is True where a new segment begins (data must be grouped by
    segment — i.e. pre-sorted by segment key, as in the paper's events
    pipeline).

    Dtype-preserving: int32 inputs scan in int32 (exact for any magnitude),
    so callers summing integer deltas must NOT pre-cast to float32 — f32
    accumulation silently rounds once running values exceed 2**24 (the
    events pipeline hits this at ~16.7M pins / huge node sizes).
    """
    flags = starts.astype(values.dtype)
    _, out = jax.lax.associative_scan(scan_combine, (flags, values),
                                      reverse=reverse)
    return out


def apply_scan_carry(local: jax.Array, starts: jax.Array, carry_in: jax.Array) -> jax.Array:
    """Patch a chunk-local inclusive segmented scan with the running value
    carried in from the previous chunk: only the prefix of the chunk that
    continues the incoming segment (no start seen yet) absorbs the carry."""
    seen = jnp.cumsum(starts.astype(jnp.int32))
    return jnp.where(seen == 0, local + carry_in, local)


def sharded_segmented_scan(values: jax.Array, starts: jax.Array,
                           axis: str | None) -> tuple[jax.Array, jax.Array]:
    """Segmented inclusive scan over an array laid out in contiguous
    per-device stripes along mesh axis ``axis`` (device i holds stripe i of
    the globally sorted order, as produced by ``ShardCtx.stripe``).

    Decoupled-lookback analogue across devices: each shard scans locally,
    then exchanges a tiny ``(has-start, end-value)`` summary per shard (an
    all-gather of two scalars — never of the data) and folds the summaries
    of all earlier shards with the same ``scan_combine`` monoid to obtain its
    incoming carry. Returns ``(scan values for this stripe, carry_in)``
    where ``carry_in`` is the running value at the last element of the
    previous stripe (0 for the first stripe / single device).
    """
    local = segmented_scan(values, starts)
    zero = jnp.zeros((), values.dtype)
    if axis is None:
        return local, zero
    flag = jnp.max(starts.astype(values.dtype))
    last = local[-1]
    flags = jax.lax.all_gather(flag, axis)   # [nshards]
    lasts = jax.lax.all_gather(last, axis)   # [nshards]
    cf, cv = jax.lax.associative_scan(scan_combine, (flags, lasts))
    idx = jax.lax.axis_index(axis)
    carry_in = jnp.where(idx > 0, cv[jnp.maximum(idx - 1, 0)], zero)
    return apply_scan_carry(local, starts, carry_in), carry_in


def segment_starts_from_sorted(keys: Sequence[jax.Array]) -> jax.Array:
    """Boolean 'new segment starts here' flags from sorted key columns."""
    k0 = keys[0]
    n = k0.shape[0]
    diff = jnp.zeros((n,), dtype=bool).at[0].set(True)
    for k in keys:
        d = jnp.concatenate([jnp.ones((1,), bool), k[1:] != k[:-1]])
        diff = diff | d
    return diff


def sort_by(keys: Sequence[jax.Array], payloads: Sequence[jax.Array]):
    """Stable lexicographic sort of payloads by key columns."""
    ops = list(keys) + list(payloads)
    out = jax.lax.sort(ops, num_keys=len(keys), is_stable=True)
    return out[: len(keys)], out[len(keys):]


def compact_flags(flags: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Positions for stream-compaction: returns (dest_idx, total_count).

    ``dest_idx[i]`` is the output slot for element ``i`` if ``flags[i]``,
    else undefined. ``total_count`` is the number of surviving elements.
    """
    f = flags.astype(jnp.int32)
    pos = jnp.cumsum(f) - f
    return pos, jnp.sum(f)


def scatter_compact(
    data: jax.Array, flags: jax.Array, out_size: int, fill
) -> tuple[jax.Array, jax.Array]:
    """Stream-compact ``data[flags]`` into a fresh array of ``out_size``.

    Single-device compaction primitive (kept as part of the CUB-analogue
    surface). The sharded pipelines compact differently — global slots from
    a ``ShardCtx.cumsum`` carry, then a psum of disjoint dense scatters, as
    in ``core.hypergraph.build_neighbors`` — so that the dense result, not
    the lanes, travels."""
    pos, cnt = compact_flags(flags)
    out = jnp.full((out_size,) + data.shape[1:], fill, dtype=data.dtype)
    idx = jnp.where(flags, pos, out_size)  # out-of-range drops
    out = out.at[idx].set(data, mode="drop")
    return out, cnt


def offsets_from_counts(counts: jax.Array) -> jax.Array:
    """CSR offsets [n+1] from per-segment counts [n]."""
    return jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)])


def rows_from_offsets(offsets: jax.Array, total: int, num_rows: int) -> jax.Array:
    """Expand CSR offsets to a per-element row-id array of length ``total``.

    Elements beyond ``offsets[num_rows_actual]`` (padding) get row id
    == num_rows (one past the end), so they can be masked / dropped by
    segment ops.
    """
    marks = jnp.zeros((total + 1,), jnp.int32)
    n = offsets.shape[0] - 1
    marks = marks.at[offsets[1:]].add(1, mode="drop")
    rows = jnp.cumsum(marks)[:total]
    return jnp.minimum(rows, num_rows)


def searchsorted_segmented(
    sorted_vals: jax.Array,
    seg_off_lo: jax.Array,
    seg_off_hi: jax.Array,
    queries: jax.Array,
    n_iters: int,
) -> jax.Array:
    """For each query i, binary-search ``queries[i]`` in
    ``sorted_vals[seg_off_lo[i]:seg_off_hi[i]]``; returns the global index of
    the first element == query (callers guarantee presence), else hi.

    This is the vectorized analogue of the paper's per-thread binary search
    into shared-memory histogram bins.
    """
    lo = seg_off_lo
    hi = seg_off_hi

    def body(_, lh):
        lo, hi = lh
        mid = (lo + hi) // 2
        v = sorted_vals[jnp.clip(mid, 0, sorted_vals.shape[0] - 1)]
        go_right = v < queries
        return jnp.where(go_right, mid + 1, lo), jnp.where(go_right, hi, mid)

    lo, hi = jax.lax.fori_loop(0, n_iters, body, (lo, hi))
    return lo
