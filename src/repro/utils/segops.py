"""Segmented / sorting primitives shared across the partitioner.

These are the TPU-side analogues of the CUB device primitives the paper
relies on (device radix sort, segmented prefix sums, atomics-based argmax):

* multi-key lexicographic sort        -> ``jax.lax.sort(..., num_keys=k)``
* segmented inclusive/exclusive scan  -> ``segmented_scan`` (associative_scan
  over (carry-flag, value) pairs)
* atomic lexicographic max            -> ``segment_argmax`` (two-pass
  segment_max with an id tie-break, larger id wins — matching the paper's
  deterministic claim resolution)

All functions are jit-safe with static shapes; invalid lanes are expected to
be masked by the caller with sentinel keys that sort to the end.

``ShardCtx`` extends the same primitives across a mesh axis inside
``shard_map``: contiguous lane-striping for the pins/pairs-sized loops,
``psum``-combined dense segment reductions (no data all-gathers), and
cross-shard segmented-scan carries (``sharded_segmented_scan``). With
``axis=None`` every helper degrades to the exact single-device computation,
so the refinement pipeline in ``core/refine.py`` is written once and runs
identically in both modes.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp

INT_SENTINEL = jnp.int32(2**31 - 1)


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Mesh-axis shard context for segment pipelines under ``shard_map``.

    ``axis=None`` (the default) is the single-device identity: ``lanes``
    covers everything, ``psum``/``gather``/``stripe`` are no-ops and
    ``segmented_scan`` has a zero carry. Frozen + hashable so it can ride in
    jit static arguments.
    """

    axis: str | None = None
    nshards: int = 1

    def index(self) -> jax.Array:
        if self.axis is None:
            return jnp.int32(0)
        return jax.lax.axis_index(self.axis).astype(jnp.int32)

    def psum(self, x: jax.Array) -> jax.Array:
        """Combine per-shard partial dense reductions (the all-gather-free
        segment reduction: dense outputs travel, never the lanes).

        Exact for integer / integer-valued partials only: float32 addition
        is not associative, so float partial sums combined by psum can drift
        from the single-device accumulation order by an ulp (enough to flip
        a downstream argmax). Bit-exact float reductions must instead gather
        their lane columns in stripe order (= global lane order) and reduce
        replicated — see `core.coarsen.score_slots` / `core.matching`."""
        if self.axis is None:
            return x
        return jax.lax.psum(x, self.axis)

    def pmax(self, x: jax.Array) -> jax.Array:
        """Cross-shard elementwise max of per-shard dense reductions. Unlike
        a float psum this is exact in any combine order (max is associative
        and commutative over totally ordered floats)."""
        if self.axis is None:
            return x
        return jax.lax.pmax(x, self.axis)

    def pmax_pair(self, values: jax.Array, ids: jax.Array
                  ) -> tuple[jax.Array, jax.Array]:
        """Cross-shard lexicographic (value, id) max, larger id breaking
        ties — the distributed form of ``segment_argmax``'s deterministic
        claim resolution. ``values``/``ids`` are per-shard dense winners
        (e.g. one per segment); empty shards contribute ``(-inf, -1)``.
        Exact: both passes are pure maxes, no float addition involved."""
        if self.axis is None:
            return values, ids
        v = jax.lax.pmax(values, self.axis)
        i = jax.lax.pmax(jnp.where(values == v, ids, -1), self.axis)
        return v, i

    def lanes(self, total: int) -> tuple[jax.Array, jax.Array]:
        """(global lane ids, in-range mask) for this shard's contiguous
        stripe of ``total`` lanes (ceil-divided; the tail shard may own
        out-of-range padding lanes, masked False)."""
        per = -(-total // max(self.nshards, 1))
        t = self.index() * per + jnp.arange(per, dtype=jnp.int32)
        return t, t < total

    def take(self, x: jax.Array, lanes: jax.Array, ok: jax.Array,
             fill) -> jax.Array:
        """``x[lanes]`` with padding / out-of-range lanes masked to
        ``fill`` — the standard stripe-local gather from a replicated array
        for ``lanes, ok = self.lanes(total)`` (clip keeps the tail shard's
        padding lanes in-bounds)."""
        return jnp.where(ok, x[jnp.clip(lanes, 0, x.shape[0] - 1)], fill)

    def rows(self, offsets: jax.Array, t: jax.Array, total: int,
             num_rows: int) -> jax.Array:
        """CSR row ids for this shard's lanes ``t`` (`rows_from_offsets`
        semantics: padding lanes map to ``num_rows``). Sharded mode binary-
        searches only the stripe's lanes — O(P/S log E) per device instead
        of materializing the full O(P) expansion everywhere."""
        if self.axis is None:
            return rows_from_offsets(offsets, total, num_rows)
        r = jnp.searchsorted(offsets, t, side="right").astype(jnp.int32) - 1
        return jnp.minimum(r, num_rows)

    def psum_stripe(self, x: jax.Array) -> jax.Array:
        """Reduce-scatter: psum a dense per-lane vector (length =
        lanes-per-shard * nshards) and keep only this shard's stripe —
        1/nshards the payload of a full psum when the consumer only reads
        its own lanes. Identity (the stripe is everything) on one device."""
        if self.axis is None:
            return x
        return jax.lax.psum_scatter(x, self.axis, scatter_dimension=0,
                                    tiled=True)

    def gather(self, x: jax.Array) -> jax.Array:
        """Concatenate all shards' stripes (in shard order) — used only for
        the sort keys/payloads of the events pipeline; see
        ``core.refine.events_validity`` for why sort is the one gathered
        stage."""
        if self.axis is None:
            return x
        g = jax.lax.all_gather(x, self.axis)
        return g.reshape((-1,) + g.shape[2:])

    def stripe(self, x: jax.Array) -> jax.Array:
        """This shard's contiguous stripe of a replicated array whose length
        divides ``nshards`` (gathered-sorted arrays always do)."""
        if self.axis is None:
            return x
        per = x.shape[0] // self.nshards
        return jax.lax.dynamic_slice_in_dim(x, self.index() * per, per)

    def stripe_start(self, length: int) -> jax.Array:
        """Global offset of this shard's stripe of a length-``length``
        replicated array."""
        return self.index() * (length // max(self.nshards, 1))

    def segmented_scan(self, values: jax.Array, starts: jax.Array
                       ) -> tuple[jax.Array, jax.Array]:
        """Cross-shard segmented scan over stripe-laid-out data; returns
        ``(values, carry_in)`` — see ``sharded_segmented_scan``."""
        return sharded_segmented_scan(values, starts, self.axis)


def segment_sum(data: jax.Array, seg: jax.Array, num: int) -> jax.Array:
    return jax.ops.segment_sum(data, seg, num_segments=num)


def segment_max(data: jax.Array, seg: jax.Array, num: int) -> jax.Array:
    return jax.ops.segment_max(data, seg, num_segments=num)


def segment_min(data: jax.Array, seg: jax.Array, num: int) -> jax.Array:
    return jax.ops.segment_min(data, seg, num_segments=num)


def f32_sort_key(x: jax.Array) -> jax.Array:
    """Monotonic float32 -> uint32 mapping (total order, NaN-free inputs)."""
    b = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    mask = jnp.where(b >> 31 != 0, jnp.uint32(0xFFFFFFFF), jnp.uint32(0x80000000))
    return b ^ mask


def segment_argmax(
    values: jax.Array,
    ids: jax.Array,
    seg: jax.Array,
    num: int,
    valid: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Per-segment (max value, id) with *larger id winning ties*.

    Mirrors the paper's atomic lexicographic max over ``(score, id)`` tuples.
    Returns ``(maxval[num], argid[num])``; empty segments give
    ``(-inf, -1)``.
    """
    neg = jnp.float32(-jnp.inf)
    v = values.astype(jnp.float32)
    if valid is not None:
        v = jnp.where(valid, v, neg)
    mx = jax.ops.segment_max(v, seg, num_segments=num)
    mx = jnp.where(jnp.isneginf(mx), neg, mx)
    hit = v == mx[seg]
    if valid is not None:
        hit = hit & valid
    arg = jax.ops.segment_max(jnp.where(hit, ids, -1), seg, num_segments=num)
    return mx, arg


def scan_combine(a, b):
    """Monoid for segmented prefix-sums over (start-flag, value) pairs.

    Associative; identity is ``(0, 0)``. Shared by the in-device
    ``segmented_scan`` and the cross-shard carry fold in
    ``sharded_segmented_scan`` so both paths sum in exactly the same order
    within an element's segment.
    """
    af, av = a
    bf, bv = b
    return jnp.maximum(af, bf), jnp.where(bf > 0, bv, av + bv)


def segmented_scan(values: jax.Array, starts: jax.Array, reverse: bool = False) -> jax.Array:
    """Inclusive segmented prefix-sum.

    ``starts[i]`` is True where a new segment begins (data must be grouped by
    segment — i.e. pre-sorted by segment key, as in the paper's events
    pipeline).

    Dtype-preserving: int32 inputs scan in int32 (exact for any magnitude),
    so callers summing integer deltas must NOT pre-cast to float32 — f32
    accumulation silently rounds once running values exceed 2**24 (the
    events pipeline hits this at ~16.7M pins / huge node sizes).
    """
    flags = starts.astype(values.dtype)
    _, out = jax.lax.associative_scan(scan_combine, (flags, values),
                                      reverse=reverse)
    return out


def apply_scan_carry(local: jax.Array, starts: jax.Array, carry_in: jax.Array) -> jax.Array:
    """Patch a chunk-local inclusive segmented scan with the running value
    carried in from the previous chunk: only the prefix of the chunk that
    continues the incoming segment (no start seen yet) absorbs the carry."""
    seen = jnp.cumsum(starts.astype(jnp.int32))
    return jnp.where(seen == 0, local + carry_in, local)


def sharded_segmented_scan(values: jax.Array, starts: jax.Array,
                           axis: str | None) -> tuple[jax.Array, jax.Array]:
    """Segmented inclusive scan over an array laid out in contiguous
    per-device stripes along mesh axis ``axis`` (device i holds stripe i of
    the globally sorted order, as produced by ``ShardCtx.stripe``).

    Decoupled-lookback analogue across devices: each shard scans locally,
    then exchanges a tiny ``(has-start, end-value)`` summary per shard (an
    all-gather of two scalars — never of the data) and folds the summaries
    of all earlier shards with the same ``scan_combine`` monoid to obtain its
    incoming carry. Returns ``(scan values for this stripe, carry_in)``
    where ``carry_in`` is the running value at the last element of the
    previous stripe (0 for the first stripe / single device).
    """
    local = segmented_scan(values, starts)
    zero = jnp.zeros((), values.dtype)
    if axis is None:
        return local, zero
    flag = jnp.max(starts.astype(values.dtype))
    last = local[-1]
    flags = jax.lax.all_gather(flag, axis)   # [nshards]
    lasts = jax.lax.all_gather(last, axis)   # [nshards]
    cf, cv = jax.lax.associative_scan(scan_combine, (flags, lasts))
    idx = jax.lax.axis_index(axis)
    carry_in = jnp.where(idx > 0, cv[jnp.maximum(idx - 1, 0)], zero)
    return apply_scan_carry(local, starts, carry_in), carry_in


def segment_starts_from_sorted(keys: Sequence[jax.Array]) -> jax.Array:
    """Boolean 'new segment starts here' flags from sorted key columns."""
    k0 = keys[0]
    n = k0.shape[0]
    diff = jnp.zeros((n,), dtype=bool).at[0].set(True)
    for k in keys:
        d = jnp.concatenate([jnp.ones((1,), bool), k[1:] != k[:-1]])
        diff = diff | d
    return diff


def sort_by(keys: Sequence[jax.Array], payloads: Sequence[jax.Array]):
    """Stable lexicographic sort of payloads by key columns."""
    ops = list(keys) + list(payloads)
    out = jax.lax.sort(ops, num_keys=len(keys), is_stable=True)
    return out[: len(keys)], out[len(keys):]


def compact_flags(flags: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Positions for stream-compaction: returns (dest_idx, total_count).

    ``dest_idx[i]`` is the output slot for element ``i`` if ``flags[i]``,
    else undefined. ``total_count`` is the number of surviving elements.
    """
    f = flags.astype(jnp.int32)
    pos = jnp.cumsum(f) - f
    return pos, jnp.sum(f)


def scatter_compact(
    data: jax.Array, flags: jax.Array, out_size: int, fill
) -> tuple[jax.Array, jax.Array]:
    """Stream-compact ``data[flags]`` into a fresh array of ``out_size``."""
    pos, cnt = compact_flags(flags)
    out = jnp.full((out_size,) + data.shape[1:], fill, dtype=data.dtype)
    idx = jnp.where(flags, pos, out_size)  # out-of-range drops
    out = out.at[idx].set(data, mode="drop")
    return out, cnt


def offsets_from_counts(counts: jax.Array) -> jax.Array:
    """CSR offsets [n+1] from per-segment counts [n]."""
    return jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)])


def rows_from_offsets(offsets: jax.Array, total: int, num_rows: int) -> jax.Array:
    """Expand CSR offsets to a per-element row-id array of length ``total``.

    Elements beyond ``offsets[num_rows_actual]`` (padding) get row id
    == num_rows (one past the end), so they can be masked / dropped by
    segment ops.
    """
    marks = jnp.zeros((total + 1,), jnp.int32)
    n = offsets.shape[0] - 1
    marks = marks.at[offsets[1:]].add(1, mode="drop")
    rows = jnp.cumsum(marks)[:total]
    return jnp.minimum(rows, num_rows)


def searchsorted_segmented(
    sorted_vals: jax.Array,
    seg_off_lo: jax.Array,
    seg_off_hi: jax.Array,
    queries: jax.Array,
    n_iters: int,
) -> jax.Array:
    """For each query i, binary-search ``queries[i]`` in
    ``sorted_vals[seg_off_lo[i]:seg_off_hi[i]]``; returns the global index of
    the first element == query (callers guarantee presence), else hi.

    This is the vectorized analogue of the paper's per-thread binary search
    into shared-memory histogram bins.
    """
    lo = seg_off_lo
    hi = seg_off_hi

    def body(_, lh):
        lo, hi = lh
        mid = (lo + hi) // 2
        v = sorted_vals[jnp.clip(mid, 0, sorted_vals.shape[0] - 1)]
        go_right = v < queries
        return jnp.where(go_right, mid + 1, lo), jnp.where(go_right, hi, mid)

    lo, hi = jax.lax.fori_loop(0, n_iters, body, (lo, hi))
    return lo
