"""Deterministic hashing for the paper's symmetric pairing noise.

The paper adds a small deterministic pseudorandom value
``rng(min(n,m), max(n,m))`` to each histogram bin, symmetric and conditioned
on both endpoints, capped at 10% of the mean h-edge weight (Sec. V-C). The
paper does not specify the PRNG; we use splitmix32, a well-mixed 32-bit
finalizer, identically on the JAX path, the Pallas kernel, and the numpy
oracle so all three agree bit-for-bit.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def _splitmix32(x):
    """Works for jnp and np uint32 arrays alike."""
    mod = jnp if isinstance(x, jnp.ndarray) else np
    x = (x + mod.uint32(0x9E3779B9)).astype(mod.uint32)
    x = (x ^ (x >> mod.uint32(16))) * mod.uint32(0x21F0AAAD)
    x = (x ^ (x >> mod.uint32(15))) * mod.uint32(0x735A2D97)
    x = x ^ (x >> mod.uint32(15))
    return x


def pair_noise_u32(a, b):
    """Symmetric uint32 hash of an unordered pair of int32 ids."""
    mod = jnp if isinstance(a, jnp.ndarray) else np
    lo = mod.minimum(a, b).astype(mod.uint32)
    hi = mod.maximum(a, b).astype(mod.uint32)
    return _splitmix32(_splitmix32(lo) ^ (hi * mod.uint32(0x85EBCA6B)))


def pair_noise(a, b, scale):
    """Symmetric noise in [0, scale); ``scale`` = 0.1 * mean edge weight."""
    mod = jnp if isinstance(a, jnp.ndarray) else np
    u = pair_noise_u32(a, b)
    return (u >> mod.uint32(8)).astype(mod.float32) * (
        mod.float32(scale) / mod.float32(2 ** 24))
