from repro.utils import segops, hashing  # noqa: F401
