"""Partitioning quality metrics and constraint audits (paper Eq. 1, 16)."""
from __future__ import annotations

import numpy as np

from repro.core.hypergraph import HostHypergraph


def _lambda_per_edge(hg: HostHypergraph, parts: np.ndarray) -> np.ndarray:
    """Number of distinct partitions touched by each edge."""
    lam = np.zeros(hg.n_edges, np.int64)
    pin_parts = parts[hg.edge_pins]
    for e in range(hg.n_edges):
        seg = pin_parts[hg.edge_off[e]: hg.edge_off[e + 1]]
        lam[e] = len(np.unique(seg))
    return lam


def connectivity(hg: HostHypergraph, parts: np.ndarray) -> float:
    """Conn(rho) = sum_e w(e) * (lambda(e) - 1)   (paper Eq. 1)."""
    lam = _lambda_per_edge(hg, parts)
    return float((hg.edge_w * np.maximum(lam - 1, 0)).sum())


def cut_net(hg: HostHypergraph, parts: np.ndarray) -> float:
    """Cut-net(rho) = sum_e w(e) * 1[lambda(e) > 1]   (paper Eq. 16)."""
    lam = _lambda_per_edge(hg, parts)
    return float((hg.edge_w * (lam > 1)).sum())


def coarsening_score(hg: HostHypergraph, gamma: np.ndarray) -> float:
    """Score(gamma) = sum_e w(e) * (|e| - |gamma(e)|)   (paper Eq. 2)."""
    card = np.diff(hg.edge_off)
    lam = _lambda_per_edge(hg, gamma)
    return float((hg.edge_w * (card - lam)).sum())


def partition_loads(hg: HostHypergraph, parts: np.ndarray,
                    node_size: np.ndarray | None = None):
    """Returns (sizes[K], distinct_inbound[K]) for partitions 0..K-1."""
    K = int(parts.max()) + 1 if len(parts) else 0
    if node_size is None:
        node_size = np.ones(hg.n_nodes, np.int64)
    sizes = np.bincount(parts, weights=node_size, minlength=K).astype(np.int64)

    pin_edge = np.repeat(np.arange(hg.n_edges, dtype=np.int64),
                         np.diff(hg.edge_off))
    rel = np.arange(hg.n_pins, dtype=np.int64) - hg.edge_off[pin_edge]
    is_dst = rel >= hg.edge_nsrc[pin_edge]
    dst_parts = parts[hg.edge_pins[is_dst]]
    dst_edges = pin_edge[is_dst]
    pe = np.unique(np.stack([dst_parts.astype(np.int64), dst_edges], 1), axis=0)
    inbound = np.bincount(pe[:, 0], minlength=K).astype(np.int64)
    return sizes, inbound


def audit(hg: HostHypergraph, parts: np.ndarray, omega: int, delta: int,
          node_size: np.ndarray | None = None) -> dict:
    """Full validity audit of a partitioning under (Omega, Delta)."""
    assert parts.min(initial=0) >= 0, "all nodes must be assigned"
    sizes, inbound = partition_loads(hg, parts, node_size)
    return dict(
        n_parts=len(sizes),
        max_size=int(sizes.max(initial=0)),
        max_inbound=int(inbound.max(initial=0)),
        size_ok=bool((sizes <= omega).all()),
        inbound_ok=bool((inbound <= delta).all()),
        n_size_violations=int((sizes > omega).sum()),
        n_inbound_violations=int((inbound > delta).sum()),
        connectivity=connectivity(hg, parts),
        cut_net=cut_net(hg, parts),
    )


def balance_epsilon(parts: np.ndarray, k: int) -> float:
    """Imbalance eps s.t. max part size == (1+eps) * N/k."""
    sizes = np.bincount(parts, minlength=k)
    return float(sizes.max() / (len(parts) / k) - 1.0)
