"""Multi-level partitioner driver (paper Sec. III, Fig. 1).

Coarsen level-by-level (clusters capped at 2 nodes per level) until the
minimum valid partition count ceil(|N|/Omega) is reached or no further valid
clusters can be built; the coarsest clusters ARE the initial partitions
(score/connectivity duality, Eq. 2 vs Eq. 1); then uncoarsen with Theta
refinement repetitions per level.

Host Python drives the level loop (the level count is data-dependent, as on
GPU where the host launches kernels per level); every level step is one
fused jit at a single static capacity signature, so the whole run compiles
exactly once per input bucket.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics
from repro.core.contract import contract
from repro.core.coarsen import CoarsenParams, coarsen_step
from repro.core.hypergraph import (Caps, GraphDelta, HostHypergraph,
                                   CapacityError, apply_delta,
                                   check_expansion_caps, check_fits_caps,
                                   device_from_host, device_pair_count,
                                   host_pair_count)
from repro.core.refine import RefineParams, refine_level
from repro.obs import trace as otrace
from repro.obs import vcycle as ovcycle


@dataclasses.dataclass
class PartitionResult:
    parts: np.ndarray          # [N] partition id per node
    n_parts: int
    n_levels: int
    connectivity: float
    cut_net: float
    audit: dict
    # thin view over the span tree (same floats): kept for API compat, the
    # span tree (repro.obs.trace) is the source of truth for phase timing
    timings: dict
    level_log: list
    # per-level Pallas dispatch coverage (empty when use_kernels=False):
    #   "coarsen": [0/1 per coarsening level, finest first]
    #   "refine":  [gains-kernel reps (0..theta) per refined level, finest
    #               first; the last entry is the coarsest level]
    #   "pins":    [pins-count-kernel reps per refined level, same layout]
    kernel_path: dict = dataclasses.field(default_factory=dict)
    # per-level telemetry (repro.obs.vcycle.LevelStats, finest first;
    # quality fields populated under collect_stats=True)
    level_stats: list = dataclasses.field(default_factory=list)
    # how this result was produced: "cold" (full V-cycle), "warm"
    # (refine-only from a previous partition), or "fallback-drift" /
    # "fallback-audit" (repartition() fell back to a full V-cycle)
    mode: str = "cold"


def _next_pow2(x: int) -> int:
    return 1 << max(0, math.ceil(math.log2(max(1, x))))


def make_coarsen_fns(cparams: CoarsenParams, plan, dist_coarsen: bool = True,
                     compensated: bool = False):
    """Per-level coarsening dispatchers shared by `partition` and
    `kway.partition_kway`: returns `(coarsen(d, caps) -> (match, n_pairs,
    (n_pairs_live, n_nbr_entries, kernel_path_taken)),
    contract(d, match, caps) -> (d2, gamma))`. With a `Plan` (and
    `dist_coarsen`), both run on the mesh via `dist.partition.coarsen_level`
    / `contract_level` — bit-exact with the single-device pair at matching
    `use_kernels` (the mesh runs the Pallas kernels stripe-locally, and
    the dispatch branch taken per level is mesh-independent — see
    `repro.kernels`). ``compensated`` opts the eta / matching-sum0 float
    reductions into the Neumaier-compensated psum (O(dense) traffic, ~1
    ulp, not bit-identical).

    Both dispatchers return the same shapes in either mode; `_coarsen`'s
    trailing diagnostics feed the drivers' host-side capacity-overflow
    audit (`check_expansion_caps`) and the per-level kernel-coverage
    accounting (`PartitionResult.kernel_path`)."""
    if plan is None or not dist_coarsen:
        def _coarsen(d_, caps_):
            match, n_pairs, props = coarsen_step(d_, caps_, cparams)
            return match, n_pairs, (props.n_pairs_live, props.n_nbr_entries,
                                    props.kernel_path_taken)

        def _contract(d_, match_, caps_):
            return contract(d_, match_, caps_)
    else:
        import repro.dist.partition as dist_partition

        def _coarsen(d_, caps_):
            return dist_partition.coarsen_level(d_, caps_, cparams, plan,
                                                compensated=compensated)

        def _contract(d_, match_, caps_):
            return dist_partition.contract_level(d_, match_, caps_, plan)
    return _coarsen, _contract


def make_refine_fn(k, kcap: int, rparams: RefineParams, rlog,
                   plan, race: bool, race_seed: int):
    """Per-level refinement dispatcher shared by `partition` and
    `kway.partition_kway`: plain `refine_level` without a plan, the
    mesh-raced/sharded `dist.partition.refine_level` with one (seed offset
    by level so replica tie-break permutations decorrelate across levels).
    Returns `fn(d, parts, caps, level) -> (parts, (kernel_hits,
    pins_hits))` — the trailing device scalars count the level's
    repetitions whose gains / pins dispatch took the Pallas branch."""
    if plan is None:
        def _refine(d_, parts_, caps_, lvl_):
            return refine_level(d_, parts_, k, caps_, kcap, rparams, rlog)
    else:
        import repro.dist.partition as dist_partition

        def _refine(d_, parts_, caps_, lvl_):
            return dist_partition.refine_level(
                d_, parts_, k, caps_, kcap, rparams, plan, race=race,
                seed=race_seed + lvl_, log=rlog)
    return _refine


def run_coarsen_loop(d, caps: Caps, target: int, max_levels: int,
                     _coarsen, _contract, log: list | None,
                     shrink: bool = False):
    """Host-driven audited coarsening loop shared by `partition` and
    `kway.partition_kway`: per level, one batched device sync for the four
    scalars, a `check_expansion_caps` overflow audit BEFORE trusting the
    matches (the device pipelines drop out-of-capacity lanes silently), stop
    on `n_pairs == 0` or `target`. Returns
    ``(d, caps, levels, gammas, coarsen_hits, coarsen_meta)`` with
    ``levels`` a list of ``(d, caps)`` per retained level (caps varies only
    under ``shrink``, the pow2 re-bucketing mode) and ``coarsen_meta`` one
    structural-stats dict per retained level (nodes/edges/pins, live pair
    and neighborhood counts with their capacity occupancy, kernel path) —
    assembled from the same batched per-level sync, so telemetry adds no
    round-trips. Blocks on the dispatch tail before returning so the
    caller's phase timer doesn't leak into the next phase."""
    from repro.core.hypergraph import shrink_device

    levels, gammas, coarsen_hits, coarsen_meta = [], [], [], []
    while int(d.n_nodes) > target and len(gammas) < max_levels:
        with otrace.span("coarsen_level", level=len(gammas)):
            match, n_pairs, ovf = _coarsen(d, caps)
            (pairs_live, nbr_entries, kern_hit, n_pairs_h, nodes_h, edges_h,
             pins_h) = (int(v) for v in jax.device_get(
                 [*ovf, n_pairs, d.n_nodes, d.n_edges, d.n_pins]))
            check_expansion_caps(caps, pairs_live, nbr_entries)
            if n_pairs_h == 0:
                break
            coarsen_hits.append(kern_hit)
            coarsen_meta.append(dict(
                nodes=nodes_h, edges=edges_h, pins=pins_h,
                pairs_live=pairs_live, nbr_entries=nbr_entries,
                pair_occupancy=pairs_live / caps.pairs,
                nbr_occupancy=nbr_entries / caps.nbrs,
                kernel_coarsen=kern_hit))
            d2, gamma = _contract(d, match, caps)
            if log is not None:
                log.append(dict(kind="coarsen", level=len(gammas),
                                nodes=nodes_h, pairs=n_pairs_h,
                                caps_n=caps.n))
            levels.append((d, caps))
            gammas.append(gamma)
            d = d2
            if shrink:
                d, caps = shrink_device(d, caps)
    jax.block_until_ready((d, gammas))
    return d, caps, levels, gammas, coarsen_hits, coarsen_meta


def run_refine_loop(d, parts, caps: Caps, levels, gammas, _refine,
                    kcap: int, omega: int, delta: int,
                    collect_stats: bool, log: list | None):
    """Host-driven uncoarsening refinement loop shared by `partition`,
    `kway.partition_kway`, and the warm-start entry `refine_from`: refine
    the coarsest (or only) level, then project through each ``gammas[lvl]``
    and refine every retained level, finest last. Runs under a "refine"
    span with one "refine_level" span per level; kernel-dispatch hits and
    quality scalars stay device values until ONE batched readback at the
    end, so telemetry adds no per-level syncs. Blocks the dispatch tail
    before the span closes.

    Returns ``(parts, refine_span, refine_meta, refine_hits, pins_hits)``
    — ``refine_meta`` one dict per refined level (``kernel_refine`` /
    ``quality`` keys, for `obs.vcycle.assemble`), the hits lists the
    per-level Pallas-branch repetition counts (gains / pins dispatch) for
    ``PartitionResult.kernel_path``. With ``levels=[]`` (warm start) this
    is a single-level refine of ``d`` — no projection, no coarsening."""
    quality_dev: dict = {}
    hits_dev: dict = {}
    with otrace.span("refine") as sp_refine:
        with otrace.span("refine_level", level=len(levels)):
            parts, hits_dev[len(levels)] = _refine(d, parts, caps,
                                                   len(levels))
        if collect_stats:
            quality_dev[len(levels)] = ovcycle.quality_scalars(
                d, parts, caps, kcap, omega, delta)
        for lvl in range(len(levels) - 1, -1, -1):
            g = gammas[lvl]
            d_lvl, caps_lvl = levels[lvl]
            coarse_cap = parts.shape[0]
            with otrace.span("refine_level", level=lvl):
                parts = jnp.where(
                    jnp.arange(caps_lvl.n) < d_lvl.n_nodes,
                    parts[jnp.clip(g[: caps_lvl.n], 0, coarse_cap - 1)], 0)
                parts, hits_dev[lvl] = _refine(d_lvl, parts, caps_lvl, lvl)
            if collect_stats:
                quality_dev[lvl] = ovcycle.quality_scalars(
                    d_lvl, parts, caps_lvl, kcap, omega, delta)
            if log is not None:
                log.append(dict(kind="refine", level=lvl))
        # block before the span closes: the refine tail would otherwise
        # drain inside the caller's np.asarray(parts), after the timer
        # stopped
        jax.block_until_ready(parts)
    # ONE batched readback for the kernel hits + quality scalars
    hits_h, quality_h = jax.device_get(
        ([hits_dev[i] for i in range(len(levels) + 1)], quality_dev))
    refine_hits = [int(kt) for kt, _ in hits_h]
    pins_hits = [int(pt) for _, pt in hits_h]
    refine_meta = {
        lvl: dict(kernel_refine=refine_hits[lvl], quality=quality_h.get(lvl))
        for lvl in range(len(levels) + 1)}
    return parts, sp_refine, refine_meta, refine_hits, pins_hits


def vcycle_device(d, omega, delta, caps: Caps, kcap: int,
                  n_cands: int = 4, theta: int = 16, max_levels: int = 16,
                  chain_rounds: int = 16):
    """Pure-device masked V-cycle: the whole multi-level solve as one traced
    function with NO host round-trips — the vmap-friendly batched entry the
    partition service (`serve.partition_service`) maps over padded request
    batches.

    The host driver's data-dependent level loop becomes a fixed-length
    `lax.scan` over ``max_levels`` with per-level ``active`` masks: a level
    whose coarsening stopped (``n_nodes <= ceil(n/omega)`` or zero matched
    pairs) keeps its graph and partition unchanged, so the scan replays the
    host loop's break semantics exactly (re-coarsening an unchanged graph is
    deterministic, hence stays stopped). ``omega``/``delta`` are *traced*
    int32 scalars — requests with different constraints share one compile.
    ``caps``/``kcap`` are static: one jit signature per capacity bucket.
    ``use_kernels`` is off (Pallas dispatch under vmap is out of scope — the
    service batches small graphs where the segment path wins anyway).

    Returns a dict of device values: ``parts [caps.n]`` (uncompacted,
    0 beyond ``n_nodes``), ``n_parts`` (coarsest-level count, before
    host-side id compaction), ``n_levels``, and the overflow diagnostics
    ``pairs_live_max`` / ``nbr_entries_max`` — per-level maxima the caller
    must audit host-side via `check_expansion_caps` (pair totals are
    monotone under coarsening, so a passed level-0 audit already bounds
    them; this is the defense-in-depth recheck).

    Bit-exactness: at matching ``caps``/``kcap``/params this reproduces
    `partition(...)` (bucket=False, use_kernels=False,
    ``kcap_hint=kcap``) exactly — verified in
    ``tests/test_partition_service.py``."""
    from repro.core.coarsen import coarsen_step_impl
    from repro.core.contract import contract_impl
    from repro.core.refine import refine_step_impl

    omega = jnp.asarray(omega, jnp.int32)
    delta = jnp.asarray(delta, jnp.int32)
    cparams = CoarsenParams(omega=omega, delta=delta, n_cands=n_cands,
                            use_kernels=False)
    rparams = RefineParams(omega=omega, delta=delta, theta=theta,
                           use_kernels=False, chain_rounds=chain_rounds)
    target = jnp.maximum(jnp.int32(1),
                         (d.n_nodes + omega - jnp.int32(1)) // omega)

    def coarsen_body(carry, _):
        d, pmax, nmax = carry
        entering = d.n_nodes > target
        match, n_pairs, props = coarsen_step_impl(d, caps, cparams)
        active = entering & (n_pairs > 0)
        pmax = jnp.maximum(pmax, jnp.where(entering, props.n_pairs_live, 0))
        nmax = jnp.maximum(nmax, jnp.where(entering, props.n_nbr_entries, 0))
        d2, gamma = contract_impl(d, match, caps)
        # inactive level: keep the graph — contract() of a stopped level
        # would still re-canonicalize pin order, which must not happen
        d_next = jax.tree.map(lambda a, b: jnp.where(active, a, b), d2, d)
        return (d_next, pmax, nmax), (d, gamma, active)

    (d, pmax, nmax), (levels_d, gammas, actives) = jax.lax.scan(
        coarsen_body, (d, jnp.int32(0), jnp.int32(0)), None,
        length=max_levels)
    # the coarsest graph is refined but never re-enters coarsening: audit
    # its pair expansion too (refinement expands the same pairs)
    pmax = jnp.maximum(pmax, device_pair_count(d.edge_off))

    k = d.n_nodes
    parts = jnp.where(jnp.arange(caps.n) < k,
                      jnp.arange(caps.n, dtype=jnp.int32), 0)

    enforce = jnp.arange(theta) >= (theta // 2)

    def refine_one_level(d_lvl, parts):
        def rep(parts, enf):
            parts2, *_ = refine_step_impl(d_lvl, parts, k, caps, kcap,
                                          rparams, enf)
            return parts2, None
        parts, _ = jax.lax.scan(rep, parts, enforce)
        return parts

    parts = refine_one_level(d, parts)  # coarsest level
    coarse_cap = parts.shape[0]

    def uncoarsen_body(parts, level):
        d_lvl, gamma, active = level
        proj = jnp.where(jnp.arange(caps.n) < d_lvl.n_nodes,
                         parts[jnp.clip(gamma, 0, coarse_cap - 1)], 0)
        parts_in = jnp.where(active, proj, parts)
        refined = refine_one_level(d_lvl, parts_in)
        return jnp.where(active, refined, parts), None

    parts, _ = jax.lax.scan(uncoarsen_body, parts,
                            (levels_d, gammas, actives), reverse=True)
    return dict(parts=parts, n_parts=k,
                n_levels=jnp.sum(actives.astype(jnp.int32)),
                pairs_live_max=pmax, nbr_entries_max=nmax)


@functools.lru_cache(maxsize=None)
def _batch_solver(caps: Caps, kcap: int, n_cands: int, theta: int,
                  max_levels: int, chain_rounds: int):
    """One jitted vmapped solver per bucket signature (lru-cached so every
    batch a bucket ever solves shares the same compiled executable)."""
    return jax.jit(
        jax.vmap(lambda d_, o_, dl_: vcycle_device(
            d_, o_, dl_, caps, kcap, n_cands=n_cands, theta=theta,
            max_levels=max_levels, chain_rounds=chain_rounds)))


def partition_batch_device(batch, omega, delta, caps: Caps, kcap: int,
                           n_cands: int = 4, theta: int = 16,
                           max_levels: int = 16, chain_rounds: int = 16):
    """vmap of `vcycle_device` over a stacked batch of capacity-padded
    device hypergraphs (every leaf gains a leading batch axis; see
    `serve.partition_service.stack_device_batch`). ``omega``/``delta`` are
    ``[B]`` int32 vectors — per-request constraints inside one solve. One
    jit cache entry per ``(caps, kcap, n_cands, theta, max_levels,
    chain_rounds)`` bucket signature, shared across every batch the bucket
    ever solves."""
    return _batch_solver(caps, kcap, n_cands, theta, max_levels,
                         chain_rounds)(batch, omega, delta)


def partition(hg: HostHypergraph, omega: int, delta: int,
              n_cands: int = 4, theta: int = 16, use_kernels: bool = False,
              refine_params: RefineParams | None = None,
              max_levels: int = 64, collect_log: bool = False,
              kcap_hint: int | None = None,
              matching: str = "exact",
              chain_rounds: int = 16,
              bucket: bool = False,
              plan=None, race: bool = True,
              race_seed: int = 0,
              dist_coarsen: bool = True,
              compensated_psum: bool = False,
              shard_graph: bool = False,
              pair_cap: int | None = None,
              nbr_cap: int | None = None,
              collect_stats: bool = False) -> PartitionResult:
    """Full multi-level constrained partitioning (paper's SNN mode).

    bucket=True enables pow2 capacity re-bucketing between levels (perf
    iteration P1; see EXPERIMENTS.md §Perf) — identical results, coarse
    levels run on geometrically shrinking arrays.

    plan (a `repro.dist.Plan`) routes the whole V-cycle onto the mesh:
    every coarsening level runs through `dist.partition.coarsen_level` /
    `contract_level` (pins/pairs pipelines sharded across the model axis,
    bit-exact with the single-device path at matching `use_kernels` — the
    Pallas hot loops run stripe-locally on the mesh, see `repro.kernels`;
    `dist_coarsen=False` keeps coarsening single-device) and every
    refinement level through
    `dist.partition.refine_level`: repetitions race as replicas across the
    mesh's data axis (`race=False` for the deterministic parity mode) and
    the pins-sized pipelines shard across its model axis. `race_seed`
    decorrelates the replica tie-break permutations. `compensated_psum`
    opts the coarsening eta / matching-sum0 float reductions into the
    Neumaier-compensated psum (O(dense) traffic instead of the stripe-order
    lane gather; within ~1 ulp but not bit-identical to one device).

    shard_graph=True additionally memory-shards the graph *storage*: the
    pins-sized arrays of every level live as per-shard stripes over the
    plan's "model" axis (`dist.graph.ShardedHypergraph`; racing replicas
    share the one striped copy) — bit-identical results, O(pins / shards)
    storage per device. Requires `plan` and `dist_coarsen`; incompatible
    with `bucket` (re-bucketing would re-slice the fixed stripe layout).

    pair_cap / nbr_cap override `Caps.for_host`'s exact pair-expansion /
    neighborhood capacities (e.g. to bound memory). Undersizing them does
    not silently truncate: every level's live counts are audited host-side
    and overflow raises `CapacityError`.

    collect_stats=True additionally populates the quality side of
    `PartitionResult.level_stats` (per-level connectivity/cut of the
    projected partition, block-size and distinct-incident-hyperedge slack
    vs Omega/Delta — `repro.obs.vcycle`): a few extra device reductions per
    level, fetched in one batched readback at the end. Telemetry only reads
    the solve's values, so results are bit-identical either way (tested).
    Phase wall-times are recorded as an `repro.obs.trace` span tree
    ("partition" > setup/coarsen/refine/audit); the ``timings`` dict on the
    result is a thin view over the same spans.
    """
    with otrace.span("partition", nodes=hg.n_nodes, edges=hg.n_edges,
                     pins=hg.n_pins, omega=omega, delta=delta) as sp_total:
        with otrace.span("setup"):
            caps = Caps.for_host(hg, pair_cap=pair_cap, nbr_cap=nbr_cap)
            # exact int64 level-0 audit before any device work: with this
            # passed, pair monotonicity under coarsening bounds every
            # level's count by caps.pairs < 2**31, making the per-level
            # int32 device counts exact
            check_expansion_caps(caps, host_pair_count(hg))
            if shard_graph:
                if plan is None:
                    raise ValueError(
                        "shard_graph=True requires a Plan (mesh) — "
                        "graph stripes live on its 'model' axis")
                if not dist_coarsen:
                    raise ValueError(
                        "shard_graph=True requires dist_coarsen=True: "
                        "the single-device coarsen path cannot read "
                        "memory-sharded storage")
                if bucket:
                    raise ValueError(
                        "bucket=True is incompatible with shard_graph=True: "
                        "capacity re-bucketing would re-slice the fixed "
                        "stripe layout")
                from repro.dist.graph import sharded_from_host
                d = sharded_from_host(hg, caps, plan)
            else:
                d = device_from_host(hg, caps)
        cparams = CoarsenParams(omega=omega, delta=delta, n_cands=n_cands,
                                use_kernels=use_kernels, matching=matching)

        target = max(1, math.ceil(hg.n_nodes / omega))
        log: list = []
        _coarsen, _contract = make_coarsen_fns(cparams, plan, dist_coarsen,
                                               compensated=compensated_psum)
        # run_coarsen_loop: per level one batched scalar sync + overflow
        # audit BEFORE trusting the matches, then blocks the dispatch tail
        # so the phase span doesn't leak into refinement
        with otrace.span("coarsen") as sp_coarsen:
            d, caps, levels, gammas, coarsen_hits, coarsen_meta = \
                run_coarsen_loop(d, caps, target, max_levels, _coarsen,
                                 _contract, log if collect_log else None,
                                 shrink=bucket)
        # the coarsest graph is refined below but never re-entered
        # coarsening, so audit its pair expansion (refinement's in-sequence
        # gains expand the same pairs) — every earlier level was audited in
        # the loop
        check_expansion_caps(caps, device_pair_count(d.edge_off))

        # initial partitioning == coarsest clusters (Sec. III)
        k = int(d.n_nodes)
        if kcap_hint is None:
            kcap = _next_pow2(k)
        else:
            if kcap_hint < k:
                raise ValueError(
                    f"kcap_hint={kcap_hint} is below the coarsest partition "
                    f"count k={k}: partition ids would be silently clipped. "
                    f"Pass kcap_hint >= k (or None for the default pow2).")
            kcap = kcap_hint
        parts = jnp.where(jnp.arange(caps.n) < k,
                          jnp.arange(caps.n, dtype=jnp.int32), 0)

        rparams = refine_params or RefineParams(
            omega=omega, delta=delta, theta=theta, use_kernels=use_kernels,
            chain_rounds=chain_rounds)

        rlog: list | None = [] if collect_log else None
        _refine = make_refine_fn(k, kcap, rparams, rlog, plan, race,
                                 race_seed)

        structure = dict(nodes=k, edges=int(d.n_edges), pins=int(d.n_pins))

        # refine the coarsest level too, then every uncoarsened level
        # (shared with kway/refine_from; one batched readback at the end)
        parts, sp_refine, refine_meta, refine_hits, pins_hits = \
            run_refine_loop(d, parts, caps, levels, gammas, _refine, kcap,
                            omega, delta, collect_stats,
                            log if collect_log else None)
        refine_meta[len(levels)]["structure"] = structure

        with otrace.span("audit"):
            parts_np = np.asarray(parts)[: hg.n_nodes].astype(np.int64)
            # compact partition ids (refinement may empty some partitions)
            uniq, parts_np = np.unique(parts_np, return_inverse=True)
            aud = metrics.audit(hg, parts_np, omega=omega, delta=delta)
    return PartitionResult(
        parts=parts_np, n_parts=len(uniq), n_levels=len(gammas),
        connectivity=aud["connectivity"], cut_net=aud["cut_net"], audit=aud,
        timings=dict(total=sp_total.duration, coarsen=sp_coarsen.duration,
                     refine=sp_refine.duration),
        level_log=(log or []) + (rlog or []),
        kernel_path=dict(coarsen=coarsen_hits, refine=refine_hits,
                         pins=pins_hits),
        level_stats=ovcycle.assemble(coarsen_meta, refine_meta))


# ---------------------------------------------------------------------------
# Streaming repartitioning: warm-started refine-only solves
# ---------------------------------------------------------------------------
def refine_from(hg: HostHypergraph, parts, omega: int, delta: int,
                *, n_parts: int | None = None, theta: int = 16,
                use_kernels: bool = False,
                refine_params: RefineParams | None = None,
                collect_log: bool = False,
                kcap_hint: int | None = None,
                chain_rounds: int = 16,
                plan=None, race: bool = True, race_seed: int = 0,
                shard_graph: bool = False,
                pair_cap: int | None = None, nbr_cap: int | None = None,
                collect_stats: bool = False,
                device_graph=None, caps: Caps | None = None,
                mode: str = "warm") -> PartitionResult:
    """Standalone refinement: the theta-rep refine loop of `partition()`
    applied to an *existing* partition vector, skipping coarsening
    entirely (``n_levels == 0``; the span tree contains no
    ``coarsen_level`` spans by construction).

    ``parts`` is a host vector of at least ``hg.n_nodes`` partition ids;
    ``n_parts`` overrides the inferred partition count (``max+1``) —
    required when trailing partitions happen to be empty but ids must stay
    stable (the k-way warm path). ``plan``/``race``/``shard_graph`` mirror
    `partition()`: with a mesh the refinement levels race replicas over
    "data" and shard the pins pipelines over "model", bit-identical at
    ``race=False``.

    ``device_graph``/``caps`` short-circuit the device upload: the caller
    (``repartition``'s warm cache) already holds graph storage at a known
    capacity signature — reusing it keeps the jit cache warm across
    resubmits. Both must be given together and are trusted to match ``hg``.
    """
    with otrace.span("partition", nodes=hg.n_nodes, edges=hg.n_edges,
                     pins=hg.n_pins, omega=omega, delta=delta,
                     mode=mode) as sp_total:
        with otrace.span("setup"):
            if (device_graph is None) != (caps is None):
                raise ValueError(
                    "device_graph and caps must be passed together")
            if caps is None:
                caps = Caps.for_host(hg, pair_cap=pair_cap, nbr_cap=nbr_cap)
                # exact int64 audit before any device work (refinement's
                # in-sequence gains expand the same pin pairs)
                check_expansion_caps(caps, host_pair_count(hg))
                if shard_graph:
                    if plan is None:
                        raise ValueError(
                            "shard_graph=True requires a Plan (mesh)")
                    from repro.dist.graph import sharded_from_host
                    d = sharded_from_host(hg, caps, plan)
                else:
                    d = device_from_host(hg, caps)
            else:
                d = device_graph

            parts_in = np.asarray(parts, np.int64).ravel()
            if parts_in.shape[0] < hg.n_nodes:
                raise ValueError(
                    f"parts has {parts_in.shape[0]} entries for "
                    f"{hg.n_nodes} nodes — apply deltas (which may add "
                    f"nodes) via repartition(), or extend the vector")
            parts_in = parts_in[: hg.n_nodes]
            if parts_in.size and parts_in.min() < 0:
                raise ValueError("parts must be non-negative")
            k = (int(parts_in.max(initial=0)) + 1 if n_parts is None
                 else int(n_parts))
            if parts_in.size and int(parts_in.max(initial=0)) >= k:
                raise ValueError(
                    f"n_parts={k} is below max partition id "
                    f"{int(parts_in.max())}")
            if kcap_hint is None:
                kcap = _next_pow2(k)
            else:
                if kcap_hint < k:
                    raise ValueError(
                        f"kcap_hint={kcap_hint} is below the partition "
                        f"count k={k}")
                kcap = kcap_hint
            parts_dev = jnp.zeros((caps.n,), jnp.int32).at[: hg.n_nodes].set(
                jnp.asarray(parts_in, jnp.int32))

        rparams = refine_params or RefineParams(
            omega=omega, delta=delta, theta=theta, use_kernels=use_kernels,
            chain_rounds=chain_rounds)
        rlog: list | None = [] if collect_log else None
        _refine = make_refine_fn(k, kcap, rparams, rlog, plan, race,
                                 race_seed)

        parts_dev, sp_refine, refine_meta, refine_hits, pins_hits = \
            run_refine_loop(d, parts_dev, caps, [], [], _refine, kcap,
                            omega, delta, collect_stats,
                            rlog if collect_log else None)
        refine_meta[0]["structure"] = dict(
            nodes=hg.n_nodes, edges=hg.n_edges, pins=hg.n_pins)

        with otrace.span("audit"):
            parts_np = np.asarray(parts_dev)[: hg.n_nodes].astype(np.int64)
            if n_parts is None:
                uniq, parts_np = np.unique(parts_np, return_inverse=True)
                n_out = len(uniq)
            else:
                # pinned id space (k-way warm path): empty partitions keep
                # their ids, no compaction
                n_out = k
            aud = metrics.audit(hg, parts_np, omega=omega, delta=delta)
    return PartitionResult(
        parts=parts_np, n_parts=n_out, n_levels=0,
        connectivity=aud["connectivity"], cut_net=aud["cut_net"], audit=aud,
        timings=dict(total=sp_total.duration, coarsen=0.0,
                     refine=sp_refine.duration),
        level_log=rlog or [],
        kernel_path=dict(coarsen=[], refine=refine_hits, pins=pins_hits),
        level_stats=ovcycle.assemble([], refine_meta),
        mode=mode)


@dataclasses.dataclass
class WarmCache:
    """Caller-owned device-storage cache for `repartition`: the capacity
    signature and graph storage of the last solve. A valid cache lets a
    resubmit skip both `Caps.for_host` and the full host->device upload
    (sharded storage updates by stripe-local scatters), and — because the
    caps are unchanged — reuse every compiled executable. `repartition`
    mutates it in place; pass a fresh instance (or None) to start cold."""

    caps: Caps | None = None
    d: object | None = None   # DeviceHypergraph | ShardedHypergraph

    def invalidate(self) -> None:
        self.caps = None
        self.d = None


def _extend_parts(prev_parts, n_nodes: int, k: int) -> np.ndarray:
    """Deterministic placement for nodes added since the previous solve:
    each new node joins the currently least-loaded partition (ties ->
    lowest id), updating loads as it goes. Node deletions are tombstones
    (ids stable), so existing entries never shift."""
    prev = np.asarray(prev_parts, np.int64).ravel()
    if prev.shape[0] >= n_nodes:
        return prev[:n_nodes]
    sizes = np.bincount(prev, minlength=max(k, 1))
    out = np.concatenate([prev, np.zeros(n_nodes - prev.shape[0], np.int64)])
    for n in range(prev.shape[0], n_nodes):
        p = int(np.argmin(sizes))
        out[n] = p
        sizes[p] += 1
    return out


def repartition(hg: HostHypergraph, prev_parts, omega: int, delta: int,
                *, deltas=None, drift_threshold: float = 0.25,
                cache: WarmCache | None = None,
                n_parts: int | None = None,
                theta: int = 16, n_cands: int = 4,
                use_kernels: bool = False,
                refine_params: RefineParams | None = None,
                collect_log: bool = False,
                kcap_hint: int | None = None,
                chain_rounds: int = 16, max_levels: int = 64,
                matching: str = "exact",
                plan=None, race: bool = True, race_seed: int = 0,
                dist_coarsen: bool = True, compensated_psum: bool = False,
                shard_graph: bool = False,
                pair_cap: int | None = None, nbr_cap: int | None = None,
                collect_stats: bool = False) -> PartitionResult:
    """Streaming repartitioning: apply ``deltas`` (a `GraphDelta` or a
    sequence of them) to ``hg`` **in place**, then re-solve warm from
    ``prev_parts`` — refinement only, no coarsening — falling back to a
    full cold V-cycle when the accumulated ``hg.drift`` exceeds
    ``drift_threshold`` or the warm solution fails the Omega/Delta +
    distinct-incident-hyperedge audit. The result's ``mode`` records which
    path produced it ("warm" / "fallback-drift" / "fallback-audit"; a
    zero-delta call with no cache is bit-identical to `refine_from`).

    ``cache`` (a `WarmCache`) carries device storage across calls: with a
    valid cache and sharded storage (``shard_graph`` + ``plan``) the deltas
    apply on device by stripe-local scatters
    (`dist.graph.apply_delta_sharded`); a `CapacityError` from the PR 5
    audit machinery — the post-delta graph outgrew the cached capacity
    signature — invalidates the cache and the solve proceeds warm at fresh
    caps (one re-upload + recompile, not a cold solve). Cold fallbacks
    reset the drift accumulator and invalidate the cache; warm solves keep
    accumulating drift, so repeated small deltas eventually trigger one
    consolidating cold solve."""
    from repro.core.hypergraph import DeviceHypergraph  # noqa: F401

    if isinstance(deltas, GraphDelta):
        deltas = [deltas]
    deltas = list(deltas or [])
    use_sharded = shard_graph and plan is not None

    for dl in deltas:
        if (use_sharded and cache is not None and cache.caps is not None
                and cache.d is not None):
            from repro.dist.graph import (ShardedHypergraph,
                                          apply_delta_sharded)
            if isinstance(cache.d, ShardedHypergraph):
                try:
                    cache.d = apply_delta_sharded(cache.d, hg, dl,
                                                  cache.caps, plan)
                except CapacityError:
                    # resize trigger: host mirror is updated; rebuild
                    # device storage at fresh caps, stay warm
                    cache.invalidate()
                continue
        apply_delta(hg, dl)
        if cache is not None and cache.caps is not None:
            cache.d = None  # replicated storage refreshes wholesale below
            try:
                check_fits_caps(hg, cache.caps)
            except CapacityError:
                cache.invalidate()

    k_prev = (int(np.asarray(prev_parts).max(initial=0)) + 1
              if n_parts is None else int(n_parts))
    parts0 = _extend_parts(prev_parts, hg.n_nodes, k_prev)

    cold_kwargs = dict(
        n_cands=n_cands, theta=theta, use_kernels=use_kernels,
        refine_params=refine_params, max_levels=max_levels,
        collect_log=collect_log, kcap_hint=kcap_hint, matching=matching,
        chain_rounds=chain_rounds, plan=plan, race=race,
        race_seed=race_seed, dist_coarsen=dist_coarsen,
        compensated_psum=compensated_psum, shard_graph=shard_graph,
        pair_cap=pair_cap, nbr_cap=nbr_cap, collect_stats=collect_stats)

    def _cold(mode: str) -> PartitionResult:
        res = partition(hg, omega, delta, **cold_kwargs)
        res.mode = mode
        hg.reset_drift()
        if cache is not None:
            cache.invalidate()
        return res

    if hg.drift > drift_threshold:
        return _cold("fallback-drift")

    # ---- warm path: reuse / rebuild device storage, refine only ----------
    wc = cache if cache is not None else WarmCache()
    if wc.caps is None:
        wc.d = None
        wc.caps = Caps.for_host(hg, pair_cap=pair_cap, nbr_cap=nbr_cap)
        check_expansion_caps(wc.caps, host_pair_count(hg))
    if wc.d is None:
        if use_sharded:
            from repro.dist.graph import sharded_from_host
            wc.d = sharded_from_host(hg, wc.caps, plan)
        else:
            wc.d = device_from_host(hg, wc.caps)
    res = refine_from(
        hg, parts0, omega, delta, n_parts=n_parts, theta=theta,
        use_kernels=use_kernels, refine_params=refine_params,
        collect_log=collect_log, kcap_hint=kcap_hint,
        chain_rounds=chain_rounds, plan=plan, race=race,
        race_seed=race_seed, shard_graph=shard_graph,
        collect_stats=collect_stats, device_graph=wc.d, caps=wc.caps,
        mode="warm")
    if not (res.audit["size_ok"] and res.audit["inbound_ok"]):
        return _cold("fallback-audit")
    return res
