"""Multi-level partitioner driver (paper Sec. III, Fig. 1).

Coarsen level-by-level (clusters capped at 2 nodes per level) until the
minimum valid partition count ceil(|N|/Omega) is reached or no further valid
clusters can be built; the coarsest clusters ARE the initial partitions
(score/connectivity duality, Eq. 2 vs Eq. 1); then uncoarsen with Theta
refinement repetitions per level.

Host Python drives the level loop (the level count is data-dependent, as on
GPU where the host launches kernels per level); every level step is one
fused jit at a single static capacity signature, so the whole run compiles
exactly once per input bucket.
"""
from __future__ import annotations

import dataclasses
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics
from repro.core.contract import contract
from repro.core.coarsen import CoarsenParams, coarsen_step
from repro.core.hypergraph import (Caps, HostHypergraph,
                                   check_expansion_caps, device_from_host,
                                   device_pair_count, host_pair_count)
from repro.core.refine import RefineParams, refine_level


@dataclasses.dataclass
class PartitionResult:
    parts: np.ndarray          # [N] partition id per node
    n_parts: int
    n_levels: int
    connectivity: float
    cut_net: float
    audit: dict
    timings: dict
    level_log: list
    # per-level Pallas dispatch coverage (empty when use_kernels=False):
    #   "coarsen": [0/1 per coarsening level, finest first]
    #   "refine":  [kernel reps (0..theta) per refined level, finest first;
    #               the last entry is the coarsest level]
    kernel_path: dict = dataclasses.field(default_factory=dict)


def _next_pow2(x: int) -> int:
    return 1 << max(0, math.ceil(math.log2(max(1, x))))


def make_coarsen_fns(cparams: CoarsenParams, plan, dist_coarsen: bool = True,
                     compensated: bool = False):
    """Per-level coarsening dispatchers shared by `partition` and
    `kway.partition_kway`: returns `(coarsen(d, caps) -> (match, n_pairs,
    (n_pairs_live, n_nbr_entries, kernel_path_taken)),
    contract(d, match, caps) -> (d2, gamma))`. With a `Plan` (and
    `dist_coarsen`), both run on the mesh via `dist.partition.coarsen_level`
    / `contract_level` — bit-exact with the single-device pair at matching
    `use_kernels` (the mesh runs the Pallas kernels stripe-locally, and
    the dispatch branch taken per level is mesh-independent — see
    `repro.kernels`). ``compensated`` opts the eta / matching-sum0 float
    reductions into the Neumaier-compensated psum (O(dense) traffic, ~1
    ulp, not bit-identical).

    Both dispatchers return the same shapes in either mode; `_coarsen`'s
    trailing diagnostics feed the drivers' host-side capacity-overflow
    audit (`check_expansion_caps`) and the per-level kernel-coverage
    accounting (`PartitionResult.kernel_path`)."""
    if plan is None or not dist_coarsen:
        def _coarsen(d_, caps_):
            match, n_pairs, props = coarsen_step(d_, caps_, cparams)
            return match, n_pairs, (props.n_pairs_live, props.n_nbr_entries,
                                    props.kernel_path_taken)

        def _contract(d_, match_, caps_):
            return contract(d_, match_, caps_)
    else:
        import repro.dist.partition as dist_partition

        def _coarsen(d_, caps_):
            return dist_partition.coarsen_level(d_, caps_, cparams, plan,
                                                compensated=compensated)

        def _contract(d_, match_, caps_):
            return dist_partition.contract_level(d_, match_, caps_, plan)
    return _coarsen, _contract


def make_refine_fn(k, kcap: int, rparams: RefineParams, rlog,
                   plan, race: bool, race_seed: int):
    """Per-level refinement dispatcher shared by `partition` and
    `kway.partition_kway`: plain `refine_level` without a plan, the
    mesh-raced/sharded `dist.partition.refine_level` with one (seed offset
    by level so replica tie-break permutations decorrelate across levels).
    Returns `fn(d, parts, caps, level) -> (parts, kernel_hits)` — the
    trailing device scalar counts the level's repetitions whose gains
    dispatch took the Pallas branch."""
    if plan is None:
        def _refine(d_, parts_, caps_, lvl_):
            return refine_level(d_, parts_, k, caps_, kcap, rparams, rlog)
    else:
        import repro.dist.partition as dist_partition

        def _refine(d_, parts_, caps_, lvl_):
            return dist_partition.refine_level(
                d_, parts_, k, caps_, kcap, rparams, plan, race=race,
                seed=race_seed + lvl_, log=rlog)
    return _refine


def partition(hg: HostHypergraph, omega: int, delta: int,
              n_cands: int = 4, theta: int = 16, use_kernels: bool = False,
              refine_params: RefineParams | None = None,
              max_levels: int = 64, collect_log: bool = False,
              kcap_hint: int | None = None,
              matching: str = "exact",
              chain_rounds: int = 16,
              bucket: bool = False,
              plan=None, race: bool = True,
              race_seed: int = 0,
              dist_coarsen: bool = True,
              compensated_psum: bool = False,
              shard_graph: bool = False,
              pair_cap: int | None = None,
              nbr_cap: int | None = None) -> PartitionResult:
    """Full multi-level constrained partitioning (paper's SNN mode).

    bucket=True enables pow2 capacity re-bucketing between levels (perf
    iteration P1; see EXPERIMENTS.md §Perf) — identical results, coarse
    levels run on geometrically shrinking arrays.

    plan (a `repro.dist.Plan`) routes the whole V-cycle onto the mesh:
    every coarsening level runs through `dist.partition.coarsen_level` /
    `contract_level` (pins/pairs pipelines sharded across the model axis,
    bit-exact with the single-device path at matching `use_kernels` — the
    Pallas hot loops run stripe-locally on the mesh, see `repro.kernels`;
    `dist_coarsen=False` keeps coarsening single-device) and every
    refinement level through
    `dist.partition.refine_level`: repetitions race as replicas across the
    mesh's data axis (`race=False` for the deterministic parity mode) and
    the pins-sized pipelines shard across its model axis. `race_seed`
    decorrelates the replica tie-break permutations. `compensated_psum`
    opts the coarsening eta / matching-sum0 float reductions into the
    Neumaier-compensated psum (O(dense) traffic instead of the stripe-order
    lane gather; within ~1 ulp but not bit-identical to one device).

    shard_graph=True additionally memory-shards the graph *storage*: the
    pins-sized arrays of every level live as per-shard stripes over the
    plan's "model" axis (`dist.graph.ShardedHypergraph`; racing replicas
    share the one striped copy) — bit-identical results, O(pins / shards)
    storage per device. Requires `plan` and `dist_coarsen`; incompatible
    with `bucket` (re-bucketing would re-slice the fixed stripe layout).

    pair_cap / nbr_cap override `Caps.for_host`'s exact pair-expansion /
    neighborhood capacities (e.g. to bound memory). Undersizing them does
    not silently truncate: every level's live counts are audited host-side
    and overflow raises `CapacityError`.
    """
    from repro.core.hypergraph import shrink_device

    t0 = time.perf_counter()
    caps = Caps.for_host(hg, pair_cap=pair_cap, nbr_cap=nbr_cap)
    # exact int64 level-0 audit before any device work: with this passed,
    # pair monotonicity under coarsening bounds every level's count by
    # caps.pairs < 2**31, making the per-level int32 device counts exact
    check_expansion_caps(caps, host_pair_count(hg))
    if shard_graph:
        if plan is None:
            raise ValueError("shard_graph=True requires a Plan (mesh) — "
                             "graph stripes live on its 'model' axis")
        if not dist_coarsen:
            raise ValueError("shard_graph=True requires dist_coarsen=True: "
                             "the single-device coarsen path cannot read "
                             "memory-sharded storage")
        if bucket:
            raise ValueError("bucket=True is incompatible with "
                             "shard_graph=True: capacity re-bucketing would "
                             "re-slice the fixed stripe layout")
        from repro.dist.graph import sharded_from_host
        d = sharded_from_host(hg, caps, plan)
    else:
        d = device_from_host(hg, caps)
    cparams = CoarsenParams(omega=omega, delta=delta, n_cands=n_cands,
                            use_kernels=use_kernels, matching=matching)

    target = max(1, math.ceil(hg.n_nodes / omega))
    levels, gammas = [], []
    log: list = []
    _coarsen, _contract = make_coarsen_fns(cparams, plan, dist_coarsen,
                                           compensated=compensated_psum)
    t_coarsen = time.perf_counter()
    coarsen_hits: list = []
    while int(d.n_nodes) > target and len(gammas) < max_levels:
        match, n_pairs, ovf = _coarsen(d, caps)
        # one batched sync for the level's four scalars, then audit
        # BEFORE trusting the matches: the device pipelines drop
        # out-of-capacity lanes silently, so an undersized Caps must raise
        # here, not mis-partition
        pairs_live, nbr_entries, kern_hit, n_pairs_h = (
            int(v) for v in jax.device_get([*ovf, n_pairs]))
        check_expansion_caps(caps, pairs_live, nbr_entries)
        if n_pairs_h == 0:
            break
        coarsen_hits.append(kern_hit)
        d2, gamma = _contract(d, match, caps)
        if collect_log:
            log.append(dict(kind="coarsen", level=len(gammas),
                            nodes=int(d.n_nodes), pairs=n_pairs_h,
                            caps_n=caps.n))
        levels.append((d, caps))
        gammas.append(gamma)
        d = d2
        if bucket:
            d, caps = shrink_device(d, caps)
    # drain the async dispatch tail before stopping the phase timer —
    # otherwise the last contract finishes during refinement (or during
    # the final np.asarray readback) and the phase columns under-report
    jax.block_until_ready((d, gammas))
    t_coarsen = time.perf_counter() - t_coarsen
    # the coarsest graph is refined below but never re-entered coarsening,
    # so audit its pair expansion (refinement's in-sequence gains expand
    # the same pairs) — every earlier level was audited in the loop
    check_expansion_caps(caps, device_pair_count(d.edge_off))

    # initial partitioning == coarsest clusters (Sec. III)
    k = int(d.n_nodes)
    if kcap_hint is None:
        kcap = _next_pow2(k)
    else:
        if kcap_hint < k:
            raise ValueError(
                f"kcap_hint={kcap_hint} is below the coarsest partition "
                f"count k={k}: partition ids would be silently clipped. "
                f"Pass kcap_hint >= k (or None for the default pow2).")
        kcap = kcap_hint
    parts = jnp.where(jnp.arange(caps.n) < k,
                      jnp.arange(caps.n, dtype=jnp.int32), 0)

    rparams = refine_params or RefineParams(
        omega=omega, delta=delta, theta=theta, use_kernels=use_kernels,
        chain_rounds=chain_rounds)

    t_refine = time.perf_counter()
    rlog: list | None = [] if collect_log else None
    _refine = make_refine_fn(k, kcap, rparams, rlog, plan, race, race_seed)

    # refine the coarsest level too, then every uncoarsened level; kernel
    # hits stay device scalars until the single batched readback below
    refine_hits_dev: dict = {}
    parts, refine_hits_dev[len(levels)] = _refine(d, parts, caps, len(levels))
    for lvl in range(len(levels) - 1, -1, -1):
        g = gammas[lvl]
        d_lvl, caps_lvl = levels[lvl]
        coarse_cap = parts.shape[0]
        parts = jnp.where(jnp.arange(caps_lvl.n) < d_lvl.n_nodes,
                          parts[jnp.clip(g[: caps_lvl.n], 0,
                                         coarse_cap - 1)], 0)
        parts, refine_hits_dev[lvl] = _refine(d_lvl, parts, caps_lvl, lvl)
        if collect_log:
            log.append(dict(kind="refine", level=lvl))
    # block before reading the timer: the refine tail would otherwise
    # drain inside np.asarray(parts) below, after t_refine stopped
    jax.block_until_ready(parts)
    t_refine = time.perf_counter() - t_refine
    refine_hits = [int(v) for v in jax.device_get(
        [refine_hits_dev[i] for i in range(len(levels) + 1)])]

    parts_np = np.asarray(parts)[: hg.n_nodes].astype(np.int64)
    # compact partition ids (refinement may empty some partitions)
    uniq, parts_np = np.unique(parts_np, return_inverse=True)
    aud = metrics.audit(hg, parts_np, omega=omega, delta=delta)
    return PartitionResult(
        parts=parts_np, n_parts=len(uniq), n_levels=len(gammas),
        connectivity=aud["connectivity"], cut_net=aud["cut_net"], audit=aud,
        timings=dict(total=time.perf_counter() - t0, coarsen=t_coarsen,
                     refine=t_refine),
        level_log=(log or []) + (rlog or []),
        kernel_path=dict(coarsen=coarsen_hits, refine=refine_hits))
