"""Multi-level partitioner driver (paper Sec. III, Fig. 1).

Coarsen level-by-level (clusters capped at 2 nodes per level) until the
minimum valid partition count ceil(|N|/Omega) is reached or no further valid
clusters can be built; the coarsest clusters ARE the initial partitions
(score/connectivity duality, Eq. 2 vs Eq. 1); then uncoarsen with Theta
refinement repetitions per level.

Host Python drives the level loop (the level count is data-dependent, as on
GPU where the host launches kernels per level); every level step is one
fused jit at a single static capacity signature, so the whole run compiles
exactly once per input bucket.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics
from repro.core.contract import contract
from repro.core.coarsen import CoarsenParams, coarsen_step
from repro.core.hypergraph import (Caps, HostHypergraph,
                                   check_expansion_caps, device_from_host,
                                   device_pair_count, host_pair_count)
from repro.core.refine import RefineParams, refine_level
from repro.obs import trace as otrace
from repro.obs import vcycle as ovcycle


@dataclasses.dataclass
class PartitionResult:
    parts: np.ndarray          # [N] partition id per node
    n_parts: int
    n_levels: int
    connectivity: float
    cut_net: float
    audit: dict
    # thin view over the span tree (same floats): kept for API compat, the
    # span tree (repro.obs.trace) is the source of truth for phase timing
    timings: dict
    level_log: list
    # per-level Pallas dispatch coverage (empty when use_kernels=False):
    #   "coarsen": [0/1 per coarsening level, finest first]
    #   "refine":  [kernel reps (0..theta) per refined level, finest first;
    #               the last entry is the coarsest level]
    kernel_path: dict = dataclasses.field(default_factory=dict)
    # per-level telemetry (repro.obs.vcycle.LevelStats, finest first;
    # quality fields populated under collect_stats=True)
    level_stats: list = dataclasses.field(default_factory=list)


def _next_pow2(x: int) -> int:
    return 1 << max(0, math.ceil(math.log2(max(1, x))))


def make_coarsen_fns(cparams: CoarsenParams, plan, dist_coarsen: bool = True,
                     compensated: bool = False):
    """Per-level coarsening dispatchers shared by `partition` and
    `kway.partition_kway`: returns `(coarsen(d, caps) -> (match, n_pairs,
    (n_pairs_live, n_nbr_entries, kernel_path_taken)),
    contract(d, match, caps) -> (d2, gamma))`. With a `Plan` (and
    `dist_coarsen`), both run on the mesh via `dist.partition.coarsen_level`
    / `contract_level` — bit-exact with the single-device pair at matching
    `use_kernels` (the mesh runs the Pallas kernels stripe-locally, and
    the dispatch branch taken per level is mesh-independent — see
    `repro.kernels`). ``compensated`` opts the eta / matching-sum0 float
    reductions into the Neumaier-compensated psum (O(dense) traffic, ~1
    ulp, not bit-identical).

    Both dispatchers return the same shapes in either mode; `_coarsen`'s
    trailing diagnostics feed the drivers' host-side capacity-overflow
    audit (`check_expansion_caps`) and the per-level kernel-coverage
    accounting (`PartitionResult.kernel_path`)."""
    if plan is None or not dist_coarsen:
        def _coarsen(d_, caps_):
            match, n_pairs, props = coarsen_step(d_, caps_, cparams)
            return match, n_pairs, (props.n_pairs_live, props.n_nbr_entries,
                                    props.kernel_path_taken)

        def _contract(d_, match_, caps_):
            return contract(d_, match_, caps_)
    else:
        import repro.dist.partition as dist_partition

        def _coarsen(d_, caps_):
            return dist_partition.coarsen_level(d_, caps_, cparams, plan,
                                                compensated=compensated)

        def _contract(d_, match_, caps_):
            return dist_partition.contract_level(d_, match_, caps_, plan)
    return _coarsen, _contract


def make_refine_fn(k, kcap: int, rparams: RefineParams, rlog,
                   plan, race: bool, race_seed: int):
    """Per-level refinement dispatcher shared by `partition` and
    `kway.partition_kway`: plain `refine_level` without a plan, the
    mesh-raced/sharded `dist.partition.refine_level` with one (seed offset
    by level so replica tie-break permutations decorrelate across levels).
    Returns `fn(d, parts, caps, level) -> (parts, kernel_hits)` — the
    trailing device scalar counts the level's repetitions whose gains
    dispatch took the Pallas branch."""
    if plan is None:
        def _refine(d_, parts_, caps_, lvl_):
            return refine_level(d_, parts_, k, caps_, kcap, rparams, rlog)
    else:
        import repro.dist.partition as dist_partition

        def _refine(d_, parts_, caps_, lvl_):
            return dist_partition.refine_level(
                d_, parts_, k, caps_, kcap, rparams, plan, race=race,
                seed=race_seed + lvl_, log=rlog)
    return _refine


def run_coarsen_loop(d, caps: Caps, target: int, max_levels: int,
                     _coarsen, _contract, log: list | None,
                     shrink: bool = False):
    """Host-driven audited coarsening loop shared by `partition` and
    `kway.partition_kway`: per level, one batched device sync for the four
    scalars, a `check_expansion_caps` overflow audit BEFORE trusting the
    matches (the device pipelines drop out-of-capacity lanes silently), stop
    on `n_pairs == 0` or `target`. Returns
    ``(d, caps, levels, gammas, coarsen_hits, coarsen_meta)`` with
    ``levels`` a list of ``(d, caps)`` per retained level (caps varies only
    under ``shrink``, the pow2 re-bucketing mode) and ``coarsen_meta`` one
    structural-stats dict per retained level (nodes/edges/pins, live pair
    and neighborhood counts with their capacity occupancy, kernel path) —
    assembled from the same batched per-level sync, so telemetry adds no
    round-trips. Blocks on the dispatch tail before returning so the
    caller's phase timer doesn't leak into the next phase."""
    from repro.core.hypergraph import shrink_device

    levels, gammas, coarsen_hits, coarsen_meta = [], [], [], []
    while int(d.n_nodes) > target and len(gammas) < max_levels:
        with otrace.span("coarsen_level", level=len(gammas)):
            match, n_pairs, ovf = _coarsen(d, caps)
            (pairs_live, nbr_entries, kern_hit, n_pairs_h, nodes_h, edges_h,
             pins_h) = (int(v) for v in jax.device_get(
                 [*ovf, n_pairs, d.n_nodes, d.n_edges, d.n_pins]))
            check_expansion_caps(caps, pairs_live, nbr_entries)
            if n_pairs_h == 0:
                break
            coarsen_hits.append(kern_hit)
            coarsen_meta.append(dict(
                nodes=nodes_h, edges=edges_h, pins=pins_h,
                pairs_live=pairs_live, nbr_entries=nbr_entries,
                pair_occupancy=pairs_live / caps.pairs,
                nbr_occupancy=nbr_entries / caps.nbrs,
                kernel_coarsen=kern_hit))
            d2, gamma = _contract(d, match, caps)
            if log is not None:
                log.append(dict(kind="coarsen", level=len(gammas),
                                nodes=nodes_h, pairs=n_pairs_h,
                                caps_n=caps.n))
            levels.append((d, caps))
            gammas.append(gamma)
            d = d2
            if shrink:
                d, caps = shrink_device(d, caps)
    jax.block_until_ready((d, gammas))
    return d, caps, levels, gammas, coarsen_hits, coarsen_meta


def vcycle_device(d, omega, delta, caps: Caps, kcap: int,
                  n_cands: int = 4, theta: int = 16, max_levels: int = 16,
                  chain_rounds: int = 16):
    """Pure-device masked V-cycle: the whole multi-level solve as one traced
    function with NO host round-trips — the vmap-friendly batched entry the
    partition service (`serve.partition_service`) maps over padded request
    batches.

    The host driver's data-dependent level loop becomes a fixed-length
    `lax.scan` over ``max_levels`` with per-level ``active`` masks: a level
    whose coarsening stopped (``n_nodes <= ceil(n/omega)`` or zero matched
    pairs) keeps its graph and partition unchanged, so the scan replays the
    host loop's break semantics exactly (re-coarsening an unchanged graph is
    deterministic, hence stays stopped). ``omega``/``delta`` are *traced*
    int32 scalars — requests with different constraints share one compile.
    ``caps``/``kcap`` are static: one jit signature per capacity bucket.
    ``use_kernels`` is off (Pallas dispatch under vmap is out of scope — the
    service batches small graphs where the segment path wins anyway).

    Returns a dict of device values: ``parts [caps.n]`` (uncompacted,
    0 beyond ``n_nodes``), ``n_parts`` (coarsest-level count, before
    host-side id compaction), ``n_levels``, and the overflow diagnostics
    ``pairs_live_max`` / ``nbr_entries_max`` — per-level maxima the caller
    must audit host-side via `check_expansion_caps` (pair totals are
    monotone under coarsening, so a passed level-0 audit already bounds
    them; this is the defense-in-depth recheck).

    Bit-exactness: at matching ``caps``/``kcap``/params this reproduces
    `partition(...)` (bucket=False, use_kernels=False,
    ``kcap_hint=kcap``) exactly — verified in
    ``tests/test_partition_service.py``."""
    from repro.core.coarsen import coarsen_step_impl
    from repro.core.contract import contract_impl
    from repro.core.refine import refine_step_impl

    omega = jnp.asarray(omega, jnp.int32)
    delta = jnp.asarray(delta, jnp.int32)
    cparams = CoarsenParams(omega=omega, delta=delta, n_cands=n_cands,
                            use_kernels=False)
    rparams = RefineParams(omega=omega, delta=delta, theta=theta,
                           use_kernels=False, chain_rounds=chain_rounds)
    target = jnp.maximum(jnp.int32(1),
                         (d.n_nodes + omega - jnp.int32(1)) // omega)

    def coarsen_body(carry, _):
        d, pmax, nmax = carry
        entering = d.n_nodes > target
        match, n_pairs, props = coarsen_step_impl(d, caps, cparams)
        active = entering & (n_pairs > 0)
        pmax = jnp.maximum(pmax, jnp.where(entering, props.n_pairs_live, 0))
        nmax = jnp.maximum(nmax, jnp.where(entering, props.n_nbr_entries, 0))
        d2, gamma = contract_impl(d, match, caps)
        # inactive level: keep the graph — contract() of a stopped level
        # would still re-canonicalize pin order, which must not happen
        d_next = jax.tree.map(lambda a, b: jnp.where(active, a, b), d2, d)
        return (d_next, pmax, nmax), (d, gamma, active)

    (d, pmax, nmax), (levels_d, gammas, actives) = jax.lax.scan(
        coarsen_body, (d, jnp.int32(0), jnp.int32(0)), None,
        length=max_levels)
    # the coarsest graph is refined but never re-enters coarsening: audit
    # its pair expansion too (refinement expands the same pairs)
    pmax = jnp.maximum(pmax, device_pair_count(d.edge_off))

    k = d.n_nodes
    parts = jnp.where(jnp.arange(caps.n) < k,
                      jnp.arange(caps.n, dtype=jnp.int32), 0)

    enforce = jnp.arange(theta) >= (theta // 2)

    def refine_one_level(d_lvl, parts):
        def rep(parts, enf):
            parts2, _, _, _ = refine_step_impl(d_lvl, parts, k, caps, kcap,
                                               rparams, enf)
            return parts2, None
        parts, _ = jax.lax.scan(rep, parts, enforce)
        return parts

    parts = refine_one_level(d, parts)  # coarsest level
    coarse_cap = parts.shape[0]

    def uncoarsen_body(parts, level):
        d_lvl, gamma, active = level
        proj = jnp.where(jnp.arange(caps.n) < d_lvl.n_nodes,
                         parts[jnp.clip(gamma, 0, coarse_cap - 1)], 0)
        parts_in = jnp.where(active, proj, parts)
        refined = refine_one_level(d_lvl, parts_in)
        return jnp.where(active, refined, parts), None

    parts, _ = jax.lax.scan(uncoarsen_body, parts,
                            (levels_d, gammas, actives), reverse=True)
    return dict(parts=parts, n_parts=k,
                n_levels=jnp.sum(actives.astype(jnp.int32)),
                pairs_live_max=pmax, nbr_entries_max=nmax)


@functools.lru_cache(maxsize=None)
def _batch_solver(caps: Caps, kcap: int, n_cands: int, theta: int,
                  max_levels: int, chain_rounds: int):
    """One jitted vmapped solver per bucket signature (lru-cached so every
    batch a bucket ever solves shares the same compiled executable)."""
    return jax.jit(
        jax.vmap(lambda d_, o_, dl_: vcycle_device(
            d_, o_, dl_, caps, kcap, n_cands=n_cands, theta=theta,
            max_levels=max_levels, chain_rounds=chain_rounds)))


def partition_batch_device(batch, omega, delta, caps: Caps, kcap: int,
                           n_cands: int = 4, theta: int = 16,
                           max_levels: int = 16, chain_rounds: int = 16):
    """vmap of `vcycle_device` over a stacked batch of capacity-padded
    device hypergraphs (every leaf gains a leading batch axis; see
    `serve.partition_service.stack_device_batch`). ``omega``/``delta`` are
    ``[B]`` int32 vectors — per-request constraints inside one solve. One
    jit cache entry per ``(caps, kcap, n_cands, theta, max_levels,
    chain_rounds)`` bucket signature, shared across every batch the bucket
    ever solves."""
    return _batch_solver(caps, kcap, n_cands, theta, max_levels,
                         chain_rounds)(batch, omega, delta)


def partition(hg: HostHypergraph, omega: int, delta: int,
              n_cands: int = 4, theta: int = 16, use_kernels: bool = False,
              refine_params: RefineParams | None = None,
              max_levels: int = 64, collect_log: bool = False,
              kcap_hint: int | None = None,
              matching: str = "exact",
              chain_rounds: int = 16,
              bucket: bool = False,
              plan=None, race: bool = True,
              race_seed: int = 0,
              dist_coarsen: bool = True,
              compensated_psum: bool = False,
              shard_graph: bool = False,
              pair_cap: int | None = None,
              nbr_cap: int | None = None,
              collect_stats: bool = False) -> PartitionResult:
    """Full multi-level constrained partitioning (paper's SNN mode).

    bucket=True enables pow2 capacity re-bucketing between levels (perf
    iteration P1; see EXPERIMENTS.md §Perf) — identical results, coarse
    levels run on geometrically shrinking arrays.

    plan (a `repro.dist.Plan`) routes the whole V-cycle onto the mesh:
    every coarsening level runs through `dist.partition.coarsen_level` /
    `contract_level` (pins/pairs pipelines sharded across the model axis,
    bit-exact with the single-device path at matching `use_kernels` — the
    Pallas hot loops run stripe-locally on the mesh, see `repro.kernels`;
    `dist_coarsen=False` keeps coarsening single-device) and every
    refinement level through
    `dist.partition.refine_level`: repetitions race as replicas across the
    mesh's data axis (`race=False` for the deterministic parity mode) and
    the pins-sized pipelines shard across its model axis. `race_seed`
    decorrelates the replica tie-break permutations. `compensated_psum`
    opts the coarsening eta / matching-sum0 float reductions into the
    Neumaier-compensated psum (O(dense) traffic instead of the stripe-order
    lane gather; within ~1 ulp but not bit-identical to one device).

    shard_graph=True additionally memory-shards the graph *storage*: the
    pins-sized arrays of every level live as per-shard stripes over the
    plan's "model" axis (`dist.graph.ShardedHypergraph`; racing replicas
    share the one striped copy) — bit-identical results, O(pins / shards)
    storage per device. Requires `plan` and `dist_coarsen`; incompatible
    with `bucket` (re-bucketing would re-slice the fixed stripe layout).

    pair_cap / nbr_cap override `Caps.for_host`'s exact pair-expansion /
    neighborhood capacities (e.g. to bound memory). Undersizing them does
    not silently truncate: every level's live counts are audited host-side
    and overflow raises `CapacityError`.

    collect_stats=True additionally populates the quality side of
    `PartitionResult.level_stats` (per-level connectivity/cut of the
    projected partition, block-size and distinct-incident-hyperedge slack
    vs Omega/Delta — `repro.obs.vcycle`): a few extra device reductions per
    level, fetched in one batched readback at the end. Telemetry only reads
    the solve's values, so results are bit-identical either way (tested).
    Phase wall-times are recorded as an `repro.obs.trace` span tree
    ("partition" > setup/coarsen/refine/audit); the ``timings`` dict on the
    result is a thin view over the same spans.
    """
    with otrace.span("partition", nodes=hg.n_nodes, edges=hg.n_edges,
                     pins=hg.n_pins, omega=omega, delta=delta) as sp_total:
        with otrace.span("setup"):
            caps = Caps.for_host(hg, pair_cap=pair_cap, nbr_cap=nbr_cap)
            # exact int64 level-0 audit before any device work: with this
            # passed, pair monotonicity under coarsening bounds every
            # level's count by caps.pairs < 2**31, making the per-level
            # int32 device counts exact
            check_expansion_caps(caps, host_pair_count(hg))
            if shard_graph:
                if plan is None:
                    raise ValueError(
                        "shard_graph=True requires a Plan (mesh) — "
                        "graph stripes live on its 'model' axis")
                if not dist_coarsen:
                    raise ValueError(
                        "shard_graph=True requires dist_coarsen=True: "
                        "the single-device coarsen path cannot read "
                        "memory-sharded storage")
                if bucket:
                    raise ValueError(
                        "bucket=True is incompatible with shard_graph=True: "
                        "capacity re-bucketing would re-slice the fixed "
                        "stripe layout")
                from repro.dist.graph import sharded_from_host
                d = sharded_from_host(hg, caps, plan)
            else:
                d = device_from_host(hg, caps)
        cparams = CoarsenParams(omega=omega, delta=delta, n_cands=n_cands,
                                use_kernels=use_kernels, matching=matching)

        target = max(1, math.ceil(hg.n_nodes / omega))
        log: list = []
        _coarsen, _contract = make_coarsen_fns(cparams, plan, dist_coarsen,
                                               compensated=compensated_psum)
        # run_coarsen_loop: per level one batched scalar sync + overflow
        # audit BEFORE trusting the matches, then blocks the dispatch tail
        # so the phase span doesn't leak into refinement
        with otrace.span("coarsen") as sp_coarsen:
            d, caps, levels, gammas, coarsen_hits, coarsen_meta = \
                run_coarsen_loop(d, caps, target, max_levels, _coarsen,
                                 _contract, log if collect_log else None,
                                 shrink=bucket)
        # the coarsest graph is refined below but never re-entered
        # coarsening, so audit its pair expansion (refinement's in-sequence
        # gains expand the same pairs) — every earlier level was audited in
        # the loop
        check_expansion_caps(caps, device_pair_count(d.edge_off))

        # initial partitioning == coarsest clusters (Sec. III)
        k = int(d.n_nodes)
        if kcap_hint is None:
            kcap = _next_pow2(k)
        else:
            if kcap_hint < k:
                raise ValueError(
                    f"kcap_hint={kcap_hint} is below the coarsest partition "
                    f"count k={k}: partition ids would be silently clipped. "
                    f"Pass kcap_hint >= k (or None for the default pow2).")
            kcap = kcap_hint
        parts = jnp.where(jnp.arange(caps.n) < k,
                          jnp.arange(caps.n, dtype=jnp.int32), 0)

        rparams = refine_params or RefineParams(
            omega=omega, delta=delta, theta=theta, use_kernels=use_kernels,
            chain_rounds=chain_rounds)

        rlog: list | None = [] if collect_log else None
        _refine = make_refine_fn(k, kcap, rparams, rlog, plan, race,
                                 race_seed)

        refine_meta: dict = {len(levels): dict(structure=dict(
            nodes=k, edges=int(d.n_edges), pins=int(d.n_pins)))}

        # refine the coarsest level too, then every uncoarsened level;
        # kernel hits and quality scalars stay device values until the
        # single batched readback below — telemetry adds no per-level syncs
        quality_dev: dict = {}
        refine_hits_dev: dict = {}
        with otrace.span("refine") as sp_refine:
            with otrace.span("refine_level", level=len(levels)):
                parts, refine_hits_dev[len(levels)] = _refine(
                    d, parts, caps, len(levels))
            if collect_stats:
                quality_dev[len(levels)] = ovcycle.quality_scalars(
                    d, parts, caps, kcap, omega, delta)
            for lvl in range(len(levels) - 1, -1, -1):
                g = gammas[lvl]
                d_lvl, caps_lvl = levels[lvl]
                coarse_cap = parts.shape[0]
                with otrace.span("refine_level", level=lvl):
                    parts = jnp.where(
                        jnp.arange(caps_lvl.n) < d_lvl.n_nodes,
                        parts[jnp.clip(g[: caps_lvl.n], 0,
                                       coarse_cap - 1)], 0)
                    parts, refine_hits_dev[lvl] = _refine(d_lvl, parts,
                                                          caps_lvl, lvl)
                if collect_stats:
                    quality_dev[lvl] = ovcycle.quality_scalars(
                        d_lvl, parts, caps_lvl, kcap, omega, delta)
                if collect_log:
                    log.append(dict(kind="refine", level=lvl))
            # block before the span closes: the refine tail would otherwise
            # drain inside np.asarray(parts) below, after the timer stopped
            jax.block_until_ready(parts)
        # ONE batched readback for the kernel hits + quality scalars
        hits_h, quality_h = jax.device_get(
            ([refine_hits_dev[i] for i in range(len(levels) + 1)],
             quality_dev))
        refine_hits = [int(v) for v in hits_h]
        for lvl in range(len(levels) + 1):
            refine_meta.setdefault(lvl, {})
            refine_meta[lvl]["kernel_refine"] = refine_hits[lvl]
            refine_meta[lvl]["quality"] = quality_h.get(lvl)

        with otrace.span("audit"):
            parts_np = np.asarray(parts)[: hg.n_nodes].astype(np.int64)
            # compact partition ids (refinement may empty some partitions)
            uniq, parts_np = np.unique(parts_np, return_inverse=True)
            aud = metrics.audit(hg, parts_np, omega=omega, delta=delta)
    return PartitionResult(
        parts=parts_np, n_parts=len(uniq), n_levels=len(gammas),
        connectivity=aud["connectivity"], cut_net=aud["cut_net"], audit=aud,
        timings=dict(total=sp_total.duration, coarsen=sp_coarsen.duration,
                     refine=sp_refine.duration),
        level_log=(log or []) + (rlog or []),
        kernel_path=dict(coarsen=coarsen_hits, refine=refine_hits),
        level_stats=ovcycle.assemble(coarsen_meta, refine_meta))
