"""Coarse hypergraph construction (paper Sec. V-E).

Clusters from `match` become coarse nodes; coarse h-edges are the images of
fine h-edges under gamma with pins deduplicated; a pin occurring as both src
and dst keeps only its dst role (paper: "duplicates ... are discarded from
src(.)/out(.)"), preserving inbound-set correctness and no-self-cycle.

GPU version: per-set hash-set dedup in shared+global memory, then
prefix-sum packing. TPU adaptation: stable multi-key sort + boundary flags +
prefix-sum compaction — identical result, deterministic, static shapes.
Edge ids and weights are preserved level-over-level (the edge *multiset*
keeps its identity; only pin segments shrink), exactly as in the paper.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.hypergraph import Caps, DeviceHypergraph, NSENT
from repro.utils import segops

IMAX = jnp.int32(2**31 - 1)


@partial(jax.jit, static_argnames=("caps",))
def contract(d: DeviceHypergraph, match: jax.Array, caps: Caps):
    """Returns (coarse DeviceHypergraph, gamma[Ncap] old->coarse id)."""
    ids = jnp.arange(caps.n, dtype=jnp.int32)
    live = ids < d.n_nodes
    m_safe = jnp.clip(match, 0, caps.n - 1)
    paired = live & (match >= 0)
    rep = jnp.where(paired, jnp.minimum(ids, m_safe), ids)
    is_rep = live & (rep == ids)
    newid = (jnp.cumsum(is_rep.astype(jnp.int32)) - 1).astype(jnp.int32)
    gamma = jnp.where(live, newid[rep], -1)
    n_new = jnp.sum(is_rep.astype(jnp.int32))

    size_new = jax.ops.segment_sum(
        jnp.where(live, d.node_size, 0), jnp.where(live, gamma, caps.n),
        num_segments=caps.n + 1)[: caps.n].astype(jnp.int32)

    # ---- coarse edge pins: map through gamma, dedup, src-first repack ----
    t = jnp.arange(caps.p, dtype=jnp.int32)
    pin_live = t < d.n_pins
    e_of = segops.rows_from_offsets(d.edge_off, caps.p, caps.e)
    e_safe = jnp.clip(e_of, 0, caps.e - 1)
    pin = jnp.clip(d.edge_pins, 0, caps.n - 1)
    pprime = jnp.where(pin_live, gamma[pin], IMAX)
    rel = t - d.edge_off[e_safe]
    is_dst = pin_live & (rel >= d.edge_nsrc[e_safe])

    k_e = jnp.where(pin_live, e_of, IMAX)
    k_p = pprime
    k_r = jnp.where(is_dst, 0, 1)  # dst sorts first within (e, p')
    (se, sp, sr), _ = segops.sort_by([k_e, k_p, k_r], [jnp.zeros_like(k_e)])
    starts = segops.segment_starts_from_sorted([se, sp])
    keep = starts & (se != IMAX) & (sp != IMAX)
    kept_dst = sr == 0  # first occurrence carries the merged role

    c_e = jnp.where(keep, se, IMAX)
    c_p = jnp.where(keep, sp, IMAX)
    c_role = jnp.where(keep, jnp.where(kept_dst, 1, 0), 2)  # src=0 < dst=1
    (fe, frole, fp), _ = segops.sort_by([c_e, c_role, c_p],
                                        [jnp.zeros_like(c_e)])
    pins_new = jnp.where(fe != IMAX, fp, NSENT)
    seg_e = jnp.where(fe != IMAX, fe, caps.e)
    counts_e = jax.ops.segment_sum(jnp.ones((caps.p,), jnp.int32), seg_e,
                                   num_segments=caps.e + 1)[: caps.e]
    nsrc_new = jax.ops.segment_sum(
        jnp.where(frole == 0, 1, 0), seg_e, num_segments=caps.e + 1)[: caps.e]
    edge_off_new = segops.offsets_from_counts(counts_e).astype(jnp.int32)
    n_pins_new = edge_off_new[caps.e]

    # ---- incidence rebuild (inbound first) -------------------------------
    t2_live = t < n_pins_new
    e2 = segops.rows_from_offsets(edge_off_new, caps.p, caps.e)
    e2_safe = jnp.clip(e2, 0, caps.e - 1)
    rel2 = t - edge_off_new[e2_safe]
    isdst2 = t2_live & (rel2 >= nsrc_new[e2_safe])
    node2 = jnp.where(t2_live, pins_new, IMAX)
    inkey = jnp.where(isdst2, 0, 1)  # inbound edges first per node
    (sn2, sk2, se2), (sin2,) = segops.sort_by(
        [node2, inkey, jnp.where(t2_live, e2, IMAX)],
        [isdst2.astype(jnp.int32)])
    node_edges_new = jnp.where(sn2 != IMAX, se2, NSENT)
    node_is_in_new = (sin2 == 1) & (sn2 != IMAX)
    segn = jnp.where(sn2 != IMAX, sn2, caps.n)
    counts_n = jax.ops.segment_sum(jnp.ones((caps.p,), jnp.int32), segn,
                                   num_segments=caps.n + 1)[: caps.n]
    nin_new = jax.ops.segment_sum(node_is_in_new.astype(jnp.int32), segn,
                                  num_segments=caps.n + 1)[: caps.n]
    node_off_new = segops.offsets_from_counts(counts_n).astype(jnp.int32)

    d_new = DeviceHypergraph(
        edge_off=edge_off_new,
        edge_pins=pins_new.astype(jnp.int32),
        edge_nsrc=nsrc_new,
        edge_w=d.edge_w,
        node_off=node_off_new,
        node_edges=node_edges_new.astype(jnp.int32),
        node_is_in=node_is_in_new,
        node_nin=nin_new,
        node_size=size_new,
        n_nodes=n_new.astype(jnp.int32),
        n_edges=d.n_edges,
        n_pins=n_pins_new.astype(jnp.int32),
    )
    return d_new, gamma
