"""Coarse hypergraph construction (paper Sec. V-E).

Clusters from `match` become coarse nodes; coarse h-edges are the images of
fine h-edges under gamma with pins deduplicated; a pin occurring as both src
and dst keeps only its dst role (paper: "duplicates ... are discarded from
src(.)/out(.)"), preserving inbound-set correctness and no-self-cycle.

GPU version: per-set hash-set dedup in shared+global memory, then
prefix-sum packing. TPU adaptation: stable multi-key sort + boundary flags +
prefix-sum compaction — identical result, deterministic, static shapes. The
repack into the src-first pin layout is a pair of segmented rank scans over
the sorted order (src rank / dst rank within each edge) plus a scatter to
``edge_off_new[e] + rank`` — the literal prefix-sum packing of the paper,
replacing a second full sort. Edge ids and weights are preserved
level-over-level (the edge *multiset* keeps its identity; only pin segments
shrink), exactly as in the paper.

Sharding (``ctx`` a ``segops.ShardCtx``, inside ``dist.partition``'s
shard_map): key construction runs on per-shard contiguous pin-lane stripes
(CSR row ids via stripe-local binary search), both key sorts run through
the distributed sample sort (``ctx.sort_by``: stripes in, stripes of the
globally sorted order out — only O(shards * samples) splitter keys are
gathered, the payload rides static-shape all_to_all exchanges), dedup /
per-edge boundary flags come from stripe-boundary-aware start flags, the
rank scans run stripe-local with cross-shard carries
(``sharded_segmented_scan``), and the packed pins / per-edge / per-node
counts and the rebuilt incidence arrays combine by psum of disjoint (or
integer) dense partials (``unstripe``). Every value in this pipeline is an
integer, so the sharded contraction is bit-exact with the single-device one
by construction — no float accumulation order to preserve.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.hypergraph import Caps, DeviceHypergraph, NSENT
from repro.utils import segops

IMAX = jnp.int32(2**31 - 1)


def _role_key(is_dst: jax.Array) -> jax.Array:
    """Secondary sort key within an (edge, coarse-pin) duplicate group: dst
    (0) sorts before src (1), so the kept first occurrence carries the dst
    role whenever the merged pin had both (paper V-E: duplicates are
    discarded from src)."""
    return jnp.where(is_dst, 0, 1)


def contract_impl(d: DeviceHypergraph, match: jax.Array, caps: Caps,
                  ctx: segops.ShardCtx = segops.ShardCtx()):
    """Returns (coarse DeviceHypergraph, gamma[Ncap] old->coarse id)."""
    ids = jnp.arange(caps.n, dtype=jnp.int32)
    live = ids < d.n_nodes
    m_safe = jnp.clip(match, 0, caps.n - 1)
    paired = live & (match >= 0)
    rep = jnp.where(paired, jnp.minimum(ids, m_safe), ids)
    is_rep = live & (rep == ids)
    newid = (jnp.cumsum(is_rep.astype(jnp.int32)) - 1).astype(jnp.int32)
    gamma = jnp.where(live, newid[rep], -1)
    n_new = jnp.sum(is_rep.astype(jnp.int32))

    size_new = jax.ops.segment_sum(
        jnp.where(live, d.node_size, 0), jnp.where(live, gamma, caps.n),
        num_segments=caps.n + 1)[: caps.n].astype(jnp.int32)

    # ---- coarse edge pins: map through gamma, dedup, src-first repack ----
    # key construction on this shard's contiguous pin-lane stripe
    t, t_in = ctx.lanes(caps.p)
    pin_live = t_in & (t < d.n_pins)
    e_of = ctx.rows(d.edge_off, t, caps.p, caps.e)
    e_safe = jnp.clip(e_of, 0, caps.e - 1)
    pin = jnp.clip(ctx.gread(d.edge_pins, t, pin_live, 0), 0, caps.n - 1)
    pprime = jnp.where(pin_live, gamma[pin], IMAX)
    rel = t - d.edge_off[e_safe]
    is_dst = pin_live & (rel >= d.edge_nsrc[e_safe])

    k_e = jnp.where(pin_live, e_of, IMAX)
    k_p = pprime
    k_r = _role_key(is_dst)
    # distributed sample sort: stripes in, stripes of the sorted order out
    # (only splitter samples gather); dedup flags are stripe-boundary-aware
    (se_l, sp_l, sr_l), _ = ctx.sort_by([k_e, k_p, k_r], [],
                                        striped_in=True, striped_out=True)
    starts_l = ctx.starts_from_sorted([se_l, sp_l])
    e_start_l = ctx.starts_from_sorted([se_l])
    keep_l = starts_l & (se_l != IMAX) & (sp_l != IMAX)
    kept_dst_l = keep_l & (sr_l == 0)  # first occurrence carries merged role
    kept_src_l = keep_l & (sr_l == 1)

    # per-edge counts from the kept set (integers — psum is exact)
    seg_e = jnp.where(keep_l, se_l, caps.e)
    ones_l = jnp.ones(se_l.shape, jnp.int32)
    counts_e = ctx.psum(jax.ops.segment_sum(
        ones_l, seg_e, num_segments=caps.e + 1))[: caps.e]
    nsrc_new = ctx.psum(jax.ops.segment_sum(
        kept_src_l.astype(jnp.int32), seg_e,
        num_segments=caps.e + 1))[: caps.e]
    edge_off_new = segops.offsets_from_counts(counts_e).astype(jnp.int32)
    n_pins_new = edge_off_new[caps.e]

    # prefix-sum packing: src/dst rank within each edge via stripe-local
    # segmented scans with cross-shard carries, then a disjoint scatter to
    # edge_off_new[e] (+ nsrc for dst) + rank — src pins first, coarse-id
    # ascending within each role (the kept order is already p'-ascending)
    src_rank, _ = ctx.segmented_scan(kept_src_l.astype(jnp.int32), e_start_l)
    dst_rank, _ = ctx.segmented_scan(kept_dst_l.astype(jnp.int32), e_start_l)
    se_safe = jnp.clip(se_l, 0, caps.e - 1)
    pos = jnp.where(kept_src_l, edge_off_new[se_safe] + src_rank - 1,
                    edge_off_new[se_safe] + nsrc_new[se_safe] + dst_rank - 1)
    striped = ctx.graph_striped and ctx.axis is not None
    if striped:
        # memory-sharded storage: reduce-scatter the packed pins so each
        # shard keeps exactly its lane stripe of the coarse graph — the
        # dense pins column never materializes replicated
        st = t.shape[0] * ctx.nshards
        pos = jnp.where(keep_l, pos, st).astype(jnp.int32)
        dense = (jnp.zeros((st + 1,), jnp.int32)
                 .at[pos].add(jnp.where(keep_l, sp_l, 0))[: st])
        pins_new = jnp.where(t < n_pins_new, ctx.psum_stripe(dense), NSENT)
    else:
        pos = jnp.where(keep_l, pos, caps.p).astype(jnp.int32)
        pins_dense = ctx.psum(jnp.zeros((caps.p + 1,), jnp.int32)
                              .at[pos].add(jnp.where(keep_l, sp_l, 0))[: caps.p])
        slot = jnp.arange(caps.p, dtype=jnp.int32)
        pins_new = jnp.where(slot < n_pins_new, pins_dense, NSENT)

    # ---- incidence rebuild (inbound first) -------------------------------
    t2_live = t_in & (t < n_pins_new)
    e2 = ctx.rows(edge_off_new, t, caps.p, caps.e)
    e2_safe = jnp.clip(e2, 0, caps.e - 1)
    rel2 = t - edge_off_new[e2_safe]
    isdst2 = t2_live & (rel2 >= nsrc_new[e2_safe])
    node2 = ctx.gread(pins_new, t, t2_live, IMAX)
    inkey = jnp.where(isdst2, 0, 1)  # inbound edges first
    key_e = jnp.where(t2_live, e2, IMAX)
    (sn2_l, sk2_l, se2_l), (sin2_l,) = ctx.sort_by(
        [node2, inkey, key_e], [isdst2.astype(jnp.int32)],
        striped_in=True, striped_out=True)
    # incidence rebuild from the sorted stripes: with memory-sharded
    # storage the sorted stripes already ARE the new incidence layout, so
    # each shard simply keeps its stripe; otherwise the replicated arrays
    # rebuild by psum of disjoint stripe scatters (`unstripe`) — integer,
    # exact either way
    ne_stripe = jnp.where(sn2_l != IMAX, se2_l, NSENT)
    ni_stripe = (sin2_l == 1) & (sn2_l != IMAX)
    if striped:
        node_edges_new, node_is_in_new = ne_stripe, ni_stripe
    else:
        node_edges_new = ctx.unstripe(ne_stripe)[: caps.p]
        node_is_in_new = ctx.unstripe(ni_stripe)[: caps.p]
    segn = jnp.where(sn2_l != IMAX, sn2_l, caps.n)
    counts_n = ctx.psum(jax.ops.segment_sum(
        jnp.ones(sn2_l.shape, jnp.int32), segn,
        num_segments=caps.n + 1))[: caps.n]
    nin_new = ctx.psum(jax.ops.segment_sum(
        ((sin2_l == 1) & (sn2_l != IMAX)).astype(jnp.int32), segn,
        num_segments=caps.n + 1))[: caps.n]
    node_off_new = segops.offsets_from_counts(counts_n).astype(jnp.int32)

    d_new = DeviceHypergraph(
        edge_off=edge_off_new,
        edge_pins=pins_new.astype(jnp.int32),
        edge_nsrc=nsrc_new,
        edge_w=d.edge_w,
        node_off=node_off_new,
        node_edges=node_edges_new.astype(jnp.int32),
        node_is_in=node_is_in_new,
        node_nin=nin_new,
        node_size=size_new,
        n_nodes=n_new.astype(jnp.int32),
        n_edges=d.n_edges,
        n_pins=n_pins_new.astype(jnp.int32),
    )
    return d_new, gamma


@partial(jax.jit, static_argnames=("caps",))
def contract(d: DeviceHypergraph, match: jax.Array, caps: Caps):
    """Single-device entry point; `dist.partition.contract_level` runs the
    same `contract_impl` under shard_map with a mesh-axis ctx."""
    return contract_impl(d, match, caps)
