"""Uncoarsening refinement (paper Sec. VI): FM-style moves applied
simultaneously via gain-ranked chains + events-based constraint validation.

Pipeline per repetition (Theta total, default 16):

  1. pins(p,e) matrix precomputation (Sec. VI-B, Fig. 2 right)
  2. in-isolation move proposal from Eq. 13 (gain = saving - loss)
  3. moves chained into paths/cycles by a greedy windowed path cover
     (Sec. VI-C, Fig. 5): grade = gain - alpha*|size dif| - beta*|in dif|
  4. in-sequence gain re-derivation (Eq. 14-15) over the pair expansion
  5. sparse events: size + inbound-set deltas, sorted, segment-prefix-summed;
     per-move active-violation count; apply the max-cumulative-gain valid
     prefix (Sec. VI-D, Fig. 6)

CUDA -> TPU mapping: warp-per-node gain loops become segment reductions /
the Pallas `gains` kernel; CUB sort+scan become `lax.sort` (multi-key) +
segmented `associative_scan` — on a mesh, the distributed sample sort
(`ShardCtx.sort_by`) + stripe-local scans with cross-shard carries; atomic
grade claims become segment-argmax with id tie-breaks.

Every pins/pairs-sized stage threads an optional `segops.ShardCtx`: with a
mesh axis set (inside `dist.partition`'s shard_map) the stage processes one
contiguous lane stripe per device and combines dense segment outputs with
psum; with the default ctx it is the exact single-device computation. Chain
construction additionally takes a `tie_rank` permutation so racing replicas
explore distinct (equally greedy) move orderings. The first half of the Theta repetitions may propose
size-violating moves, the second half enforces size feasibility in the
proposal — final validity is always enforced by the events check, with
violations permitted *inside* the sequence (only the cut point must be
globally valid), exactly as in the paper.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.hypergraph import Caps, DeviceHypergraph, build_pairs
from repro.utils import segops

IMAX = jnp.int32(2**31 - 1)
NEG = jnp.float32(-jnp.inf)


@dataclasses.dataclass(frozen=True)
class RefineParams:
    omega: int
    delta: int
    theta: int = 16           # repetitions per level
    window: int = 256         # successor window (paper: 256)
    chain_rounds: int = 16    # chaining rounds (paper: up to 16)
    alpha: float = 1e-6       # size-difference grade weight
    beta: float = 1e-7        # inbound-size-difference grade weight
    include_zero_gain: bool = True  # allow 0-gain proposals (enables swaps)
    use_kernels: bool = False


# ---------------------------------------------------------------------------
# 1. pins matrix
# ---------------------------------------------------------------------------
def pins_matrix(d: DeviceHypergraph, parts: jax.Array, caps: Caps, kcap: int,
                ctx: segops.ShardCtx = segops.ShardCtx()):
    """pins[p,e] (all pins) and pins_in[p,e] (dst pins only), [kcap, Ecap].

    Sharded mode (``ctx.axis`` set, inside shard_map): each device counts
    only its contiguous stripe of pin lanes and the dense [kcap, Ecap]
    matrices are psum-combined — the all-gather-free segment reduction."""
    t, in_rng = ctx.lanes(caps.p)
    live = in_rng & (t < d.n_pins)
    e_of = ctx.rows(d.edge_off, t, caps.p, caps.e)
    e_safe = jnp.clip(e_of, 0, caps.e - 1)
    pin = jnp.clip(ctx.gread(d.edge_pins, t, live, 0), 0, caps.n - 1)
    p_of = jnp.where(live, parts[pin], kcap)
    rel = t - d.edge_off[e_safe]
    is_dst = live & (rel >= d.edge_nsrc[e_safe])
    flat = jnp.where(live, p_of * caps.e + e_safe, kcap * caps.e)
    ones = jnp.ones(t.shape, jnp.int32)
    pins = jax.ops.segment_sum(ones, flat, num_segments=kcap * caps.e + 1)
    pins = ctx.psum(pins[:-1]).reshape(kcap, caps.e)
    pins_in = jax.ops.segment_sum(is_dst.astype(jnp.int32), flat,
                                  num_segments=kcap * caps.e + 1)
    pins_in = ctx.psum(pins_in[:-1]).reshape(kcap, caps.e)
    return pins, pins_in


def partition_sizes(d: DeviceHypergraph, parts: jax.Array, caps: Caps, kcap: int):
    ids = jnp.arange(caps.n, dtype=jnp.int32)
    live = ids < d.n_nodes
    return jax.ops.segment_sum(jnp.where(live, d.node_size, 0),
                               jnp.where(live, parts, kcap),
                               num_segments=kcap + 1)[:kcap]


# ---------------------------------------------------------------------------
# 2. move proposal (Eq. 13)
# ---------------------------------------------------------------------------
def propose_moves(d: DeviceHypergraph, parts: jax.Array, pins: jax.Array,
                  caps: Caps, kcap: int, params: RefineParams,
                  enforce_size: jax.Array, n_parts: jax.Array,
                  ctx: segops.ShardCtx = segops.ShardCtx()):
    """Returns (move_to[Ncap] or -1, gain_iso[Ncap], saving[Ncap],
    kernel_taken) — the trailing scalar is 1 iff the conn_w dispatch took
    the Pallas `gains` branch (0 on the segment path)."""
    t, in_rng = ctx.lanes(caps.p)
    live = in_rng & (t < d.n_pins)
    n_of = ctx.rows(d.node_off, t, caps.p, caps.n)
    n_safe = jnp.clip(n_of, 0, caps.n - 1)
    e = jnp.clip(ctx.gread(d.node_edges, t, live, 0), 0, caps.e - 1)
    w = jnp.where(live, d.edge_w[e], 0.0)
    p_n = parts[n_safe]

    pins_own = pins[p_n, e]
    saving = ctx.psum(jax.ops.segment_sum(
        jnp.where(live & (pins_own == 1), w, 0.0),
        jnp.where(live, n_of, caps.n), num_segments=caps.n + 1)[: caps.n])
    w_tot = ctx.psum(jax.ops.segment_sum(
        w, jnp.where(live, n_of, caps.n),
        num_segments=caps.n + 1)[: caps.n])

    def _conn_segments():
        # conn_w[n, p] = sum_{e in I(n)} w(e) * [pins(p,e) > 0]
        contrib = jnp.where(live, w, 0.0)[:, None] * (pins[:, e].T > 0)
        return ctx.psum(jax.ops.segment_sum(
            contrib, jnp.where(live, n_of, caps.n),
            num_segments=caps.n + 1)[: caps.n])

    if params.use_kernels:
        from repro.kernels.gains import ops as g_ops
        # replicated mesh-independent predicate: every shard (and the
        # single-device run) takes the same branch — see repro.kernels
        fits = g_ops.fits_kernel(d, caps)
        conn_w = jax.lax.cond(
            fits,
            lambda: g_ops.conn_weights(d, parts, pins, caps, kcap, ctx),
            _conn_segments)
        kernel_taken = fits.astype(jnp.int32)
    else:
        conn_w = _conn_segments()
        kernel_taken = jnp.int32(0)

    ids = jnp.arange(caps.n, dtype=jnp.int32)
    node_live = ids < d.n_nodes
    # gain(n,p) = saving - (w_tot - conn_w) ; exclude own partition
    gain_all = saving[:, None] - w_tot[:, None] + conn_w
    col = jnp.arange(kcap, dtype=jnp.int32)[None, :]
    mask = (col != parts[:, None]) & (col < n_parts)
    psz = partition_sizes(d, parts, caps, kcap)
    fits = psz[None, :] + d.node_size[:, None] <= params.omega
    mask = mask & jnp.where(enforce_size, fits, True)
    gain_all = jnp.where(mask, gain_all, NEG)

    # paper tie-break: max_id argmax over partitions
    mx = jnp.max(gain_all, axis=1)
    best_p = jnp.max(jnp.where(gain_all == mx[:, None], col, -1), axis=1)
    best_g = mx
    ok = node_live & (best_p >= 0) & ~jnp.isneginf(best_g)
    ok = ok & ((best_g >= 0.0) if params.include_zero_gain else (best_g > 0.0))
    move_to = jnp.where(ok, best_p.astype(jnp.int32), -1)
    return move_to, jnp.where(ok, best_g, 0.0), saving, kernel_taken


# ---------------------------------------------------------------------------
# 3. chain construction (Sec. VI-C)
# ---------------------------------------------------------------------------
def build_sequence(d: DeviceHypergraph, parts: jax.Array, move_to: jax.Array,
                   gain: jax.Array, caps: Caps, kcap: int,
                   params: RefineParams, tie_rank: jax.Array | None = None,
                   with_aux: bool = False,
                   ctx: segops.ShardCtx = segops.ShardCtx()):
    """Orders moves into gain-ranked chains; returns seq[Ncap] (IMAX for
    non-movers) and n_movers.

    ``tie_rank`` (a permutation of node ids, default identity) replaces the
    node id wherever it only breaks ties — the sort keys, the successor-claim
    argmax, and the cycle-cut anchor. Distinct permutations give the
    replica-racing mode of ``dist.partition`` distinct (equally greedy)
    chains per device; the identity reproduces the single-device sequence
    bit-for-bit. ``with_aux`` additionally returns the pred/head arrays for
    the oracle/property tests. The mover and chain-head orderings run
    through ``ctx.sort_by`` (replicated in/out — the windowed candidate
    lookup needs the whole sorted order), so on a mesh the sort work
    distributes while the result stays replicated and bit-identical."""
    ids = jnp.arange(caps.n, dtype=jnp.int32)
    rank = ids if tie_rank is None else tie_rank
    mover = move_to >= 0
    ps = jnp.where(mover, parts, kcap)
    pd = jnp.where(mover, move_to, kcap)

    # sort movers by (ps, -gain, rank): per-source-partition gain-descending
    gkey = jnp.where(mover, -gain, jnp.float32(jnp.inf))
    (_, _, _), (order,) = ctx.sort_by([ps, gkey, rank], [ids])
    # segment start offset per partition
    cnt_p = jax.ops.segment_sum(jnp.ones((caps.n,), jnp.int32), ps,
                                num_segments=kcap + 1)[:kcap]
    seg_off = segops.offsets_from_counts(cnt_p)[:-1]  # [kcap]

    pred = jnp.full((caps.n,), -1, jnp.int32)
    has_succ = jnp.zeros((caps.n,), bool)
    W = params.window

    for _ in range(params.chain_rounds):
        free = mover & ~has_succ
        # windowed candidates in the pd-segment of the sorted move list
        base = seg_off[jnp.clip(pd, 0, kcap - 1)]
        end = base + cnt_p[jnp.clip(pd, 0, kcap - 1)]
        cand_pos = base[:, None] + jnp.arange(W, dtype=jnp.int32)[None, :]
        in_seg = cand_pos < end[:, None]
        cand = order[jnp.clip(cand_pos, 0, caps.n - 1)]          # [Ncap, W]
        c_ok = (in_seg & free[:, None] & mover[cand] & (pred[cand] < 0)
                & (cand != ids[:, None]))
        grade = (gain[cand]
                 - params.alpha * jnp.abs(d.node_size[:, None]
                                          - d.node_size[cand]).astype(jnp.float32)
                 - params.beta * jnp.abs(d.node_nin[:, None]
                                         - d.node_nin[cand]).astype(jnp.float32))
        grade = jnp.where(c_ok, grade, NEG)
        gmax = jnp.max(grade, axis=1)
        pick = jnp.max(jnp.where(grade == gmax[:, None], cand, -1), axis=1)
        want = free & (pick >= 0) & ~jnp.isneginf(gmax)
        # conflicts: parallel max on (grade, proposer rank) per successor
        # (paper's atomic lexicographic max; rank==id unless racing)
        succ_seg = jnp.where(want, pick, caps.n)
        _, winner = segops.segment_argmax(gmax, rank, succ_seg, caps.n + 1,
                                          valid=want)
        winner = winner[: caps.n]
        got = want & (winner[jnp.clip(pick, 0, caps.n - 1)] == rank)
        pred = pred.at[jnp.where(got, pick, caps.n)].set(ids, mode="drop")
        has_succ = has_succ | got

    # --- resolve chains: cut cycles at their min-rank node -----------------
    K = max(1, math.ceil(math.log2(caps.n + 1)) + 1)
    ptr = pred
    minacc = jnp.where(ptr >= 0,
                       jnp.minimum(rank, rank[jnp.clip(ptr, 0, caps.n - 1)]),
                       rank)
    for _ in range(K):
        p_safe = jnp.clip(ptr, 0, caps.n - 1)
        minacc = jnp.where(ptr >= 0, jnp.minimum(minacc, minacc[p_safe]), minacc)
        ptr = jnp.where(ptr >= 0, ptr[p_safe], -1)
    on_cycle = ptr >= 0  # pred-chain never terminated
    cyc_head = on_cycle & (minacc == rank)
    pred = jnp.where(cyc_head, -1, pred)

    # --- position within chain + chain head via pointer doubling ----------
    ptr = pred
    dist = jnp.where(ptr >= 0, 1, 0).astype(jnp.int32)
    head = jnp.where(ptr >= 0, ptr, ids)
    for _ in range(K):
        p_safe = jnp.clip(ptr, 0, caps.n - 1)
        dist = jnp.where(ptr >= 0, dist + dist[p_safe], dist)
        head = jnp.where(ptr >= 0, head[p_safe], head)
        ptr = jnp.where(ptr >= 0, ptr[p_safe], -1)

    # --- rank chains by total gain (desc), concatenate ---------------------
    seg_head = jnp.where(mover, head, caps.n)
    chain_gain = jax.ops.segment_sum(jnp.where(mover, gain, 0.0), seg_head,
                                     num_segments=caps.n + 1)[: caps.n]
    chain_len = jax.ops.segment_sum(jnp.ones((caps.n,), jnp.int32), seg_head,
                                    num_segments=caps.n + 1)[: caps.n]
    is_head = mover & (head == ids)
    hkey = jnp.where(is_head, -chain_gain, jnp.float32(jnp.inf))
    (_, _), (horder,) = ctx.sort_by([hkey, rank], [ids])
    # chain start offsets in ranked order
    rlen = jnp.where(is_head[horder], chain_len[horder], 0)
    roff = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                            jnp.cumsum(rlen)[:-1].astype(jnp.int32)])
    chain_start = jnp.zeros((caps.n,), jnp.int32).at[horder].set(roff)
    seq = jnp.where(mover, chain_start[jnp.clip(head, 0, caps.n - 1)] + dist,
                    IMAX)
    n_movers = jnp.sum(mover.astype(jnp.int32))
    if with_aux:
        return seq, n_movers, dict(pred=pred, head=head, dist=dist,
                                   cyc_head=cyc_head)
    return seq, n_movers


# ---------------------------------------------------------------------------
# 4. in-sequence gains (Eq. 14 / 15)
# ---------------------------------------------------------------------------
def inseq_gains(d: DeviceHypergraph, parts: jax.Array, pins: jax.Array,
                move_to: jax.Array, gain_iso: jax.Array, seq: jax.Array,
                caps: Caps, kcap: int,
                ctx: segops.ShardCtx = segops.ShardCtx()):
    pidx, p_ok = ctx.lanes(caps.pairs)
    pairs = build_pairs(d, caps, idx=pidx, idx_ok=p_ok, ctx=ctx)
    n = jnp.clip(pairs.n, 0, caps.n - 1)
    m = jnp.clip(pairs.m, 0, caps.n - 1)
    e = jnp.clip(pairs.edge, 0, caps.e - 1)
    mover_n = pairs.valid & (move_to[n] >= 0)
    mover_m = pairs.valid & (move_to[m] >= 0)
    before = mover_n & mover_m & (seq[m] < seq[n])

    ps_n, pd_n = parts[n], jnp.clip(move_to[n], 0, kcap - 1)
    ps_m, pd_m = parts[m], jnp.clip(move_to[m], 0, kcap - 1)

    # per-(n,e) counts, keyed by incidence slot. The count vectors are only
    # ever read at this shard's own slot lanes, so combine the pair-shard
    # partials with a reduce-scatter over the lane stripes (1/nshards the
    # payload of a full psum). Lane stripes are ceil-divided, so the dense
    # vector is padded to nshards * lanes-per-shard; the sentinel bucket
    # sits past that.
    t, t_ok = ctx.lanes(caps.p)
    stripe_total = t.shape[0] * ctx.nshards
    seg = jnp.where(mover_n, pairs.slot_n, stripe_total)

    def cnt(cond):
        return ctx.psum_stripe(jax.ops.segment_sum(
            jnp.where(before & cond, 1, 0), seg,
            num_segments=stripe_total + 1)[: stripe_total])

    a_pd = cnt(pd_n == ps_m)          # m leaving n's destination
    b_pd = cnt(pd_n == pd_m)          # m also entering it
    a_ps = cnt(ps_n == ps_m)          # m also leaving n's source
    b_ps = cnt(ps_n == pd_m)          # m entering it

    # per-(n, e) evaluation at each live incidence slot (slot lanes sharded)
    slot_live = t_ok & (t < d.n_pins)
    # slot_n indexes edge_pins: node at that slot, edge via rows
    e_slot = ctx.rows(d.edge_off, t, caps.p, caps.e)
    e_slot = jnp.clip(e_slot, 0, caps.e - 1)
    n_slot = jnp.clip(ctx.gread(d.edge_pins, t, slot_live, 0), 0, caps.n - 1)
    is_mover = slot_live & (move_to[n_slot] >= 0)
    psn = parts[n_slot]
    pdn = jnp.clip(move_to[n_slot], 0, kcap - 1)
    w = d.edge_w[e_slot]
    pins_pd = pins[pdn, e_slot]
    pins_ps = pins[psn, e_slot]

    # Exact in-sequence correction. Paper Eq. 14/15 express the four
    # transition cases as two OR-ed conditions adjusting by +-w once; when
    # both clauses of one equation hold simultaneously (e.g. the move both
    # loses its isolation saving AND creates a new cut on the same h-edge)
    # the OR under-counts by w. We use the equivalent exact before/after
    # form, which reduces to Eq. 14/15 whenever a single clause fires
    # (verified against both a literal Eq. 14/15 oracle and brute-force
    # connectivity deltas in tests/test_refine.py).
    saving_iso = pins_ps == 1
    saving_now = (pins_ps - a_ps + b_ps) == 1
    loss_iso = pins_pd == 0
    loss_now = (pins_pd - a_pd + b_pd) == 0
    adj = jnp.where(
        is_mover,
        w * ((saving_now.astype(jnp.float32) - saving_iso.astype(jnp.float32))
             - (loss_now.astype(jnp.float32) - loss_iso.astype(jnp.float32))),
        0.0)
    adj_n = ctx.psum(jax.ops.segment_sum(
        adj, jnp.where(slot_live, n_slot, caps.n),
        num_segments=caps.n + 1)[: caps.n])
    return gain_iso + adj_n


# ---------------------------------------------------------------------------
# 5. events-based constraint checks (Sec. VI-D, Fig. 6)
# ---------------------------------------------------------------------------
def events_validity(d: DeviceHypergraph, parts: jax.Array,
                    pins_in: jax.Array, move_to: jax.Array, seq: jax.Array,
                    gain_seq: jax.Array, caps: Caps, kcap: int,
                    params: RefineParams,
                    ctx: segops.ShardCtx = segops.ShardCtx()):
    """Returns (apply_mask[Ncap], applied_gain) — the max-cumulative-gain
    prefix of the move sequence whose end state satisfies both constraints
    for every partition (violations *inside* the prefix are permitted).

    All running counts scan in int32 (``segops.segmented_scan`` is
    dtype-preserving): the previous float32 cast was exact only while
    running sizes / distinct counts stayed below 2**24.

    Sharded mode (``ctx.axis`` set): the pins-sized inbound-event pipeline
    is fully distributed — event construction, both event *sorts*
    (``ShardCtx.sort_by``: the sample sort of ``repro.dist.sort``, stripes
    in / stripes of the sorted order out, only splitter samples gathered)
    and the segmented scans all run on each device's contiguous lane stripe
    (cross-shard scan carries via ``ShardCtx.segmented_scan``, sorted-key
    segment starts and group closings via the scalar boundary exchanges
    ``starts_from_sorted`` / ``edge_prev`` / ``edge_next``), and the
    per-seq violation deltas are psum-combined dense vectors. The
    node-sized size-event pipeline stays replicated — it is O(N), dominated
    by the O(pins) inbound pipeline."""
    mover = move_to >= 0
    ps = jnp.where(mover, parts, kcap)
    pd = jnp.where(mover, move_to, kcap)

    init_size = partition_sizes(d, parts, caps, kcap)
    init_distinct = jnp.sum(pins_in > 0, axis=1).astype(jnp.int32)  # [kcap]

    # ---- size events: (p, seq, +-size(n)) --------------------------------
    ev_p = jnp.concatenate([ps, pd])
    ev_s = jnp.concatenate([seq, seq])
    ev_d = jnp.concatenate([-d.node_size, d.node_size])
    msk = jnp.concatenate([mover, mover])
    ev_p = jnp.where(msk, ev_p, kcap)
    ev_s = jnp.where(msk, ev_s, IMAX)
    ev_d = jnp.where(msk, ev_d, 0)
    (sp, ss), (sd,) = segops.sort_by([ev_p, ev_s], [ev_d])
    starts = segops.segment_starts_from_sorted([sp])
    cum = segops.segmented_scan(sd, starts)
    size_after = init_size[jnp.clip(sp, 0, kcap - 1)] + cum
    inv = (sp < kcap) & (size_after > params.omega)
    prev_inv = jnp.where(
        starts, init_size[jnp.clip(sp, 0, kcap - 1)] > params.omega,
        jnp.concatenate([jnp.zeros((1,), bool), inv[:-1]]))
    size_vdelta = inv.astype(jnp.int32) - prev_inv.astype(jnp.int32)
    size_vseq = jnp.where(sp < kcap, ss, IMAX)

    # ---- inbound events: (p, e, seq, +-1) over e in in(n) of movers ------
    # construction on this shard's pin-lane stripe
    t, t_ok = ctx.lanes(caps.p)
    slot_live = t_ok & (t < d.n_pins)
    n_of = ctx.rows(d.node_off, t, caps.p, caps.n)
    n_safe = jnp.clip(n_of, 0, caps.n - 1)
    e_in = jnp.clip(ctx.gread(d.node_edges, t, slot_live, 0), 0, caps.e - 1)
    is_ev = (ctx.gread(d.node_is_in, t, slot_live, False)
             & slot_live & mover[n_safe])
    ie_p = jnp.concatenate([jnp.where(is_ev, ps[n_safe], kcap),
                            jnp.where(is_ev, pd[n_safe], kcap)])
    ie_e = jnp.concatenate([jnp.where(is_ev, e_in, caps.e)] * 2)
    ie_s = jnp.concatenate([jnp.where(is_ev, seq[n_safe], IMAX)] * 2)
    ie_d = jnp.concatenate([jnp.where(is_ev, -1, 0),
                            jnp.where(is_ev, 1, 0)]).astype(jnp.int32)
    # global (p, e, seq) order via the distributed sample sort: each shard
    # passes its event-lane stripe and receives its contiguous stripe of
    # the sorted order — only splitter samples are ever gathered
    # (``dist.sort``; bit-identical to the old gather-sort-stripe). Live
    # event keys are unique (seq is a permutation, pins are unique per
    # edge), so the sorted order is independent of shard interleaving.
    (ip, ie, isq), (idv,) = ctx.sort_by([ie_p, ie_e, ie_s], [ie_d],
                                        striped_in=True, striped_out=True)
    pe_start_s = ctx.starts_from_sorted([ip, ie])
    base = pins_in[jnp.clip(ip, 0, kcap - 1), jnp.clip(ie, 0, caps.e - 1)]
    cum_pe, carry_pe = ctx.segmented_scan(idv, pe_start_s)
    run = base + cum_pe
    # `run` at the element just before this stripe: its (p, e) key rides in
    # on a scalar boundary exchange, its scan value is the incoming carry
    prev_p = ctx.edge_prev(ip, ip[0])[0]
    prev_e = ctx.edge_prev(ie, ie[0])[0]
    prev_base = pins_in[jnp.clip(prev_p, 0, kcap - 1),
                        jnp.clip(prev_e, 0, caps.e - 1)]
    run_prev = jnp.concatenate([(prev_base + carry_pe)[None], run[:-1]])
    prev_run = jnp.where(pe_start_s, base, run_prev)
    live_ev = (ip < kcap) & (ie < caps.e)
    up = live_ev & (prev_run == 0) & (run > 0)     # 0 -> 1 : new distinct edge
    dn = live_ev & (prev_run > 0) & (run == 0)     # 1 -> 0 : edge left p
    dd = up.astype(jnp.int32) - dn.astype(jnp.int32)
    # distinct-count running value per (p, seq): same striped sample sort
    # over the transition deltas
    (dp2, ds2), (dd2,) = ctx.sort_by(
        [jnp.where(dd != 0, ip, kcap), jnp.where(dd != 0, isq, IMAX)], [dd],
        striped_in=True, striped_out=True)
    p_start2_s = ctx.starts_from_sorted([dp2])
    # per-(p,seq) group: state observable at the last event of the group;
    # the stripe's last element peeks at the next shard's first key (-1
    # fill: past the globally last element every group is closed)
    grp_last = ((ctx.edge_next(dp2, -1) != dp2)
                | (ctx.edge_next(ds2, -1) != ds2))
    cum2, _ = ctx.segmented_scan(dd2, p_start2_s)
    distinct_after = init_distinct[jnp.clip(dp2, 0, kcap - 1)] + cum2
    inv_i = (dp2 < kcap) & (distinct_after > params.delta)
    # forward-fill last group state within p-segment (value+1; 0 = none yet)
    state_here = jnp.where(grp_last, inv_i.astype(jnp.int32), -1)
    filled, carry_fill = ctx.segmented_scan(
        jnp.where(state_here >= 0, state_here + 1, 0),
        p_start2_s | (state_here >= 0))
    # filled at position of a group-last = its own state+1; previous group
    # state for this stripe's first element rides in on the scan carry
    prev_state = jnp.concatenate([carry_fill[None], filled[:-1]]) - 1
    nglast, _ = ctx.segmented_scan(grp_last.astype(jnp.int32), p_start2_s)
    seg_first_group = nglast <= 1
    init_inv_i = init_distinct[jnp.clip(dp2, 0, kcap - 1)] > params.delta
    prev_state = jnp.where(p_start2_s | (prev_state < 0) | seg_first_group,
                           init_inv_i.astype(jnp.int32), prev_state)
    inb_vdelta = jnp.where(grp_last & (dp2 < kcap),
                           inv_i.astype(jnp.int32) - prev_state, 0)
    inb_vseq = jnp.where(grp_last & (dp2 < kcap), ds2, IMAX)

    # ---- merge violation deltas; active count per sequence position ------
    nm_cap = caps.n  # seq positions < caps.n
    vd_size = jax.ops.segment_sum(
        size_vdelta, jnp.clip(jnp.where(size_vseq == IMAX, nm_cap, size_vseq),
                              0, nm_cap), num_segments=nm_cap + 1)[:nm_cap]
    vd_inb = ctx.psum(jax.ops.segment_sum(
        inb_vdelta, jnp.clip(jnp.where(inb_vseq == IMAX, nm_cap, inb_vseq),
                             0, nm_cap), num_segments=nm_cap + 1)[:nm_cap])
    v0 = (jnp.sum((init_size[:kcap] > params.omega).astype(jnp.int32))
          + jnp.sum((init_distinct[:kcap] > params.delta).astype(jnp.int32)))
    active = v0 + jnp.cumsum(vd_size + vd_inb)

    # ---- cumulative in-sequence gain; choose best valid prefix -----------
    n_movers = jnp.sum(mover.astype(jnp.int32))
    gain_by_seq = jnp.zeros((nm_cap,), jnp.float32).at[
        jnp.where(mover, seq, nm_cap)].add(
        jnp.where(mover, gain_seq, 0.0), mode="drop")
    cumgain = jnp.cumsum(gain_by_seq)
    pos = jnp.arange(nm_cap, dtype=jnp.int32)
    cand = (pos < n_movers) & (active == 0)
    val = jnp.where(cand, cumgain, NEG)
    t_star = jnp.argmax(val).astype(jnp.int32)
    ok = val[t_star] > 0.0
    apply_mask = mover & ok & (seq <= t_star)
    return apply_mask, jnp.where(ok, val[t_star], 0.0)


# ---------------------------------------------------------------------------
# 6. one refinement repetition + level driver
# ---------------------------------------------------------------------------
def refine_step_impl(d: DeviceHypergraph, parts: jax.Array,
                     n_parts: jax.Array, caps: Caps, kcap: int,
                     params: RefineParams, enforce_size: jax.Array,
                     ctx: segops.ShardCtx = segops.ShardCtx(),
                     tie_rank: jax.Array | None = None):
    """One full repetition (pins -> proposal -> chains -> in-seq gains ->
    events). Single source of truth for both the jitted single-device
    ``refine_step`` and ``dist.partition``'s shard_map'd racing step
    (``ctx`` shards the pins/pairs pipelines, ``tie_rank`` diversifies
    replicas)."""
    if params.use_kernels:
        from repro.kernels.pins_count import ops as pc_ops
        # replicated mesh-independent predicate (branch parity, see
        # repro.kernels): the pins kernel runs stripe-locally per shard
        fits = pc_ops.fits_kernel(d, caps)
        pins, pins_in = jax.lax.cond(
            fits,
            lambda: pc_ops.pins_matrix_kernel(d, parts, caps, kcap, ctx),
            lambda: pins_matrix(d, parts, caps, kcap, ctx))
        pins_taken = fits.astype(jnp.int32)
    else:
        pins, pins_in = pins_matrix(d, parts, caps, kcap, ctx)
        pins_taken = jnp.int32(0)
    move_to, gain_iso, _, kernel_taken = propose_moves(
        d, parts, pins, caps, kcap, params, enforce_size, n_parts, ctx)
    seq, _ = build_sequence(d, parts, move_to, gain_iso, caps, kcap, params,
                            tie_rank=tie_rank, ctx=ctx)
    gain_seq = inseq_gains(d, parts, pins, move_to, gain_iso, seq, caps,
                           kcap, ctx)
    apply_mask, applied_gain = events_validity(
        d, parts, pins_in, move_to, seq, gain_seq, caps, kcap, params, ctx)
    parts_new = jnp.where(apply_mask, jnp.where(move_to >= 0, move_to, parts),
                          parts)
    return (parts_new, applied_gain,
            jnp.sum(apply_mask.astype(jnp.int32)), kernel_taken, pins_taken)


@partial(jax.jit, static_argnames=("caps", "kcap", "params", "enforce_size"))
def refine_step(d: DeviceHypergraph, parts: jax.Array, n_parts: jax.Array,
                caps: Caps, kcap: int, params: RefineParams,
                enforce_size: bool):
    return refine_step_impl(d, parts, n_parts, caps, kcap, params,
                            jnp.asarray(enforce_size))


def refine_level(d: DeviceHypergraph, parts: jax.Array, n_parts,
                 caps: Caps, kcap: int, params: RefineParams,
                 log: list | None = None):
    """Theta repetitions; first half may propose size-violating moves.
    Returns (parts, (kernel_hits, pins_hits)) — device-scalar counts of
    repetitions whose gains / pins dispatch took the Pallas branch
    (each 0..theta)."""
    n_parts = jnp.asarray(n_parts, jnp.int32)
    hits = jnp.int32(0)
    phits = jnp.int32(0)
    for rep in range(params.theta):
        enforce = rep >= params.theta // 2
        parts, g, nmv, kt, pt = refine_step(d, parts, n_parts, caps, kcap,
                                            params, enforce)
        hits = hits + kt
        phits = phits + pt
        if log is not None:
            log.append(dict(rep=rep, gain=float(g), applied=int(nmv),
                            kernel=int(kt)))
    return parts, (hits, phits)
