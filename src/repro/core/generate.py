"""Synthetic hypergraph generators.

The paper evaluates on (a) 12 SNN hypergraphs from [25] (Zenodo, not
available offline) spanning regular "-model" topologies and small-world
"-rand" ones, and (b) the ISPD98 netlists augmented 16x. We generate
structurally matched synthetic analogues:

* ``snn_layered``    — "-model"-like: layered feed-forward net, one outbound
  h-edge (axon) per neuron whose destinations are a local window in the next
  layer; regular, high locality, cardinality ~ fanout.
* ``snn_smallworld`` — "-rand"-like: ring locality + random rewiring, large
  erratic neighborhoods.
* ``ispd_like``      — netlist-like: small cardinality (avg 3.4—4.5),
  driver + sinks, id-window locality (placement order locality).
* ``random_kuniform``— uniform random k-edges (property tests).

All generators are deterministic in ``seed`` and return HostHypergraph with
sources-first pin layout, unique pins, and src/dst disjoint per edge.
"""
from __future__ import annotations

import numpy as np

from repro.core.hypergraph import GraphDelta, HostHypergraph


def _finalize(n_nodes, pin_lists, nsrc, weights) -> HostHypergraph:
    off = np.zeros(len(pin_lists) + 1, np.int64)
    off[1:] = np.cumsum([len(p) for p in pin_lists])
    pins = np.concatenate(pin_lists) if pin_lists else np.zeros(0, np.int32)
    hg = HostHypergraph(
        n_nodes=n_nodes, edge_off=off, edge_pins=pins.astype(np.int32),
        edge_nsrc=np.asarray(nsrc, np.int32), edge_w=np.asarray(weights, np.float32))
    return hg


def snn_layered(n_layers: int = 6, width: int = 256, fanout: int = 12,
                window: int = 24, seed: int = 0,
                weight_mode: str = "spikes") -> HostHypergraph:
    """Feed-forward SNN: neuron (l, i) drives a window in layer l+1."""
    rng = np.random.default_rng(seed)
    n_nodes = n_layers * width
    pin_lists, nsrc, weights = [], [], []
    for l in range(n_layers - 1):
        for i in range(width):
            src = l * width + i
            center = i
            lo = max(0, center - window // 2)
            hi = min(width, lo + window)
            cand = np.arange(lo, hi) + (l + 1) * width
            k = min(fanout, len(cand))
            dst = rng.choice(cand, size=k, replace=False).astype(np.int32)
            pin_lists.append(np.concatenate([[src], np.sort(dst)]).astype(np.int32))
            nsrc.append(1)
            w = rng.poisson(8.0) + 1.0 if weight_mode == "spikes" else 1.0
            weights.append(w)
    return _finalize(n_nodes, pin_lists, nsrc, weights)


def snn_smallworld(n_nodes: int = 1024, fanout: int = 16, rewire: float = 0.35,
                   seed: int = 0, weight_mode: str = "spikes") -> HostHypergraph:
    """Ring-local axons with random long-range rewiring (small-world)."""
    rng = np.random.default_rng(seed)
    pin_lists, nsrc, weights = [], [], []
    for src in range(n_nodes):
        local = (src + 1 + np.arange(fanout * 2)) % n_nodes
        k = fanout
        n_far = rng.binomial(k, rewire)
        far = rng.integers(0, n_nodes, size=n_far)
        near = rng.choice(local, size=k - n_far, replace=False)
        dst = np.unique(np.concatenate([near, far]).astype(np.int32))
        dst = dst[dst != src]
        if len(dst) == 0:
            dst = np.array([(src + 1) % n_nodes], np.int32)
        pin_lists.append(np.concatenate([[src], dst]).astype(np.int32))
        nsrc.append(1)
        w = rng.poisson(8.0) + 1.0 if weight_mode == "spikes" else 1.0
        weights.append(w)
    return _finalize(n_nodes, pin_lists, nsrc, weights)


def ispd_like(n_nodes: int = 4096, n_edges: int | None = None,
              avg_card: float = 3.8, locality: int = 64,
              seed: int = 0) -> HostHypergraph:
    """Netlist-like: cardinality 2 + geometric, driver + local sinks."""
    rng = np.random.default_rng(seed)
    if n_edges is None:
        n_edges = int(n_nodes * 1.25)
    pin_lists, nsrc, weights = [], [], []
    p_geom = 1.0 / max(avg_card - 2.0, 0.25)
    for _ in range(n_edges):
        card = 2 + rng.geometric(min(p_geom, 1.0)) - 1
        card = int(min(card, 24))
        driver = int(rng.integers(0, n_nodes))
        lo = max(0, driver - locality)
        hi = min(n_nodes, driver + locality)
        sinks = rng.integers(lo, hi, size=card * 2)
        sinks = np.unique(sinks[sinks != driver])[: card - 1]
        if len(sinks) == 0:
            sinks = np.array([(driver + 1) % n_nodes])
        pin_lists.append(np.concatenate([[driver], sinks]).astype(np.int32))
        nsrc.append(1)
        weights.append(1.0)
    return _finalize(n_nodes, pin_lists, nsrc, weights)


def random_kuniform(n_nodes: int, n_edges: int, k: int, seed: int = 0,
                    n_src: int = 1, weighted: bool = False) -> HostHypergraph:
    rng = np.random.default_rng(seed)
    k = min(k, n_nodes)
    n_src = min(n_src, k - 1) if k > 1 else 0
    pin_lists, nsrc, weights = [], [], []
    for _ in range(n_edges):
        pins = rng.choice(n_nodes, size=k, replace=False).astype(np.int32)
        pin_lists.append(pins)
        nsrc.append(n_src)
        weights.append(float(rng.integers(1, 10)) if weighted else 1.0)
    return _finalize(n_nodes, pin_lists, nsrc, weights)


def perturb_delta(hg: HostHypergraph, n_edges: int = 8,
                  seed: int = 0) -> GraphDelta:
    """A structure-preserving random perturbation: delete ``n_edges``
    random edges and insert the same number of fresh similar-shaped ones
    (driver + sampled sinks, cardinality drawn from the existing edge
    cardinality distribution). Deterministic in ``seed``. This is the
    synthetic load shift used by the streaming-repartition benchmark, the
    launch CLI's ``--perturb-edges``, and the warm-path tests."""
    rng = np.random.default_rng(seed)
    n_edges = int(min(n_edges, hg.n_edges))
    if n_edges <= 0:
        return GraphDelta()
    dels = rng.choice(hg.n_edges, size=n_edges, replace=False)
    card = np.maximum(np.diff(hg.edge_off), 2).astype(np.int64)
    adds = []
    for e in dels:
        k = int(min(card[int(e)], hg.n_nodes))
        pins = rng.choice(hg.n_nodes, size=k, replace=False).astype(np.int32)
        adds.append((pins, 1 if k > 1 else 0, float(hg.edge_w[int(e)])))
    return GraphDelta(del_edges=tuple(int(e) for e in dels),
                      add_edges=tuple(adds))


# Named suites mirroring the paper's tables at CPU-tractable scale.
def paper_snn_suite(scale: float = 1.0) -> dict[str, HostHypergraph]:
    s = lambda x: max(2, int(x * scale))
    return {
        "model-s": snn_layered(n_layers=s(5), width=s(192), fanout=10, seed=1),
        "model-m": snn_layered(n_layers=s(6), width=s(320), fanout=12, seed=2),
        "model-l": snn_layered(n_layers=s(8), width=s(448), fanout=14, seed=3),
        "rand-s": snn_smallworld(n_nodes=s(768), fanout=12, seed=4),
        "rand-m": snn_smallworld(n_nodes=s(1536), fanout=16, seed=5),
        "rand-l": snn_smallworld(n_nodes=s(3072), fanout=16, seed=6),
    }


def paper_ispd_suite(scale: float = 1.0) -> dict[str, HostHypergraph]:
    s = lambda x: max(64, int(x * scale))
    return {
        "ibm01-like": ispd_like(n_nodes=s(2048), seed=11),
        "ibm05-like": ispd_like(n_nodes=s(4096), seed=12),
        "ibm10-like": ispd_like(n_nodes=s(8192), seed=13),
    }
