"""Exact maximum-weight matching on the candidate-pair pseudo-forest.

Faithful JAX port of paper Sec. V-D: every node proposes ``target(n)`` with
``score(n)``; the proposal graph is a functional pseudo-forest whose cycles
are 2-cycles (score symmetry + id tie-break). We compute the exact DP
(Eq. 7-12): a bottom-up sweep accumulating, per node,

  sum0(n)   = sum of ss0 over finalized non-root children,
  best(n)   = max over children of ss1-0 (value, id) with larger-id tie-break
              — the functional analogue of the paper's atomic lexicographic
              max claim,

followed by 2-cycle root settlement (Eq. 8/11) and a top-down resolution
sweep (Eq. 12). Both sweeps are ``lax.while_loop`` wavefronts whose trip
count is the tree height — the same span the paper reports (S = height,
treated as ~1).

Robustness beyond the paper: when later proposal rounds (pi > 1) or
floating-point asymmetry break the 2-cycle invariant, the wavefront can
stall on a longer cycle. We then deterministically cut the outgoing edge of
every stalled node whose (score, id) key is smaller than its target's —
at least one such edge exists on any cycle, so progress is guaranteed; the
cut node becomes a tree root. Round 1 under exact symmetry never stalls, so
the paper's exactness claim is preserved where it applies. The cut is
applied as a mask (empty whenever any node is ready) rather than a
``lax.cond`` so the sharded reductions below stay structurally uniform —
every wavefront iteration executes the same collectives on every shard.

Sharding (``ctx`` a ``segops.ShardCtx``, inside ``dist.partition``'s
shard_map): the DP state stays replicated; each wavefront iteration stripes
the *child lanes* across shards and combines per-parent reductions so the
DP stays exact: integer child counts psum (exact), per-parent (value, id)
claims take a cross-shard lexicographic pmax (exact — pure maxes), and the
float ``sum0`` pushes gather their (segment, value) lane columns in stripe
order — the global child order — so the scatter accumulation is
bit-identical to the single-device sweep (a float psum would not be).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.utils import segops

NEG = jnp.float32(-jnp.inf)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class _State:
    done: jax.Array
    cnt: jax.Array
    sum0: jax.Array
    bestval: jax.Array
    bestid: jax.Array
    has_parent: jax.Array  # child edge still present (False once cut)
    stall_guard: jax.Array


def match_pseudoforest(target: jax.Array, score: jax.Array,
                       live: jax.Array,
                       ctx: segops.ShardCtx = segops.ShardCtx()) -> jax.Array:
    """Returns match[Ncap] int32: partner id, or -1 if unmatched.

    target: proposed partner per node (-1 = no proposal). score: eta of the
    proposal. live: mask of nodes participating in this round.
    """
    ncap = target.shape[0]
    ids = jnp.arange(ncap, dtype=jnp.int32)

    tgt_live = live & (target >= 0) & live[jnp.clip(target, 0, ncap - 1)]
    target = jnp.where(tgt_live, target, -1)
    t_safe = jnp.clip(target, 0, ncap - 1)

    # 2-cycle roots (paper: all cycles have length two under the invariant)
    root_pair = tgt_live & (target[t_safe] == ids)

    # this shard's contiguous stripe of child lanes (all lanes on one device)
    ch, ch_in = ctx.lanes(ncap)
    ch_safe = jnp.clip(ch, 0, ncap - 1)

    def count_children(mask):
        """#children per parent from a replicated child mask (int, psum)."""
        seg = jnp.where(ctx.take(mask, ch, ch_in, False),
                        target[ch_safe], ncap)
        return ctx.psum(jax.ops.segment_sum(
            jnp.ones(ch.shape, jnp.int32), seg,
            num_segments=ncap + 1))[:ncap]

    def sum_children(mask, values):
        """Float sum per parent: lanes gather in stripe order (= global
        child order) so the accumulation is bit-identical to one device;
        with ``ctx.compensated`` the per-shard dense partials combine by a
        Neumaier-compensated psum instead — O(ncap) traffic in place of the
        O(lanes) gather, within ~1 ulp but not bit-identical."""
        msk = ctx.take(mask, ch, ch_in, False)
        seg = jnp.where(msk, target[ch_safe], ncap)
        val = jnp.where(msk, values[ch_safe], 0.0)
        if ctx.compensated:
            return ctx.psum_compensated(jax.ops.segment_sum(
                val, seg, num_segments=ncap + 1)[:ncap])
        return jax.ops.segment_sum(ctx.gather(val), ctx.gather(seg),
                                   num_segments=ncap + 1)[:ncap]

    def best_children(values, mask):
        """(max value, larger-id tie-break) per parent; (-inf, -1) if
        empty. Cross-shard combine is a pure (value, id) max — exact."""
        msk = ctx.take(mask, ch, ch_in, False)
        seg = jnp.where(msk, target[ch_safe], ncap)
        v = jnp.where(msk, values[ch_safe], NEG)
        mx = ctx.pmax(jax.ops.segment_max(v, seg, num_segments=ncap + 1)[:ncap])
        mx = jnp.nan_to_num(mx, neginf=float("-inf"))
        hit = msk & (v == mx[jnp.clip(seg, 0, ncap - 1)]) & ~jnp.isneginf(v) \
            & (seg < ncap)
        arg = ctx.pmax(jax.ops.segment_max(
            jnp.where(hit, ch, -1), seg, num_segments=ncap + 1)[:ncap])
        return mx, arg

    cnt0 = count_children(tgt_live & ~root_pair)

    st = _State(
        done=~live,
        cnt=cnt0,
        sum0=jnp.zeros((ncap,), jnp.float32),
        bestval=jnp.full((ncap,), NEG),
        bestid=jnp.full((ncap,), -1, jnp.int32),
        has_parent=tgt_live & ~root_pair,
        stall_guard=jnp.int32(0),
    )

    # deterministic cycle-cut key: cut n when key(n) < key(target(n))
    k_lt = (score < score[t_safe]) | (
        (score == score[t_safe]) & (ids < target))

    def pending(s):
        return live & ~s.done & ~root_pair

    def cond(s):
        return jnp.any(pending(s))

    def body(s):
        pend = pending(s)
        ready = pend & (s.cnt == 0)
        any_ready = jnp.any(ready)

        ss0_r = s.sum0 + jnp.maximum(0.0, jnp.where(jnp.isneginf(s.bestval),
                                                    0.0, s.bestval))
        ss1_r = score + s.sum0
        push = ready & s.has_parent
        sum0 = s.sum0 + sum_children(push, ss0_r)
        nv, ni = best_children(ss1_r - ss0_r, push)
        better = (nv > s.bestval) | ((nv == s.bestval) & (ni > s.bestid))
        bestval = jnp.where(better, nv, s.bestval)
        bestid = jnp.where(better, ni, s.bestid)
        done = s.done | ready

        # stall => deterministic cycle cut; the mask is empty on any
        # progress round, so this is the lax.cond of the single-device
        # version unrolled into uniform (always-executed) reductions.
        # parent bookkeeping: every finalized child (pushed or cut) ticks
        # cnt — the ready and cut masks are disjoint (ready vs ~ready), so
        # one merged count covers both at the original cost
        cut = pend & ~ready & k_lt & s.has_parent & ~any_ready
        cnt = s.cnt - count_children((ready & tgt_live & ~root_pair) | cut)
        return _State(done=done, cnt=cnt, sum0=sum0, bestval=bestval,
                      bestid=bestid, has_parent=s.has_parent & ~cut,
                      stall_guard=s.stall_guard
                      + jnp.where(any_ready, 0, 1).astype(jnp.int32))

    st = jax.lax.while_loop(cond, body, st)

    # ---- root settlement --------------------------------------------------
    ss0 = st.sum0 + jnp.maximum(0.0, jnp.where(jnp.isneginf(st.bestval),
                                               0.0, st.bestval))
    best_ok = (st.bestid >= 0) & (st.bestval >= 0.0)
    best_or_none = jnp.where(best_ok, st.bestid, -1)

    partner = t_safe
    ss1_root = score + st.sum0 + st.sum0[partner]          # Eq. 8
    pairup = root_pair & (ss1_root > ss0 + ss0[partner])   # Eq. 11
    match = jnp.full((ncap,), -1, jnp.int32)
    match = jnp.where(root_pair, jnp.where(pairup, target, best_or_none), match)

    treeroot = live & ~st.has_parent & ~root_pair  # includes cut + undefined
    match = jnp.where(treeroot, best_or_none, match)
    resolved = ~live | root_pair | treeroot

    # ---- top-down resolution (Eq. 12) --------------------------------------
    def cond2(c):
        resolved, match = c
        return jnp.any(~resolved)

    def body2(c):
        resolved, match = c
        ready = ~resolved & resolved[t_safe]
        claimed = match[t_safe] == ids
        m_new = jnp.where(claimed, target, best_or_none)
        match = jnp.where(ready, m_new, match)
        return resolved | ready, match

    _, match = jax.lax.while_loop(cond2, body2, (resolved, match))

    # drop non-mutual entries (a node whose chosen child was claimed upstream)
    m_safe = jnp.clip(match, 0, ncap - 1)
    mutual = (match >= 0) & (match[m_safe] == ids)
    return jnp.where(mutual, match, -1)
