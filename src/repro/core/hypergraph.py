"""Hypergraph containers: host (numpy, ragged) and device (JAX, static-capacity).

The paper stores hypergraphs as two-level compressed sparse structures
(Fig. 2): a segmented data array plus an offsets array, with h-edge pins
stored *sources first* and node incidence stored *inbound h-edges first*,
each with a secondary count array (``|src(e)|`` / ``|in(n)|``).

We keep exactly that layout. The TPU adaptation is that device arrays are
**capacity-padded with validity counts** (XLA needs static shapes): the
coarsened level-(l+1) hypergraph lives in arrays of the same capacity as
level l, with ``n_nodes/n_edges/n_pins`` giving the live prefix sizes.
Padding lanes carry sentinels that sort to the end — the static-shape
analogue of the paper's idle CUDA lanes.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

NSENT = np.int32(2**31 - 1)  # sentinel id for padding lanes


class CapacityError(ValueError):
    """A static device capacity was exceeded by the live data.

    The capacity-padded device pipelines clip/drop out-of-capacity lanes
    (XLA needs static shapes), so an undersized ``Caps`` would otherwise
    corrupt results *silently* — e.g. a truncated neighborhood CSR simply
    drops candidate pairs. The drivers therefore audit the live counts
    host-side every level (see ``check_expansion_caps``) and raise this
    instead of mis-partitioning."""


def check_expansion_caps(caps: "Caps", n_pairs_live, n_nbr_entries=None):
    """Host-side overflow audit for one level's pair/neighborhood expansion.

    ``n_pairs_live`` is the *true* ordered-pin-pair count (``build_pairs``
    derives it from ``edge_off`` alone, so it is exact even when the lane
    expansion was truncated); ``n_nbr_entries`` the deduplicated
    neighborhood entry count from ``build_neighbors`` (exact only while the
    pair expansion itself fit — hence pairs are checked first). Either may
    be a device scalar; syncing them here is the per-level host round-trip
    the drivers already pay for the ``n_pairs`` stop check."""
    pl = int(n_pairs_live)
    if pl > caps.pairs:
        raise CapacityError(
            f"pair-expansion overflow: {pl} live ordered pin pairs exceed "
            f"Caps.pairs={caps.pairs}; lanes past capacity were dropped. "
            f"Raise pair_cap (Caps.for_host computes the exact bound by "
            f"default).")
    if n_nbr_entries is not None:
        nl = int(n_nbr_entries)
        if nl > caps.nbrs:
            raise CapacityError(
                f"neighborhood overflow: {nl} deduplicated (node, neighbor) "
                f"entries exceed Caps.nbrs={caps.nbrs}; the compacted CSR "
                f"would have been truncated. Raise nbr_cap.")


# --------------------------------------------------------------------------
# Host container
# --------------------------------------------------------------------------
@dataclasses.dataclass
class HostHypergraph:
    """Ragged numpy hypergraph; ground-truth structure for IO / oracles.

    ``drift_pins`` accumulates the number of pins touched by ``apply_delta``
    batches since the last full (cold) solve — the numerator of the
    ``drift`` metric that ``core.partitioner.repartition`` compares against
    its fallback threshold. A cold solve calls ``reset_drift()``.
    """

    n_nodes: int
    edge_off: np.ndarray    # [E+1] int64
    edge_pins: np.ndarray   # [P]   int32 — sources first within each edge
    edge_nsrc: np.ndarray   # [E]   int32
    edge_w: np.ndarray      # [E]   float32
    drift_pins: int = 0     # pins touched by deltas since last full solve

    def __post_init__(self):
        self.edge_off = np.asarray(self.edge_off, np.int64)
        self.edge_pins = np.asarray(self.edge_pins, np.int32)
        self.edge_nsrc = np.asarray(self.edge_nsrc, np.int32)
        self.edge_w = np.asarray(self.edge_w, np.float32)

    @property
    def n_edges(self) -> int:
        return len(self.edge_w)

    @property
    def n_pins(self) -> int:
        return int(self.edge_off[-1])

    @property
    def drift(self) -> float:
        """Fraction of the current pin population touched by deltas since
        the last full solve, clamped to 1.0. The streaming repartitioner
        falls back to a cold V-cycle once this crosses its threshold."""
        return min(1.0, self.drift_pins / max(self.n_pins, 1))

    def reset_drift(self) -> None:
        self.drift_pins = 0

    def edge(self, e: int) -> np.ndarray:
        return self.edge_pins[self.edge_off[e]: self.edge_off[e + 1]]

    def src(self, e: int) -> np.ndarray:
        return self.edge_pins[self.edge_off[e]: self.edge_off[e] + self.edge_nsrc[e]]

    def dst(self, e: int) -> np.ndarray:
        return self.edge_pins[self.edge_off[e] + self.edge_nsrc[e]: self.edge_off[e + 1]]

    def validate(self) -> None:
        assert self.edge_off[0] == 0 and np.all(np.diff(self.edge_off) >= 0)
        assert self.edge_pins.min(initial=0) >= 0
        assert self.edge_pins.max(initial=-1) < self.n_nodes
        for e in range(self.n_edges):
            pins = self.edge(e)
            assert len(np.unique(pins)) == len(pins), f"duplicate pin in edge {e}"
            assert 0 <= self.edge_nsrc[e] <= len(pins)

    # -- derived structure (numpy reference for incidence construction) ----
    def incidence(self):
        """Returns (node_off[N+1], node_edges[P], node_is_in[P], node_nin[N])
        with inbound edges first per node, ordered by edge id within group."""
        E, P, N = self.n_edges, self.n_pins, self.n_nodes
        pin_edge = np.repeat(np.arange(E, dtype=np.int32),
                             np.diff(self.edge_off).astype(np.int64))
        rel = np.arange(P, dtype=np.int64) - self.edge_off[pin_edge]
        is_dst = rel >= self.edge_nsrc[pin_edge]
        order = np.lexsort((pin_edge, ~is_dst, self.edge_pins))
        node_edges = pin_edge[order]
        node_is_in = is_dst[order]
        counts = np.bincount(self.edge_pins, minlength=N)
        node_off = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        node_nin = np.bincount(self.edge_pins[is_dst], minlength=N).astype(np.int32)
        return node_off, node_edges, node_is_in, node_nin

    def stats(self) -> dict:
        card = np.diff(self.edge_off)
        node_off, *_ = self.incidence()
        deg = np.diff(node_off)
        return dict(
            n_nodes=self.n_nodes, n_edges=self.n_edges, n_pins=self.n_pins,
            max_card=int(card.max(initial=0)), avg_card=float(card.mean()) if len(card) else 0.0,
            max_deg=int(deg.max(initial=0)), avg_deg=float(deg.mean()) if len(deg) else 0.0,
            pair_expansion=int((card.astype(np.int64) ** 2 - card).sum()),
        )


# --------------------------------------------------------------------------
# Incremental updates (streaming repartitioning)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class GraphDelta:
    """One batched structural update against a ``HostHypergraph``.

    **Id semantics.** Every node/edge id in a delta refers to the graph
    *before* the batch is applied. New node ids are knowable upfront
    (``old_n .. old_n + add_nodes - 1``) and may appear in ``add_pins`` /
    ``add_edges`` of the same batch. Edge ids shift down after deletions
    (edge order is otherwise preserved, then ``add_edges`` append), so a
    *subsequent* delta must use post-batch ids.

    **Node deletion is a tombstone**: every pin of the node is dropped from
    every edge, but the id stays allocated as an isolated node — node ids
    are stable, so a previous partition vector remains aligned (the warm
    path's core invariant).

    Fields:
      * ``add_nodes`` — number of fresh (isolated) nodes to append.
      * ``del_nodes`` — node ids to tombstone.
      * ``del_edges`` — edge ids to remove outright.
      * ``add_edges`` — ``(pins, nsrc, w)`` triples; pins sources-first.
      * ``add_pins`` — ``(edge, node)`` pairs appended as *dst* pins.
      * ``del_pins`` — ``(edge, node)`` pairs removed (nsrc adjusts if the
        removed pin was a source).
    """

    add_nodes: int = 0
    del_nodes: tuple = ()
    del_edges: tuple = ()
    add_edges: tuple = ()   # of (pins: array-like, nsrc: int, w: float)
    add_pins: tuple = ()    # of (edge, node)
    del_pins: tuple = ()    # of (edge, node)


def apply_delta(hg: HostHypergraph, delta: GraphDelta) -> int:
    """Apply one delta batch to ``hg`` **in place**; returns the number of
    pins touched (also accumulated onto ``hg.drift_pins``).

    Application order: pin deletions -> node tombstones -> pin insertions ->
    edge deletions -> edge insertions -> node-space growth. Touched pins =
    every explicitly edited pin + every pin of a deleted or inserted edge +
    every pin dropped by a tombstone. Raises ``ValueError`` on ids that do
    not resolve against the pre-batch graph (a malformed delta must never
    half-apply silently — callers treat the graph as corrupt if this
    escapes mid-batch, exactly like a failed transaction)."""
    new_n = hg.n_nodes + int(delta.add_nodes)
    pins = [list(map(int, hg.edge(e))) for e in range(hg.n_edges)]
    nsrc = [int(v) for v in hg.edge_nsrc]
    wts = [float(v) for v in hg.edge_w]
    E = len(pins)
    touched = 0

    for e, v in delta.del_pins:
        e, v = int(e), int(v)
        if not 0 <= e < E:
            raise ValueError(f"del_pins: edge {e} out of range")
        try:
            i = pins[e].index(v)
        except ValueError:
            raise ValueError(f"del_pins: node {v} is not a pin of edge {e}")
        del pins[e][i]
        if i < nsrc[e]:
            nsrc[e] -= 1
        touched += 1

    dead_nodes = {int(v) for v in delta.del_nodes}
    if dead_nodes:
        for v in dead_nodes:
            if not 0 <= v < hg.n_nodes:
                raise ValueError(f"del_nodes: node {v} out of range")
        for e in range(E):
            lst = pins[e]
            hit = [i for i, v in enumerate(lst) if v in dead_nodes]
            if hit:
                nsrc[e] -= sum(1 for i in hit if i < nsrc[e])
                pins[e] = [v for i, v in enumerate(lst) if v not in dead_nodes]
                touched += len(hit)

    for e, v in delta.add_pins:
        e, v = int(e), int(v)
        if not 0 <= e < E:
            raise ValueError(f"add_pins: edge {e} out of range")
        if not 0 <= v < new_n:
            raise ValueError(f"add_pins: node {v} out of range")
        if v in pins[e]:
            raise ValueError(f"add_pins: node {v} already a pin of edge {e}")
        pins[e].append(v)
        touched += 1

    dead_edges = {int(e) for e in delta.del_edges}
    for e in dead_edges:
        if not 0 <= e < E:
            raise ValueError(f"del_edges: edge {e} out of range")
        touched += len(pins[e])
    keep = [e for e in range(E) if e not in dead_edges]
    pins = [pins[e] for e in keep]
    nsrc = [nsrc[e] for e in keep]
    wts = [wts[e] for e in keep]

    for epins, ensrc, ew in delta.add_edges:
        epins = [int(v) for v in np.asarray(epins).ravel()]
        if len(set(epins)) != len(epins):
            raise ValueError("add_edges: duplicate pin within an edge")
        for v in epins:
            if not 0 <= v < new_n:
                raise ValueError(f"add_edges: node {v} out of range")
        if not 0 <= int(ensrc) <= len(epins):
            raise ValueError("add_edges: nsrc out of range")
        pins.append(epins)
        nsrc.append(int(ensrc))
        wts.append(float(ew))
        touched += len(epins)

    lens = np.array([len(p) for p in pins], np.int64)
    hg.n_nodes = new_n
    hg.edge_off = np.concatenate([[0], np.cumsum(lens)]).astype(np.int64)
    hg.edge_pins = (np.concatenate([np.asarray(p, np.int32) for p in pins])
                    if pins and sum(lens) else np.zeros(0, np.int32))
    hg.edge_nsrc = np.asarray(nsrc, np.int32)
    hg.edge_w = np.asarray(wts, np.float32)
    hg.drift_pins += touched
    return touched


def check_fits_caps(hg: HostHypergraph, caps: "Caps") -> None:
    """Resize trigger for delta-updated graphs: raises ``CapacityError``
    when ``hg`` no longer fits a previously computed ``Caps`` — live counts
    against the node/edge/pin capacities, plus the PR 5 pair-expansion audit
    (``check_expansion_caps``), since inserted edges can grow the pair total
    past ``caps.pairs``. The kernel tile bounds (``d_max``/``h0``) are *not*
    checked here: the Pallas dispatches guard them with their own runtime
    ``fits_kernel`` predicates and fall back to the segment paths, so stale
    tile bounds degrade speed, never correctness."""
    if hg.n_nodes > caps.n or hg.n_edges > caps.e or hg.n_pins > caps.p:
        raise CapacityError(
            f"delta-updated graph outgrew its capacities: "
            f"nodes {hg.n_nodes}/{caps.n}, edges {hg.n_edges}/{caps.e}, "
            f"pins {hg.n_pins}/{caps.p}. Rebuild device storage at fresh "
            f"Caps (Caps.for_host) — the warm solver does this "
            f"automatically.")
    check_expansion_caps(caps, host_pair_count(hg))


# --------------------------------------------------------------------------
# Static capacities
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Caps:
    """Static device capacities. Monotone under coarsening (coarse pins
    dedup, so pins/pairs/neighbor totals never grow level-over-level), hence
    one jit signature serves the entire multi-level run."""

    n: int      # node capacity
    e: int      # edge capacity
    p: int      # pins capacity
    pairs: int  # ordered-pin-pair expansion capacity (sum_e |e|^2 - |e|)
    nbrs: int   # unique (node, neighbor) capacity
    d_max: int = 0  # max h-edge cardinality (monotone non-increasing
                    # under coarsening: coarse pins only deduplicate)
    h0: int = 0   # level-0 max node incidence degree (kernel tile bound)
    l0: int = 0   # level-0 max per-node traversal sum_{e in I(n)} (|e|-1)
    u0: int = 0   # level-0 bound on unique neighbors per node

    @staticmethod
    def for_host(hg: HostHypergraph, pair_cap: int | None = None,
                 nbr_cap: int | None = None) -> "Caps":
        st = hg.stats()
        pairs = int(st["pair_expansion"]) if pair_cap is None else pair_cap
        nbrs = min(pairs, hg.n_nodes * max(1, hg.n_nodes - 1)) if nbr_cap is None else nbr_cap
        nbrs = max(nbrs, 1)
        # per-node traversal bound for the pair_scores kernel tiles
        node_off, node_edges, _, _ = hg.incidence()
        card = np.diff(hg.edge_off).astype(np.int64)
        trav = np.maximum(card[node_edges] - 1, 0)
        if hg.n_pins:
            # clip: trailing isolated nodes put their offset at P itself,
            # which reduceat rejects; where() zeroes those segments anyway
            idx = node_off[:-1].astype(np.int64).clip(0, len(trav) - 1)
            trav_per_node = np.add.reduceat(trav, idx)
        else:
            trav_per_node = np.zeros(1)
        trav_per_node = np.where(np.diff(node_off) > 0, trav_per_node, 0)
        l0 = int(trav_per_node.max(initial=0))
        return Caps(n=max(hg.n_nodes, 1), e=max(hg.n_edges, 1),
                    p=max(hg.n_pins, 1), pairs=max(pairs, 1), nbrs=nbrs,
                    d_max=int(st["max_card"]), h0=int(st["max_deg"]),
                    l0=max(l0, 1), u0=max(min(l0, hg.n_nodes - 1), 1))


# --------------------------------------------------------------------------
# Device container
# --------------------------------------------------------------------------
@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DeviceHypergraph:
    """Capacity-padded device hypergraph (all int32/float32)."""

    edge_off: jax.Array    # [Ecap+1]
    edge_pins: jax.Array   # [Pcap]  — NSENT beyond n_pins
    edge_nsrc: jax.Array   # [Ecap]
    edge_w: jax.Array      # [Ecap] f32
    node_off: jax.Array    # [Ncap+1]
    node_edges: jax.Array  # [Pcap] — NSENT beyond n_pins
    node_is_in: jax.Array  # [Pcap] bool
    node_nin: jax.Array    # [Ncap]
    node_size: jax.Array   # [Ncap] int32 cluster sizes (0 beyond n_nodes)
    n_nodes: jax.Array     # scalar int32
    n_edges: jax.Array
    n_pins: jax.Array

    @property
    def ncap(self) -> int:
        return self.node_off.shape[0] - 1

    @property
    def ecap(self) -> int:
        return self.edge_off.shape[0] - 1

    @property
    def pcap(self) -> int:
        return self.edge_pins.shape[0]


def packed_host_arrays(hg: HostHypergraph, caps: Caps,
                       pcap: int | None = None) -> dict:
    """Capacity-padded numpy staging arrays for a device hypergraph.

    ``pcap`` overrides the padded length of the three pins-sized arrays
    (``edge_pins``/``node_edges``/``node_is_in``) — ``dist.graph`` pads them
    to the shard-stripe total (``ceil(caps.p / nshards) * nshards``) so the
    stripes tile the mesh's model axis; the extra lanes carry the same
    sentinels as ordinary capacity padding."""
    node_off, node_edges, node_is_in, node_nin = hg.incidence()
    N, E, P = hg.n_nodes, hg.n_edges, hg.n_pins
    pcap = caps.p if pcap is None else pcap

    def pad(a, cap, fill, dtype):
        out = np.full((cap,), fill, dtype=dtype)
        out[: len(a)] = a
        return out

    eo = np.full((caps.e + 1,), P, np.int32)
    eo[: E + 1] = hg.edge_off
    no = np.full((caps.n + 1,), P, np.int32)
    no[: N + 1] = node_off
    return dict(
        edge_off=eo,
        edge_pins=pad(hg.edge_pins, pcap, NSENT, np.int32),
        edge_nsrc=pad(hg.edge_nsrc, caps.e, 0, np.int32),
        edge_w=pad(hg.edge_w, caps.e, 0.0, np.float32),
        node_off=no,
        node_edges=pad(node_edges, pcap, NSENT, np.int32),
        node_is_in=pad(node_is_in, pcap, False, bool),
        node_nin=pad(node_nin, caps.n, 0, np.int32),
        node_size=pad(np.ones(N, np.int32), caps.n, 0, np.int32),
        n_nodes=np.int32(N),
        n_edges=np.int32(E),
        n_pins=np.int32(P),
    )


def device_from_host(hg: HostHypergraph, caps: Caps) -> DeviceHypergraph:
    arrays = packed_host_arrays(hg, caps)
    return DeviceHypergraph(**{k: jnp.asarray(v) for k, v in arrays.items()})


def host_from_device(d: DeviceHypergraph) -> HostHypergraph:
    n_nodes = int(d.n_nodes)
    n_edges = int(d.n_edges)
    n_pins = int(d.n_pins)
    return HostHypergraph(
        n_nodes=n_nodes,
        edge_off=np.asarray(d.edge_off)[: n_edges + 1],
        edge_pins=np.asarray(d.edge_pins)[:n_pins],
        edge_nsrc=np.asarray(d.edge_nsrc)[:n_edges],
        edge_w=np.asarray(d.edge_w)[:n_edges],
    )


# --------------------------------------------------------------------------
# In-jit derived structures
# --------------------------------------------------------------------------
@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PairExpansion:
    """Flat ordered-pin-pair traversal: one entry per (edge, pin i, pin j!=i).

    This is the linearization of the paper's nested traversal
    ``forall n, forall e in I(n), forall m in e`` (Eq. 4): entry k visits
    node n = pins[i] seeing neighbor m = pins[j] through edge e. ``slot_n``
    is the global pin-slot of (e, i) — a unique id for the incidence pair
    (n, e), used as the segment key for per-(n,e) reductions.
    """

    edge: jax.Array      # [L] int32 edge id (NSENT padding)
    n: jax.Array         # [L] int32 visiting node
    m: jax.Array         # [L] int32 seen neighbor
    w_norm: jax.Array    # [L] f32 omega(e)/|e|
    w: jax.Array         # [L] f32 omega(e)
    both_dst: jax.Array  # [L] bool  n,m in dst(e)  (inter() contribution)
    slot_n: jax.Array    # [L] int32 pin-slot of n in e  == (n,e) segment id
    valid: jax.Array     # [L] bool
    n_pairs: jax.Array   # scalar int32


def build_pairs(d: DeviceHypergraph, caps: Caps,
                idx: jax.Array | None = None,
                idx_ok: jax.Array | None = None,
                ctx=None) -> PairExpansion:
    """``idx``/``idx_ok`` (from ``ShardCtx.lanes(caps.pairs)``) restrict the
    expansion to one shard's contiguous lane stripe; default is all lanes.

    ``ctx`` (a ``segops.ShardCtx``) matters only for memory-sharded graph
    storage (``ctx.graph_striped``): the expansion joins two *arbitrary*
    pin slots per pair lane (``edge_pins[slot_n]`` / ``edge_pins[slot_m]``)
    — the one access pattern lane striping cannot serve — so the pins
    column is transiently rebuilt full-length via ``ctx.gfull`` for the
    duration of the expansion (O(pins) live, freed after; the persistent
    storage stays striped — see ``dist.graph``)."""
    from repro.utils import segops

    if ctx is None:
        ctx = segops.ShardCtx()
    edge_pins = ctx.gfull(d.edge_pins)
    L = caps.pairs
    ecap = d.ecap
    card = (d.edge_off[1:] - d.edge_off[:-1]).astype(jnp.int32)  # [Ecap]
    live_edge = jnp.arange(ecap, dtype=jnp.int32) < d.n_edges
    card = jnp.where(live_edge, card, 0)
    pcnt = card * jnp.maximum(card - 1, 0)
    poff = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(pcnt)])
    n_pairs = poff[-1]

    if idx is None:
        idx = jnp.arange(L, dtype=jnp.int32)
    e = jnp.clip(jnp.searchsorted(poff, idx, side="right").astype(jnp.int32) - 1,
                 0, ecap - 1)
    valid = idx < n_pairs
    if idx_ok is not None:
        valid = valid & idx_ok
    r = idx - poff[e]
    c = jnp.maximum(card[e], 2)
    i = r // (c - 1)
    j0 = r % (c - 1)
    j = j0 + (j0 >= i)
    base = d.edge_off[e]
    slot_n = base + i
    slot_m = base + j
    safe = lambda s: jnp.clip(s, 0, caps.p - 1)
    n = jnp.where(valid, edge_pins[safe(slot_n)], NSENT)
    m = jnp.where(valid, edge_pins[safe(slot_m)], NSENT)
    nsrc = d.edge_nsrc[e]
    both_dst = valid & (i >= nsrc) & (j >= nsrc)
    wn = jnp.where(valid, d.edge_w[e] / jnp.maximum(card[e], 1), 0.0)
    w = jnp.where(valid, d.edge_w[e], 0.0)
    return PairExpansion(
        edge=jnp.where(valid, e, NSENT), n=n, m=m, w_norm=wn, w=w,
        both_dst=both_dst, slot_n=jnp.where(valid, slot_n, caps.p),
        valid=valid, n_pairs=n_pairs)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Neighborhoods:
    """Materialized deduplicated neighborhoods (paper Sec. V-B), CSR by node,
    ids ascending within each node's segment (binary-searchable)."""

    off: jax.Array       # [Ncap+1] int32
    ids: jax.Array       # [NBcap] int32 neighbor ids (NSENT padding)
    n_entries: jax.Array  # scalar int32


def build_neighbors(pairs: PairExpansion, d: DeviceHypergraph, caps: Caps,
                    ctx=None) -> Neighborhoods:
    """Sort-dedup the pair expansion into unique (n, m) adjacency.

    TPU adaptation of the paper's one-time hash-set construction: a stable
    two-key sort + boundary flags + compaction gives the same deduplicated
    CSR with deterministic ordering.

    ``ctx`` (a ``segops.ShardCtx``): ``pairs`` is then one shard's lane
    stripe; the (n, m) keys go through the distributed sample sort
    (``ctx.sort_by``, stripes in / stripes out — only splitter samples are
    gathered), dedup flags come from stripe-boundary-aware start flags, the
    compaction positions from a cross-shard cumsum carry, and the dense
    neighborhood arrays combine by psum of disjoint scatters. Bit-identical
    to the single-device build, which remains the ``ctx=None`` degenerate
    case of the same code path.
    """
    from repro.utils import segops

    if ctx is None:
        ctx = segops.ShardCtx()
    keyn = jnp.where(pairs.valid, pairs.n, NSENT)
    keym = jnp.where(pairs.valid, pairs.m, NSENT)
    (skn, skm), _ = ctx.sort_by([keyn, keym], [], striped_in=True,
                                striped_out=True)
    starts = ctx.starts_from_sorted([skn, skm])
    keep = starts & (skn != NSENT)
    f = keep.astype(jnp.int32)
    pos = ctx.cumsum(f) - f                      # global compaction slots
    n_entries = ctx.psum(jnp.sum(f))
    slot = jnp.where(keep, jnp.minimum(pos, caps.nbrs), caps.nbrs)
    live = jnp.arange(caps.nbrs, dtype=jnp.int32) < n_entries
    ids = ctx.psum(jnp.zeros((caps.nbrs + 1,), jnp.int32)
                   .at[slot].set(skm, mode="drop")[: caps.nbrs])
    ids = jnp.where(live, ids, NSENT)
    owner = ctx.psum(jnp.zeros((caps.nbrs + 1,), jnp.int32)
                     .at[slot].set(skn, mode="drop")[: caps.nbrs])
    owner = jnp.where(live, owner, NSENT)
    counts = jax.ops.segment_sum(
        jnp.ones_like(owner), jnp.where(owner == NSENT, caps.n, owner),
        num_segments=caps.n + 1)[: caps.n]
    off = segops.offsets_from_counts(counts.astype(jnp.int32))
    return Neighborhoods(off=off, ids=ids, n_entries=n_entries)


def host_pair_count(hg: HostHypergraph) -> int:
    """Exact (int64) ordered-pin-pair expansion size on host. The drivers
    audit this against ``Caps.pairs`` *before* any device work: pair totals
    are monotone non-increasing under coarsening (coarse pins dedup — see
    ``Caps``), so once level 0 fits, every coarser level's count is bounded
    by ``caps.pairs < 2**31`` and the per-level int32 device counts
    (``device_pair_count``, ``build_pairs``'s cumsum) are exact — no wrap
    can slip an overflow past the audit."""
    card = np.diff(hg.edge_off).astype(np.int64)
    return int((card * np.maximum(card - 1, 0)).sum())


@jax.jit
def device_pair_count(edge_off: jax.Array) -> jax.Array:
    """Live ordered-pin-pair expansion size ``sum_e |e|^2 - |e|`` computed
    on device from the (capacity-padded) offsets — dead edges beyond
    ``n_edges`` have zero cardinality by the padding convention, so no live
    mask is needed. int32, exact only while the total stays below 2**31 —
    guaranteed by the drivers' upfront ``host_pair_count`` audit plus pair
    monotonicity under coarsening (this is a per-level defense-in-depth
    recheck, not the primary overflow guard)."""
    card = (edge_off[1:] - edge_off[:-1]).astype(jnp.int32)
    return jnp.sum(card * jnp.maximum(card - 1, 0))


def shrink_device(d: DeviceHypergraph, caps: Caps) -> tuple[DeviceHypergraph, Caps]:
    """Perf iteration P1 (EXPERIMENTS.md §Perf): re-bucket capacities to the
    next power of two above the live sizes between coarsening levels.

    Baseline keeps level-0 capacities for every level (one jit signature,
    but each level pays O(caps) work on mostly-dead lanes). Bucketing trades
    a handful of extra compilations (one per pow2 bucket, amortized across
    levels) for geometric work decay. Edge capacity never shrinks (edge ids
    persist across levels, paper Sec. V-E).

    The live pair count is reduced on device (``device_pair_count``) and
    read back in the same ``device_get`` as the node/pin scalars — one
    host sync of three scalars per bucketed level, replacing the previous
    blocking O(E) ``edge_off`` readback.
    """
    import math as _math
    n_live, p_live, pair_live = (int(v) for v in jax.device_get(
        [d.n_nodes, d.n_pins, device_pair_count(d.edge_off)]))
    new_n = 1 << max(0, _math.ceil(_math.log2(max(n_live, 1))))
    new_p = 1 << max(0, _math.ceil(_math.log2(max(p_live, 1))))
    if new_n >= caps.n and new_p >= caps.p:
        return d, caps
    new_n = min(new_n, caps.n)
    new_p = min(new_p, caps.p)
    new_pairs = min(caps.pairs,
                    1 << max(0, _math.ceil(_math.log2(max(pair_live, 1)))))
    new_nbrs = min(caps.nbrs, new_pairs)
    caps2 = Caps(n=new_n, e=caps.e, p=new_p, pairs=max(new_pairs, 1),
                 nbrs=max(new_nbrs, 1), d_max=caps.d_max, h0=caps.h0,
                 l0=caps.l0, u0=caps.u0)
    d2 = DeviceHypergraph(
        edge_off=d.edge_off,
        edge_pins=d.edge_pins[:new_p],
        edge_nsrc=d.edge_nsrc,
        edge_w=d.edge_w,
        node_off=d.node_off[: new_n + 1],
        node_edges=d.node_edges[:new_p],
        node_is_in=d.node_is_in[:new_p],
        node_nin=d.node_nin[:new_n],
        node_size=d.node_size[:new_n],
        n_nodes=d.n_nodes, n_edges=d.n_edges, n_pins=d.n_pins,
    )
    return d2, caps2
