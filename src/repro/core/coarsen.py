"""Constraint-aware coarsening: candidate pairs proposal (paper Sec. V-C).

Per node n we build the neighbor histogram

    eta(n, m) = sum_{e in I(n), m in e} w(e)/|e|                    (Eq. 5)

and — inline, in the same pass, exactly like the paper's in-histogram
counter (Fig. 3) — the inbound-set intersection

    inter(n, m) = |{e : n, m in dst(e)}|

so the union-size constraint check is `|in(n)|+|in(m)|-inter(n,m) <= Delta`
with no extra traversal. On GPU the histogram lives in shared memory and
pins binary-search their bin; here the histogram is *the materialized
neighborhood segment itself* (slots sorted by id), the binary search is a
vectorized segmented search, and the accumulation is a segment-sum over the
flat pair expansion. The Pallas kernel `repro.kernels.pair_scores` provides
the TPU-tiled equivalent of the same computation.

Candidate quality mechanisms reproduced from the paper: symmetric
deterministic noise capped at 10% of mean edge weight; top-Pi candidates per
node (Pi proposal graphs / matching rounds); best-effort pairing of nodes
with no valid candidates (size-sorted, union size overestimated by sums).

Every pins/pairs-sized stage threads an optional `segops.ShardCtx` (mirror
of `core.refine`): inside `dist.partition`'s shard_map the pair expansion,
the neighborhood binary searches and the Pi-round candidate argmaxes run on
one contiguous lane stripe per device. Integer partials (inter, matching
counts) combine with psum, per-node (value, id) claims with an exact
lexicographic pmax, and float partials (eta) gather their lane columns in
stripe order so the accumulation order — and hence every last bit — matches
the single-device path. With the default ctx everything below is the exact
single-device computation.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.hypergraph import (Caps, DeviceHypergraph, Neighborhoods,
                                   PairExpansion, NSENT)
from repro.core.matching import match_pseudoforest
from repro.utils import segops
from repro.utils.hashing import pair_noise

NEG = jnp.float32(-jnp.inf)


@dataclasses.dataclass(frozen=True)
class CoarsenParams:
    omega: int            # max cluster/partition size
    delta: int            # max distinct inbound h-edges
    n_cands: int = 4      # Pi
    noise_frac: float = 0.1
    use_kernels: bool = False  # route scoring through the Pallas kernels
    matching: str = "exact"    # "exact" DP | "greedy" (ablation, [22])

    def __post_init__(self):
        if self.matching not in ("exact", "greedy"):
            raise ValueError(
                "CoarsenParams.matching must be 'exact' or 'greedy', got "
                f"{self.matching!r}")


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Proposals:
    cand_ids: jax.Array     # [Pi, Ncap] neighbor id or -1
    cand_scores: jax.Array  # [Pi, Ncap]
    eta: jax.Array          # [NBcap] histogram values (for tests/ablation)
    inter: jax.Array        # [NBcap]
    valid_slot: jax.Array   # [NBcap]
    # live-vs-capacity diagnostics for the drivers' host-side overflow
    # audit (`hypergraph.check_expansion_caps`): the true ordered-pin-pair
    # expansion size and the deduplicated neighborhood entry count — the
    # device pipelines silently drop out-of-capacity lanes, so exceeding
    # `caps.pairs` / `caps.nbrs` must raise host-side, not mis-partition.
    n_pairs_live: jax.Array = dataclasses.field(
        default_factory=lambda: jnp.int32(0))  # scalar
    n_nbr_entries: jax.Array = dataclasses.field(
        default_factory=lambda: jnp.int32(0))  # scalar
    # 1 iff the use_kernels dispatch took the Pallas branch (0 on the
    # segment path or with use_kernels=False) — surfaces silent fallbacks
    # to tests/benchmarks via `PartitionResult.kernel_path`
    kernel_path_taken: jax.Array = dataclasses.field(
        default_factory=lambda: jnp.int32(0))  # scalar


def score_slots(d: DeviceHypergraph, nbrs: Neighborhoods,
                pairs: PairExpansion, caps: Caps,
                ctx: segops.ShardCtx = segops.ShardCtx()):
    """eta + inter accumulated over materialized neighbor slots.

    ``pairs`` may be one shard's lane stripe (``build_pairs`` with
    ``idx=ctx.lanes(caps.pairs)``): the binary searches run stripe-local;
    the integer ``inter`` partials psum exactly, while the float ``eta``
    lanes gather in stripe order — the global lane order — so the scatter
    accumulation order (and hence every bit) matches one device."""
    n_safe = jnp.clip(pairs.n, 0, caps.n - 1)
    lo = nbrs.off[n_safe]
    hi = nbrs.off[jnp.clip(pairs.n + 1, 0, caps.n)]
    iters = max(1, math.ceil(math.log2(caps.nbrs + 1)) + 1)
    slot = segops.searchsorted_segmented(nbrs.ids, lo, hi, pairs.m, iters)
    slot = jnp.where(pairs.valid, slot, caps.nbrs)
    if ctx.compensated:
        # opt-in O(dense) combine: Neumaier-compensated psum of per-shard
        # partials (~1 ulp of the true sum, not bit-identical to one device)
        eta = ctx.psum_compensated(jax.ops.segment_sum(
            pairs.w_norm, slot, num_segments=caps.nbrs + 1)[: caps.nbrs])
    else:
        eta = jax.ops.segment_sum(ctx.gather(pairs.w_norm), ctx.gather(slot),
                                  num_segments=caps.nbrs + 1)[: caps.nbrs]
    inter = ctx.psum(jax.ops.segment_sum(
        pairs.both_dst.astype(jnp.int32), slot,
        num_segments=caps.nbrs + 1))[: caps.nbrs]
    return eta, inter


def propose(d: DeviceHypergraph, nbrs: Neighborhoods, pairs: PairExpansion,
            caps: Caps, params: CoarsenParams,
            ctx: segops.ShardCtx = segops.ShardCtx()) -> Proposals:
    if params.use_kernels:
        from repro.kernels.pair_scores import ops as ps_ops
        # tile bounds are level-0 derived; guard + fall back (see ops.py).
        # The predicate is replicated and mesh-independent, so every shard
        # takes the same branch and the branch matches the single-device
        # run — required by the race=False parity contract.
        fits = ps_ops.fits_kernel(d, nbrs, pairs, caps, ctx)
        eta, inter = jax.lax.cond(
            fits,
            lambda: ps_ops.score_slots_kernel(d, nbrs, pairs, caps, ctx),
            lambda: score_slots(d, nbrs, pairs, caps, ctx))
        kernel_taken = fits.astype(jnp.int32)
    else:
        eta, inter = score_slots(d, nbrs, pairs, caps, ctx)
        kernel_taken = jnp.int32(0)

    owner = segops.rows_from_offsets(nbrs.off, caps.nbrs, caps.n)
    m = nbrs.ids
    entry_live = (m != NSENT) & (owner < caps.n)
    owner_safe = jnp.clip(owner, 0, caps.n - 1)
    m_safe = jnp.clip(m, 0, caps.n - 1)

    mean_w = jnp.sum(d.edge_w) / jnp.maximum(d.n_edges, 1)
    noise = pair_noise(owner_safe, m_safe, 1.0) * (params.noise_frac * mean_w)
    eta_n = eta + jnp.where(entry_live, noise, 0.0)

    size_ok = d.node_size[owner_safe] + d.node_size[m_safe] <= params.omega
    union = d.node_nin[owner_safe] + d.node_nin[m_safe] - inter
    inbound_ok = union <= params.delta
    valid_slot = entry_live & size_ok & inbound_ok

    value = jnp.where(valid_slot, eta_n, NEG)

    # Pi candidate rounds on lane-local slot stripes: each shard argmaxes
    # its contiguous stripe of the slot space, winners combine with the
    # exact cross-shard lexicographic (value, slot-id) pmax, and the shard
    # owning the winning slot retires it for the next round.
    sl, sl_ok = ctx.lanes(caps.nbrs)
    owner_l = owner_safe[jnp.clip(sl, 0, caps.nbrs - 1)]
    value_l = ctx.take(value, sl, sl_ok, NEG)
    per = sl.shape[0]

    cand_ids, cand_scores = [], []
    for _ in range(params.n_cands):
        mx_l, arg_l = segops.segment_argmax(
            value_l, sl, owner_l, caps.n, valid=value_l > NEG)
        mx, arg_slot = ctx.pmax_pair(mx_l, arg_l)
        got = (arg_slot >= 0) & ~jnp.isneginf(mx)
        cid = jnp.where(got, m[jnp.clip(arg_slot, 0, caps.nbrs - 1)], -1)
        cand_ids.append(cid)
        cand_scores.append(jnp.where(got, mx, 0.0))
        loc = arg_slot - sl[0]
        value_l = value_l.at[jnp.where(got & (loc >= 0) & (loc < per),
                                       loc, per)].set(NEG, mode="drop")

    return Proposals(cand_ids=jnp.stack(cand_ids),
                     cand_scores=jnp.stack(cand_scores),
                     eta=eta_n, inter=inter, valid_slot=valid_slot,
                     kernel_path_taken=kernel_taken)


def run_matching_rounds(props: Proposals, d: DeviceHypergraph, caps: Caps,
                        params: CoarsenParams,
                        ctx: segops.ShardCtx = segops.ShardCtx()) -> jax.Array:
    """Pi rounds of exact matching; matched nodes leave subsequent graphs."""
    ids = jnp.arange(caps.n, dtype=jnp.int32)
    live0 = ids < d.n_nodes
    match = jnp.full((caps.n,), -1, jnp.int32)

    for pi in range(params.n_cands):
        unmatched = live0 & (match < 0)
        tgt = props.cand_ids[pi]
        t_safe = jnp.clip(tgt, 0, caps.n - 1)
        tgt = jnp.where(unmatched & (tgt >= 0) & (match[t_safe] < 0), tgt, -1)
        if params.matching == "greedy":
            # ablation: prototype heuristic [22] — only mutual targets pair
            mutual = (tgt >= 0) & (tgt[jnp.clip(tgt, 0, caps.n - 1)] == ids)
            m_round = jnp.where(mutual, tgt, -1)
        else:
            m_round = match_pseudoforest(tgt, props.cand_scores[pi],
                                         unmatched, ctx)
        match = jnp.where((match < 0) & (m_round >= 0), m_round, match)
    return match


def pair_isolated(match: jax.Array, props: Proposals, d: DeviceHypergraph,
                  caps: Caps, params: CoarsenParams) -> jax.Array:
    """Best-effort pairing of nodes left with no valid candidates: sort by
    (size, id), pair adjacent entries when within constraints; inbound union
    overestimated by |in(n)|+|in(m)| (paper Sec. V-C, last mechanism)."""
    ids = jnp.arange(caps.n, dtype=jnp.int32)
    live = ids < d.n_nodes
    lonely = live & (match < 0) & (props.cand_ids[0] < 0)
    key = jnp.where(lonely, d.node_size, jnp.int32(2**30))
    (_, _), (perm,) = segops.sort_by([key, ids], [ids])
    npairs = caps.n // 2  # odd capacity: the last sorted entry stays single
    a = perm[0: 2 * npairs: 2]
    b = perm[1: 2 * npairs: 2]
    ok = (lonely[a] & lonely[b]
          & (d.node_size[a] + d.node_size[b] <= params.omega)
          & (d.node_nin[a] + d.node_nin[b] <= params.delta))
    match = match.at[jnp.where(ok, a, caps.n)].set(b, mode="drop")
    match = match.at[jnp.where(ok, b, caps.n)].set(a, mode="drop")
    return match


def coarsen_step_impl(d: DeviceHypergraph, caps: Caps, params: CoarsenParams,
                      ctx: segops.ShardCtx = segops.ShardCtx()):
    """One full coarsening level: neighbors -> proposals -> matching.

    Single source of truth for the jitted single-device ``coarsen_step``
    and ``dist.partition.coarsen_level``'s shard_map'd body (``ctx`` stripes
    the pairs/slot pipelines; the isolated-node pairing sort stays
    replicated — its inputs are node-sized and already replicated)."""
    from repro.core.hypergraph import build_neighbors, build_pairs

    pidx, pidx_ok = ctx.lanes(caps.pairs)
    pairs = build_pairs(d, caps, idx=pidx, idx_ok=pidx_ok, ctx=ctx)
    nbrs = build_neighbors(pairs, d, caps, ctx)
    props = propose(d, nbrs, pairs, caps, params, ctx)
    match = run_matching_rounds(props, d, caps, params, ctx)
    match = pair_isolated(match, props, d, caps, params)
    n_pairs = jnp.sum((match >= 0) & (jnp.arange(caps.n) < d.n_nodes)) // 2
    props = dataclasses.replace(props, n_pairs_live=pairs.n_pairs,
                                n_nbr_entries=nbrs.n_entries)
    return match, n_pairs, props


@partial(jax.jit, static_argnames=("caps", "params"))
def coarsen_step(d: DeviceHypergraph, caps: Caps, params: CoarsenParams):
    """Returns (match[Ncap], n_matched_pairs, proposals) — contraction
    happens in `repro.core.contract`."""
    return coarsen_step_impl(d, caps, params)
