"""Placement planning: the paper's constrained partitioner as a framework
feature.

* plan_expert_placement — MoE expert -> EP-shard assignment. Hypergraph:
  nodes = experts (unit size), one h-edge per observed co-activation set
  (the top-k expert set of a token, deduplicated, weight = frequency; all
  pins are destinations). Connectivity sum_e w(e)(lambda(e)-1) is then
  exactly the number of extra shards each routed token-group must reach —
  the all-to-all fan-out we pay at dispatch. Omega = experts/shard;
  Delta bounds the *distinct inbound routing groups* per shard (the ICI
  fan-in budget — the paper's distinct-inbound-h-edge constraint, verbatim).
  Returns a permutation placing co-activated experts on the same shard.

* plan_stage_assignment — layer -> pipeline-stage clustering. Nodes =
  layers (size = parameter-byte weight), h-edges = activation streams
  (residual chain + skip fan-ins); Omega = per-stage byte budget, Delta =
  per-stage distinct inbound activation tensors (chiplet-style interface
  budget, straight from the paper's motivation).

Both run the full multi-level GPU->TPU pipeline from repro.core.
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import ArchConfig
from repro.core import metrics
from repro.core.generate import _finalize
from repro.core.hypergraph import GraphDelta, HostHypergraph
from repro.core.kway import partition_kway, repartition_kway
from repro.core.partitioner import partition


def synth_routing_trace(cfg: ArchConfig, n_tokens: int = 4096,
                        seed: int = 0) -> np.ndarray:
    """Synthetic correlated router sample [n_tokens, top_k]: tokens draw
    experts from per-cluster Zipf-ish preference groups (real routers are
    strongly clustered, which is exactly what placement can exploit)."""
    mo = cfg.moe
    rng = np.random.default_rng(seed)
    n_groups = max(2, mo.n_experts // 8)
    group_of = rng.integers(0, n_groups, size=n_tokens)
    prefs = rng.dirichlet(np.full(mo.n_experts, 0.15), size=n_groups)
    out = np.zeros((n_tokens, mo.top_k), np.int32)
    for g in range(n_groups):
        idx = np.where(group_of == g)[0]
        for i in idx:
            out[i] = rng.choice(mo.n_experts, size=mo.top_k, replace=False,
                                p=prefs[g])
    return out


def routing_hypergraph(trace: np.ndarray, n_experts: int) -> HostHypergraph:
    sets: dict[tuple, int] = {}
    for row in trace:
        key = tuple(sorted(set(int(x) for x in row)))
        sets[key] = sets.get(key, 0) + 1
    pin_lists, nsrc, w = [], [], []
    for key, cnt in sorted(sets.items()):
        if len(key) < 2:
            continue
        pin_lists.append(np.array(key, np.int32))
        nsrc.append(0)           # pure-destination h-edge: all pins inbound
        w.append(float(cnt))
    return _finalize(n_experts, pin_lists, nsrc, w)


def routing_delta(old_hg: HostHypergraph,
                  new_hg: HostHypergraph) -> GraphDelta:
    """`GraphDelta` taking the routing hypergraph of the previous trace
    window to the current one: h-edges (deduplicated co-activation sets)
    are matched by pin set; vanished sets delete, fresh sets insert, and a
    set whose observed frequency changed is replaced (delete + insert —
    `GraphDelta` has no in-place weight update, and replacement keeps the
    pin accounting behind the drift metric honest). Both graphs must share
    the expert id space (same node count; node churn is out of scope for
    routing traces)."""
    if old_hg.n_nodes != new_hg.n_nodes:
        raise ValueError("routing graphs must share the expert id space")

    def keyed(hg: HostHypergraph) -> dict[tuple, int]:
        return {tuple(int(p) for p in hg.edge(e)): e
                for e in range(hg.n_edges)}

    old_keys, new_keys = keyed(old_hg), keyed(new_hg)
    dels, adds = [], []
    for key, e in old_keys.items():
        ne = new_keys.get(key)
        if ne is None or new_hg.edge_w[ne] != old_hg.edge_w[e]:
            dels.append(e)
    for key, ne in sorted(new_keys.items()):
        oe = old_keys.get(key)
        if oe is None or old_hg.edge_w[oe] != new_hg.edge_w[ne]:
            adds.append((np.array(key, np.int32),
                         int(new_hg.edge_nsrc[ne]),
                         float(new_hg.edge_w[ne])))
    return GraphDelta(del_edges=tuple(dels), add_edges=tuple(adds))


def _placement_from_parts(hg: HostHypergraph, parts: np.ndarray,
                          n_experts: int, n_shards: int,
                          delta: int | None) -> dict:
    """Shared tail of the placement planners: cap-respecting slot
    assignment from a raw partition vector (spill by id), audit, and the
    identity-placement fallback guard."""
    cap = n_experts // n_shards
    buckets: dict[int, list[int]] = {}
    for e in range(n_experts):
        buckets.setdefault(int(parts[e]) % n_shards, []).append(e)
    slots = np.full(n_experts, -1, np.int64)
    shard_fill = [0] * n_shards
    overflow = []
    for p in sorted(buckets):
        tgt = p % n_shards
        for e in buckets[p]:
            if shard_fill[tgt] < cap:
                slots[e] = tgt * cap + shard_fill[tgt]
                shard_fill[tgt] += 1
            else:
                overflow.append(e)
    for e in overflow:
        tgt = int(np.argmin(shard_fill))
        slots[e] = tgt * cap + shard_fill[tgt]
        shard_fill[tgt] += 1
    shard_of = slots // cap
    report = metrics.audit(hg, shard_of, omega=cap,
                           delta=delta if delta else 2 ** 29)
    # baseline: identity placement; never ship a placement worse than it
    ident = np.arange(n_experts) // cap
    report["connectivity_identity"] = metrics.connectivity(hg, ident)
    if report["connectivity"] > report["connectivity_identity"]:
        slots = np.arange(n_experts, dtype=np.int64)
        shard_of = ident
        report["connectivity"] = report["connectivity_identity"]
        report["fell_back_to_identity"] = True
    report["a2a_reduction"] = (
        report["connectivity_identity"] / max(report["connectivity"], 1e-9))
    return dict(perm=slots.astype(np.int32), parts=shard_of, report=report)


def plan_expert_placement(cfg: ArchConfig, n_shards: int,
                          trace: np.ndarray | None = None,
                          delta: int | None = None, seed: int = 0,
                          theta: int = 8) -> dict:
    """Returns dict(perm [E] old->new expert slot, parts [E], report,
    graph, raw_parts) — ``graph``/``raw_parts`` are the warm-start state
    `replan_expert_placement` resumes from."""
    mo = cfg.moe
    assert mo is not None and mo.n_experts % n_shards == 0
    if trace is None:
        trace = synth_routing_trace(cfg, seed=seed)
    hg = routing_hypergraph(trace, mo.n_experts)
    if delta is None:
        res = partition_kway(hg, k=n_shards, eps=0.0, theta=theta,
                             coarse_target=max(4 * n_shards, 16))
    else:
        res = partition(hg, omega=mo.n_experts // n_shards, delta=delta,
                        theta=theta)
    out = _placement_from_parts(hg, res.parts, mo.n_experts, n_shards, delta)
    out.update(graph=hg, raw_parts=res.parts, mode=res.mode,
               n_levels=res.n_levels)
    return out


def replan_expert_placement(cfg: ArchConfig, prev: dict, n_shards: int,
                            trace: np.ndarray, theta: int = 8,
                            drift_threshold: float = 0.5) -> dict:
    """Warm re-placement under a shifted routing trace: diff the new
    trace's routing hypergraph against the previous one (`routing_delta`),
    apply the delta in place, and re-refine from the previous raw parts
    (`kway.repartition_kway` — no coarsening, no cold solve) unless drift
    or the balance audit forces the cold fallback. ``prev`` is the dict a
    previous `plan_expert_placement` / `replan_expert_placement` returned;
    the returned dict is the same shape (chain them across trace
    windows)."""
    mo = cfg.moe
    hg = prev["graph"]
    dl = routing_delta(hg, routing_hypergraph(trace, mo.n_experts))
    res = repartition_kway(hg, prev["raw_parts"], k=n_shards, eps=0.0,
                           deltas=dl, drift_threshold=drift_threshold,
                           theta=theta,
                           coarse_target=max(4 * n_shards, 16))
    out = _placement_from_parts(hg, res.parts, mo.n_experts, n_shards, None)
    out.update(graph=hg, raw_parts=res.parts, mode=res.mode,
               n_levels=res.n_levels)
    return out


def layer_hypergraph(cfg: ArchConfig) -> HostHypergraph:
    """Residual-stream chain + periodic skip fan-ins over layers."""
    from repro.models import transformer as T
    from repro.models.common import param_count
    L = cfg.n_layers
    sizes = np.zeros(L, np.int64)
    per_layer = max(1, param_count(T.lm_shapes(cfg)) // max(L, 1))
    sizes[:] = per_layer // 2 ** 20 + 1          # MB-ish units
    pin_lists, nsrc, w = [], [], []
    for i in range(L - 1):
        pin_lists.append(np.array([i, i + 1], np.int32))
        nsrc.append(1)
        w.append(float(cfg.d_model))             # activation width proxy
    # periodic global taps (norm stats / telemetry fan-in)
    for i in range(0, L - 8, 8):
        pin_lists.append(np.arange(i, i + 8, dtype=np.int32))
        nsrc.append(1)
        w.append(float(cfg.d_model) / 8)
    return _finalize(L, pin_lists, nsrc, w), sizes


def plan_stage_assignment(cfg: ArchConfig, n_stages: int,
                          theta: int = 8) -> dict:
    hg, sizes = layer_hypergraph(cfg)
    res = partition_kway(hg, k=n_stages, eps=0.10, theta=theta,
                         coarse_target=max(4 * n_stages, 16))
    report = dict(connectivity=res.connectivity, cut_net=res.cut_net,
                  balance_eps=res.audit.get("balance_eps"))
    return dict(stage_of_layer=res.parts, report=report)
