"""k-way balanced partitioning mode (paper Sec. VII-E).

Minimal changes from the constrained mode, as in the paper:
  Omega = (1+eps) * |N| / k,  Delta = +inf,
coarsening halts early (paper: < 4096 coarse nodes, empirically stable for
small k) and a robust initial k-way partitioning is computed on the coarse
graph. The paper delegates that step to Mt-KaHyPar's direct k-way mode
(tens of ms on CPU, included in timings); offline we implement a greedy
affinity + least-load placement on the (tiny) coarsest graph instead —
documented as a deviation in DESIGN.md. Uncoarsening + refinement then run
exactly as in the constrained mode with K = k.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics
from repro.core.coarsen import CoarsenParams
from repro.core.hypergraph import (Caps, HostHypergraph,
                                   check_expansion_caps, device_from_host,
                                   device_pair_count, host_from_device,
                                   host_pair_count)
from repro.core.partitioner import (PartitionResult, _next_pow2,
                                    make_coarsen_fns, make_refine_fn,
                                    run_coarsen_loop)
from repro.core.refine import RefineParams
from repro.obs import trace as otrace
from repro.obs import vcycle as ovcycle

BIG_DELTA = 2 ** 29


def greedy_initial_kway(hg: HostHypergraph, node_size: np.ndarray, k: int,
                        omega: int) -> np.ndarray:
    """Greedy affinity placement on the coarsest graph (host-side; the
    coarsest graph is tiny). Nodes in size-descending order pick the
    partition with the highest total weight of h-edges already touching it,
    subject to the size budget; ties -> least-loaded, then lowest id."""
    N = hg.n_nodes
    parts = np.full(N, -1, np.int64)
    load = np.zeros(k, np.int64)
    affinity = np.zeros((N, k), np.float64)
    node_off, node_edges, _, _ = hg.incidence()
    order = np.lexsort((np.arange(N), -node_size[:N]))
    edge_pin_cache = [hg.edge(e) for e in range(hg.n_edges)]
    for n in order:
        fits = load + node_size[n] <= omega
        if not fits.any():
            fits = load == load.min()  # relief valve: least-loaded
        cand = np.where(fits)[0]
        best = cand[np.lexsort((cand, load[cand], -affinity[n, cand]))[0]]
        parts[n] = best
        load[best] += node_size[n]
        for e in node_edges[node_off[n]: node_off[n + 1]]:
            w = hg.edge_w[e]
            for m in edge_pin_cache[e]:
                if parts[m] < 0:
                    affinity[m, best] += w
    return parts


def partition_kway(hg: HostHypergraph, k: int, eps: float = 0.03,
                   n_cands: int = 4, theta: int = 16,
                   coarse_target: int | None = None,
                   use_kernels: bool = False, check_delta: bool = True,
                   collect_log: bool = False,
                   max_levels: int = 64,
                   plan=None, race: bool = True,
                   race_seed: int = 0,
                   dist_coarsen: bool = True,
                   compensated_psum: bool = False,
                   shard_graph: bool = False,
                   collect_stats: bool = False) -> PartitionResult:
    """k-way balanced partitioning; cut-net results from minimizing
    connectivity, exactly as the paper frames it.

    plan/race/race_seed/dist_coarsen/compensated_psum/shard_graph mirror
    `partitioner.partition`: with a `Plan`, each coarsening level runs
    mesh-sharded via `dist.partition.coarsen_level`/`contract_level` and
    each refinement level as mesh-raced replicas with sharded pipelines via
    `dist.partition.refine_level`; `shard_graph` memory-shards the
    pins-sized storage over the plan's "model" axis (`dist.graph`).
    `collect_stats` populates the quality side of
    `PartitionResult.level_stats` exactly as in `partitioner.partition`;
    phase wall-times are recorded as a "partition_kway" span tree and
    `timings` is a thin view over it."""
    omega = max(int((1 + eps) * hg.n_nodes / k), math.ceil(hg.n_nodes / k))
    with otrace.span("partition_kway", nodes=hg.n_nodes, edges=hg.n_edges,
                     k=k, omega=omega) as sp_total:
        with otrace.span("setup"):
            caps = Caps.for_host(hg)
            # exact int64 level-0 audit (see partitioner.partition): with
            # this passed the per-level int32 device counts cannot wrap
            check_expansion_caps(caps, host_pair_count(hg))
            if shard_graph:
                if plan is None or not dist_coarsen:
                    raise ValueError("shard_graph=True requires a Plan and "
                                     "dist_coarsen=True")
                from repro.dist.graph import sharded_from_host
                d = sharded_from_host(hg, caps, plan)
            else:
                d = device_from_host(hg, caps)
        cparams = CoarsenParams(omega=omega, delta=BIG_DELTA,
                                n_cands=n_cands, use_kernels=use_kernels)
        if coarse_target is None:
            coarse_target = min(4096, max(4 * k, 64))

        log: list = []
        _coarsen, _contract = make_coarsen_fns(cparams, plan, dist_coarsen,
                                               compensated=compensated_psum)
        # shared audited loop (one batched scalar sync + overflow audit per
        # level); blocks the dispatch tail so the phase span doesn't leak
        # into the host-side initial-partitioning step below
        with otrace.span("coarsen") as sp_coarsen:
            d, caps, levels, gammas, coarsen_hits, coarsen_meta = \
                run_coarsen_loop(d, caps, coarse_target, max_levels,
                                 _coarsen, _contract,
                                 log if collect_log else None)
        check_expansion_caps(caps, device_pair_count(d.edge_off))

        # ---- initial k-way on the coarsest graph (host, tiny) ------------
        with otrace.span("initial_kway"):
            if shard_graph:
                from repro.dist.graph import host_from_sharded
                coarse_host = host_from_sharded(d)
            else:
                coarse_host = host_from_device(d)
            coarse_sizes = np.asarray(d.node_size)[: coarse_host.n_nodes]
            init = greedy_initial_kway(coarse_host, coarse_sizes, k, omega)
            kcap = _next_pow2(k)
            parts = jnp.zeros((caps.n,), jnp.int32)
            parts = parts.at[: coarse_host.n_nodes].set(
                jnp.asarray(init, jnp.int32))

        rparams = RefineParams(omega=omega,
                               delta=BIG_DELTA if not check_delta
                               else BIG_DELTA,
                               theta=theta, use_kernels=use_kernels)

        rlog: list | None = [] if collect_log else None
        _refine = make_refine_fn(k, kcap, rparams, rlog, plan, race,
                                 race_seed)

        refine_meta: dict = {len(levels): dict(structure=dict(
            nodes=coarse_host.n_nodes, edges=int(d.n_edges),
            pins=int(d.n_pins)))}
        quality_dev: dict = {}
        refine_hits_dev: dict = {}
        with otrace.span("refine") as sp_refine:
            with otrace.span("refine_level", level=len(levels)):
                parts, refine_hits_dev[len(levels)] = _refine(
                    d, parts, caps, len(levels))
            if collect_stats:
                quality_dev[len(levels)] = ovcycle.quality_scalars(
                    d, parts, caps, kcap, omega, BIG_DELTA)
            for lvl in range(len(levels) - 1, -1, -1):
                g = gammas[lvl]
                d_lvl, caps_lvl = levels[lvl]
                with otrace.span("refine_level", level=lvl):
                    parts = jnp.where(
                        jnp.arange(caps_lvl.n) < d_lvl.n_nodes,
                        parts[jnp.clip(g, 0, caps_lvl.n - 1)], 0)
                    parts, refine_hits_dev[lvl] = _refine(d_lvl, parts,
                                                          caps_lvl, lvl)
                if collect_stats:
                    quality_dev[lvl] = ovcycle.quality_scalars(
                        d_lvl, parts, caps_lvl, kcap, omega, BIG_DELTA)
            # block before the span closes (the tail would otherwise drain
            # in np.asarray below, after the timer stopped)
            jax.block_until_ready(parts)
        hits_h, quality_h = jax.device_get(
            ([refine_hits_dev[i] for i in range(len(levels) + 1)],
             quality_dev))
        refine_hits = [int(v) for v in hits_h]
        for lvl in range(len(levels) + 1):
            refine_meta.setdefault(lvl, {})
            refine_meta[lvl]["kernel_refine"] = refine_hits[lvl]
            refine_meta[lvl]["quality"] = quality_h.get(lvl)

        with otrace.span("audit"):
            parts_np = np.asarray(parts)[: hg.n_nodes].astype(np.int64)
            aud = metrics.audit(hg, parts_np, omega=omega, delta=BIG_DELTA)
            aud["balance_eps"] = metrics.balance_epsilon(parts_np, k)
    return PartitionResult(
        parts=parts_np, n_parts=int(parts_np.max()) + 1,
        n_levels=len(gammas),
        connectivity=aud["connectivity"], cut_net=aud["cut_net"], audit=aud,
        timings=dict(total=sp_total.duration, coarsen=sp_coarsen.duration,
                     refine=sp_refine.duration),
        level_log=(log or []) + (rlog or []),
        kernel_path=dict(coarsen=coarsen_hits, refine=refine_hits),
        level_stats=ovcycle.assemble(coarsen_meta, refine_meta))
