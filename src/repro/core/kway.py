"""k-way balanced partitioning mode (paper Sec. VII-E).

Minimal changes from the constrained mode, as in the paper:
  Omega = (1+eps) * |N| / k,  Delta = +inf,
coarsening halts early (paper: < 4096 coarse nodes, empirically stable for
small k) and a robust initial k-way partitioning is computed on the coarse
graph. The paper delegates that step to Mt-KaHyPar's direct k-way mode
(tens of ms on CPU, included in timings); offline we implement a greedy
affinity + least-load placement on the (tiny) coarsest graph instead —
documented as a deviation in DESIGN.md. Uncoarsening + refinement then run
exactly as in the constrained mode with K = k.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics
from repro.core.coarsen import CoarsenParams
from repro.core.hypergraph import (Caps, HostHypergraph,
                                   check_expansion_caps, device_from_host,
                                   device_pair_count, host_from_device,
                                   host_pair_count)
from repro.core.partitioner import (PartitionResult, _next_pow2,
                                    make_coarsen_fns, make_refine_fn,
                                    run_coarsen_loop, run_refine_loop)
from repro.core.refine import RefineParams
from repro.obs import trace as otrace
from repro.obs import vcycle as ovcycle

BIG_DELTA = 2 ** 29


def greedy_initial_kway(hg: HostHypergraph, node_size: np.ndarray, k: int,
                        omega: int) -> np.ndarray:
    """Greedy affinity placement on the coarsest graph (host-side; the
    coarsest graph is tiny). Nodes in size-descending order pick the
    partition with the highest total weight of h-edges already touching it,
    subject to the size budget; ties -> least-loaded, then lowest id."""
    N = hg.n_nodes
    parts = np.full(N, -1, np.int64)
    load = np.zeros(k, np.int64)
    affinity = np.zeros((N, k), np.float64)
    node_off, node_edges, _, _ = hg.incidence()
    order = np.lexsort((np.arange(N), -node_size[:N]))
    edge_pin_cache = [hg.edge(e) for e in range(hg.n_edges)]
    for n in order:
        fits = load + node_size[n] <= omega
        if not fits.any():
            fits = load == load.min()  # relief valve: least-loaded
        cand = np.where(fits)[0]
        best = cand[np.lexsort((cand, load[cand], -affinity[n, cand]))[0]]
        parts[n] = best
        load[best] += node_size[n]
        for e in node_edges[node_off[n]: node_off[n + 1]]:
            w = hg.edge_w[e]
            for m in edge_pin_cache[e]:
                if parts[m] < 0:
                    affinity[m, best] += w
    return parts


def partition_kway(hg: HostHypergraph, k: int, eps: float = 0.03,
                   n_cands: int = 4, theta: int = 16,
                   coarse_target: int | None = None,
                   use_kernels: bool = False, check_delta: bool = True,
                   collect_log: bool = False,
                   max_levels: int = 64,
                   plan=None, race: bool = True,
                   race_seed: int = 0,
                   dist_coarsen: bool = True,
                   compensated_psum: bool = False,
                   shard_graph: bool = False,
                   collect_stats: bool = False) -> PartitionResult:
    """k-way balanced partitioning; cut-net results from minimizing
    connectivity, exactly as the paper frames it.

    plan/race/race_seed/dist_coarsen/compensated_psum/shard_graph mirror
    `partitioner.partition`: with a `Plan`, each coarsening level runs
    mesh-sharded via `dist.partition.coarsen_level`/`contract_level` and
    each refinement level as mesh-raced replicas with sharded pipelines via
    `dist.partition.refine_level`; `shard_graph` memory-shards the
    pins-sized storage over the plan's "model" axis (`dist.graph`).
    `collect_stats` populates the quality side of
    `PartitionResult.level_stats` exactly as in `partitioner.partition`;
    phase wall-times are recorded as a "partition_kway" span tree and
    `timings` is a thin view over it."""
    omega = max(int((1 + eps) * hg.n_nodes / k), math.ceil(hg.n_nodes / k))
    with otrace.span("partition_kway", nodes=hg.n_nodes, edges=hg.n_edges,
                     k=k, omega=omega) as sp_total:
        with otrace.span("setup"):
            caps = Caps.for_host(hg)
            # exact int64 level-0 audit (see partitioner.partition): with
            # this passed the per-level int32 device counts cannot wrap
            check_expansion_caps(caps, host_pair_count(hg))
            if shard_graph:
                if plan is None or not dist_coarsen:
                    raise ValueError("shard_graph=True requires a Plan and "
                                     "dist_coarsen=True")
                from repro.dist.graph import sharded_from_host
                d = sharded_from_host(hg, caps, plan)
            else:
                d = device_from_host(hg, caps)
        cparams = CoarsenParams(omega=omega, delta=BIG_DELTA,
                                n_cands=n_cands, use_kernels=use_kernels)
        if coarse_target is None:
            coarse_target = min(4096, max(4 * k, 64))

        log: list = []
        _coarsen, _contract = make_coarsen_fns(cparams, plan, dist_coarsen,
                                               compensated=compensated_psum)
        # shared audited loop (one batched scalar sync + overflow audit per
        # level); blocks the dispatch tail so the phase span doesn't leak
        # into the host-side initial-partitioning step below
        with otrace.span("coarsen") as sp_coarsen:
            d, caps, levels, gammas, coarsen_hits, coarsen_meta = \
                run_coarsen_loop(d, caps, coarse_target, max_levels,
                                 _coarsen, _contract,
                                 log if collect_log else None)
        check_expansion_caps(caps, device_pair_count(d.edge_off))

        # ---- initial k-way on the coarsest graph (host, tiny) ------------
        with otrace.span("initial_kway"):
            if shard_graph:
                from repro.dist.graph import host_from_sharded
                coarse_host = host_from_sharded(d)
            else:
                coarse_host = host_from_device(d)
            coarse_sizes = np.asarray(d.node_size)[: coarse_host.n_nodes]
            init = greedy_initial_kway(coarse_host, coarse_sizes, k, omega)
            kcap = _next_pow2(k)
            parts = jnp.zeros((caps.n,), jnp.int32)
            parts = parts.at[: coarse_host.n_nodes].set(
                jnp.asarray(init, jnp.int32))

        rparams = RefineParams(omega=omega,
                               delta=BIG_DELTA if not check_delta
                               else BIG_DELTA,
                               theta=theta, use_kernels=use_kernels)

        rlog: list | None = [] if collect_log else None
        _refine = make_refine_fn(k, kcap, rparams, rlog, plan, race,
                                 race_seed)

        # shared uncoarsening-refinement loop (one batched telemetry
        # readback; kway's collect_log never logged refine entries -> None)
        parts, sp_refine, refine_meta, refine_hits, pins_hits = \
            run_refine_loop(d, parts, caps, levels, gammas, _refine, kcap,
                            omega, BIG_DELTA, collect_stats, None)
        refine_meta[len(levels)]["structure"] = dict(
            nodes=coarse_host.n_nodes, edges=int(d.n_edges),
            pins=int(d.n_pins))

        with otrace.span("audit"):
            parts_np = np.asarray(parts)[: hg.n_nodes].astype(np.int64)
            aud = metrics.audit(hg, parts_np, omega=omega, delta=BIG_DELTA)
            aud["balance_eps"] = metrics.balance_epsilon(parts_np, k)
    return PartitionResult(
        parts=parts_np, n_parts=int(parts_np.max()) + 1,
        n_levels=len(gammas),
        connectivity=aud["connectivity"], cut_net=aud["cut_net"], audit=aud,
        timings=dict(total=sp_total.duration, coarsen=sp_coarsen.duration,
                     refine=sp_refine.duration),
        level_log=(log or []) + (rlog or []),
        kernel_path=dict(coarsen=coarsen_hits, refine=refine_hits,
                         pins=pins_hits),
        level_stats=ovcycle.assemble(coarsen_meta, refine_meta))


def repartition_kway(hg: HostHypergraph, prev_parts, k: int,
                     eps: float = 0.03, *, deltas=None,
                     drift_threshold: float = 0.25, cache=None,
                     n_cands: int = 4, theta: int = 16,
                     coarse_target: int | None = None,
                     use_kernels: bool = False,
                     collect_log: bool = False, max_levels: int = 64,
                     plan=None, race: bool = True, race_seed: int = 0,
                     dist_coarsen: bool = True,
                     compensated_psum: bool = False,
                     shard_graph: bool = False,
                     collect_stats: bool = False) -> PartitionResult:
    """k-way sibling of `partitioner.repartition`: apply ``deltas`` to
    ``hg`` in place, then re-refine from ``prev_parts`` with the k-way
    constraint frame (Omega recomputed from the post-delta node count,
    Delta = +inf), falling back to a cold `partition_kway` when drift
    exceeds the threshold or the warm result breaks balance. ``n_parts=k``
    is pinned so trailing empty partitions keep their ids."""
    from repro.core.hypergraph import (CapacityError, GraphDelta,
                                       apply_delta, check_fits_caps)
    from repro.core.partitioner import WarmCache, _extend_parts, refine_from

    if isinstance(deltas, GraphDelta):
        deltas = [deltas]
    for dl in (deltas or []):
        apply_delta(hg, dl)
        if cache is not None and cache.caps is not None:
            cache.d = None
            try:
                check_fits_caps(hg, cache.caps)
            except CapacityError:
                cache.invalidate()

    omega = max(int((1 + eps) * hg.n_nodes / k), math.ceil(hg.n_nodes / k))
    parts0 = _extend_parts(prev_parts, hg.n_nodes, k)

    def _cold(mode: str) -> PartitionResult:
        res = partition_kway(
            hg, k, eps, n_cands=n_cands, theta=theta,
            coarse_target=coarse_target, use_kernels=use_kernels,
            collect_log=collect_log, max_levels=max_levels, plan=plan,
            race=race, race_seed=race_seed, dist_coarsen=dist_coarsen,
            compensated_psum=compensated_psum, shard_graph=shard_graph,
            collect_stats=collect_stats)
        res.mode = mode
        hg.reset_drift()
        if cache is not None:
            cache.invalidate()
        return res

    if hg.drift > drift_threshold:
        return _cold("fallback-drift")

    wc = cache if cache is not None else WarmCache()
    if wc.caps is None:
        wc.d = None
        wc.caps = Caps.for_host(hg)
        check_expansion_caps(wc.caps, host_pair_count(hg))
    if wc.d is None:
        if shard_graph and plan is not None:
            from repro.dist.graph import sharded_from_host
            wc.d = sharded_from_host(hg, wc.caps, plan)
        else:
            wc.d = device_from_host(hg, wc.caps)
    res = refine_from(
        hg, parts0, omega, BIG_DELTA, n_parts=k, theta=theta,
        use_kernels=use_kernels, collect_log=collect_log, plan=plan,
        race=race, race_seed=race_seed, shard_graph=shard_graph,
        collect_stats=collect_stats, device_graph=wc.d, caps=wc.caps,
        mode="warm")
    res.audit["balance_eps"] = metrics.balance_epsilon(res.parts, k)
    if not res.audit["size_ok"]:
        return _cold("fallback-audit")
    return res
