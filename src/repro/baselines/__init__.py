"""The paper's comparison baselines (Sec. VII-A), implemented in numpy:

* sequential_ml — an hMETIS-style sequential multi-level partitioner
  adapted to the size + distinct-inbound constraints ([4, 13] in the paper)
* overlap      — greedy incidence-overlap SNN mapper ([4])
* onepass      — single-pass constraint-driven filler ([5])
"""
from repro.baselines.sequential_ml import sequential_multilevel  # noqa: F401
from repro.baselines.overlap import overlap_partition  # noqa: F401
from repro.baselines.onepass import onepass_partition  # noqa: F401
