"""Trivial "one-pass" SNN mapping baseline (paper baseline [5]).

Fills one partition after the other in a single pass over nodes, driven
solely by the constraints.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.hypergraph import HostHypergraph


def onepass_partition(hg: HostHypergraph, omega: int, delta: int):
    t0 = time.perf_counter()
    n = hg.n_nodes
    node_off, node_edges, node_is_in, _ = hg.incidence()
    parts = np.full(n, -1, np.int64)
    cur, p_sz = 0, 0
    p_in: set[int] = set()
    for node in range(n):
        seg = node_edges[node_off[node]: node_off[node + 1]]
        isin = node_is_in[node_off[node]: node_off[node + 1]]
        my_in = set(seg[isin].tolist())
        if p_sz + 1 > omega or len(p_in | my_in) > delta:
            cur += 1
            p_sz = 0
            p_in = set()
        parts[node] = cur
        p_sz += 1
        p_in |= my_in
    return parts, dict(time=time.perf_counter() - t0, n_parts=cur + 1)
