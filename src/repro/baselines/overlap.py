"""Greedy "overlap" SNN mapping heuristic (paper baseline [4]).

Co-locates nodes by inbound-incidence-set overlap: grow one partition at a
time, repeatedly adding the candidate whose inbound set overlaps the
partition's inbound set the most, within (Omega, Delta).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.hypergraph import HostHypergraph


def overlap_partition(hg: HostHypergraph, omega: int, delta: int):
    t0 = time.perf_counter()
    n = hg.n_nodes
    node_off, node_edges, node_is_in, _ = hg.incidence()
    inb = []
    nbrs = [set() for _ in range(n)]
    edge_members = [hg.edge(e).tolist() for e in range(hg.n_edges)]
    for node in range(n):
        seg = node_edges[node_off[node]: node_off[node + 1]]
        isin = node_is_in[node_off[node]: node_off[node + 1]]
        inb.append(set(seg[isin].tolist()))
        for e in seg:
            nbrs[node].update(m for m in edge_members[e] if m != node)

    parts = np.full(n, -1, np.int64)
    cur = 0
    unassigned = set(range(n))
    while unassigned:
        seed = min(unassigned)
        parts[seed] = cur
        unassigned.discard(seed)
        p_in = set(inb[seed])
        p_sz = 1
        frontier = set(m for m in nbrs[seed] if parts[m] < 0)
        while p_sz < omega and frontier:
            best, best_ov = -1, -1
            for m in sorted(frontier):
                ov = len(p_in & inb[m])
                if ov > best_ov:
                    best, best_ov = m, ov
            if best < 0:
                break
            if len(p_in | inb[best]) > delta:
                frontier.discard(best)
                continue
            parts[best] = cur
            unassigned.discard(best)
            p_in |= inb[best]
            p_sz += 1
            frontier.discard(best)
            frontier.update(m for m in nbrs[best] if parts[m] < 0)
        cur += 1
    return parts, dict(time=time.perf_counter() - t0, n_parts=cur)
