"""Sequential multi-level partitioner (hMETIS-style, constraint-adapted).

This is the paper's primary wall-clock baseline: "an implementation of the
multi-level scheme in hMETIS adapted to our constraints [4, 13]". Greedy
heavy-edge coarsening with inline union-size checks, clusters as initial
partitions, sequential single-move FM refinement during uncoarsening.
Deliberately sequential Python/numpy — it is the thing the paper's 380x is
measured against.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.hypergraph import HostHypergraph
from repro.core import metrics


def _incidence_sets(hg: HostHypergraph):
    node_off, node_edges, node_is_in, _ = hg.incidence()
    inc, inb = [], []
    for n in range(hg.n_nodes):
        seg = node_edges[node_off[n]: node_off[n + 1]]
        isin = node_is_in[node_off[n]: node_off[n + 1]]
        inc.append(seg)
        inb.append(set(seg[isin].tolist()))
    return inc, inb


def sequential_multilevel(hg: HostHypergraph, omega: int, delta: int,
                          theta: int = 4, max_levels: int = 64):
    t0 = time.perf_counter()
    # level state: cluster membership over original nodes
    n = hg.n_nodes
    card = np.diff(hg.edge_off)
    inc, inb = _incidence_sets(hg)
    size = np.ones(n, np.int64)
    cluster = np.arange(n)  # current coarse id per original node
    active = list(range(n))
    edge_members = [hg.edge(e).tolist() for e in range(hg.n_edges)]

    levels = 0
    while levels < max_levels:
        # greedy heavy-edge matching on current clusters
        ids = sorted(active)
        matched = {}
        taken = set()
        # neighbor scores eta via incident edges
        members = {c: [] for c in ids}
        for orig in range(n):
            members[cluster[orig]].append(orig)
        cl_edges = {c: set() for c in ids}
        for c in ids:
            for orig in members[c]:
                cl_edges[c].update(inc[orig].tolist())
        cl_inb = {c: set() for c in ids}
        for c in ids:
            for orig in members[c]:
                cl_inb[c] |= inb[orig]
        for c in ids:
            if c in taken:
                continue
            scores: dict[int, float] = {}
            for e in cl_edges[c]:
                w = float(hg.edge_w[e]) / max(len(edge_members[e]), 1)
                for m_orig in edge_members[e]:
                    mc = cluster[m_orig]
                    if mc != c:
                        scores[mc] = scores.get(mc, 0.0) + w
            best, best_s = -1, 0.0
            for mc, s in sorted(scores.items()):
                if mc in taken or mc == c:
                    continue
                if size[c] + size[mc] > omega:
                    continue
                if len(cl_inb[c] | cl_inb[mc]) > delta:
                    continue
                if s > best_s or (s == best_s and mc > best):
                    best, best_s = mc, s
            if best >= 0:
                matched[c] = best
                taken.add(c)
                taken.add(best)
        if not matched:
            break
        for c, m_ in matched.items():
            keep, drop = min(c, m_), max(c, m_)
            for orig in members[drop]:
                cluster[orig] = keep
            size[keep] += size[drop]
        active = sorted(set(cluster.tolist()))
        levels += 1
        if len(active) <= max(1, int(np.ceil(n / omega))):
            break

    # initial partitions = clusters; sequential FM refinement
    remap = {c: i for i, c in enumerate(sorted(set(cluster.tolist())))}
    parts = np.array([remap[c] for c in cluster], np.int64)
    k = len(remap)
    for _ in range(theta):
        improved = False
        psize = np.bincount(parts, weights=np.ones(n), minlength=k)
        pinb = [set() for _ in range(k)]
        for node in range(n):
            pinb[parts[node]] |= inb[node]
        for node in range(n):
            ps = parts[node]
            # gain per candidate partition (neighbor partitions only)
            cand: dict[int, float] = {}
            saving = 0.0
            for e in inc[node]:
                in_ps = sum(1 for m_ in edge_members[e] if parts[m_] == ps)
                if in_ps == 1:
                    saving += float(hg.edge_w[e])
                for m_ in edge_members[e]:
                    if parts[m_] != ps:
                        cand.setdefault(parts[m_], 0.0)
            for pd in cand:
                loss = 0.0
                for e in inc[node]:
                    if not any(parts[m_] == pd for m_ in edge_members[e]):
                        loss += float(hg.edge_w[e])
                cand[pd] = saving - loss
            if not cand:
                continue
            pd, g = max(sorted(cand.items()), key=lambda kv: kv[1])
            if g <= 0:
                continue
            if psize[pd] + 1 > omega:
                continue
            new_inb = pinb[pd] | inb[node]
            if len(new_inb) > delta:
                continue
            parts[node] = pd
            psize[ps] -= 1
            psize[pd] += 1
            pinb[pd] = new_inb
            pinb[ps] = set()
            for m_ in range(n):
                if parts[m_] == ps:
                    pinb[ps] |= inb[m_]
            improved = True
        if not improved:
            break

    _, parts = np.unique(parts, return_inverse=True)
    return parts, dict(time=time.perf_counter() - t0, levels=levels)
