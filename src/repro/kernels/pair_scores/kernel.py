"""Candidate-pairs scoring kernel: the paper's hottest kernel (Fig. 8).

CUDA original (Sec. V-C, Fig. 3): one warp per node; a batch of the node's
materialized unique neighbors is staged in shared memory as histogram bins;
threads stream the node's incident h-edges' pins and binary-search their
bin, accumulating eta(n,m) += w(e)/|e| — and, in the same bin, the
inbound-set intersection counter inter(n,m) whenever both endpoints are
destinations of the h-edge.

TPU redesign: binary search + scattered bin increments do not map to the
VPU. Instead the histogram *is* a dense equality-reduce over the node's
padded traversal against its padded unique-neighbor slots:

    eta[t, u]   = sum_l w[t, l]   * (trav[t, l] == nbr[t, u])
    inter[t, u] = sum_l dst[t, l] * (trav[t, l] == nbr[t, u])

The grid walks (node tiles x traversal chunks); nbr slots play the role of
the shared-memory batch (they live in VMEM for the whole row of chunks),
and the traversal chunks stream through exactly like the paper's pin
batches. Both planes accumulate in one pass — the constraint counter is
free, as in the paper.

  grid   = (N/TN, L/LC)
  nbr    : int32[N, U]    (pad -1)        block (TN, U)  idx (i, 0)
  trav_m : int32[N, L]    (pad -2)        block (TN, LC) idx (i, j)
  trav_w : f32[N, L]                      block (TN, LC) idx (i, j)
  trav_d : int32[N, L]                    block (TN, LC) idx (i, j)
  eta    : f32[N, U]                      block (TN, U)  idx (i, 0)  (accum)
  inter  : i32[N, U]                      block (TN, U)  idx (i, 0)  (accum)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pair_scores_kernel(nbr_ref, m_ref, w_ref, d_ref, eta_ref, inter_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        eta_ref[...] = jnp.zeros_like(eta_ref)
        inter_ref[...] = jnp.zeros_like(inter_ref)

    nbr = nbr_ref[...]                     # [TN, U]
    m = m_ref[...]                         # [TN, LC]
    eq = m[:, :, None] == nbr[:, None, :]  # [TN, LC, U]
    eta_ref[...] += jnp.sum(eq * w_ref[...][:, :, None], axis=1)
    inter_ref[...] += jnp.sum(eq * d_ref[...][:, :, None], axis=1)


@functools.partial(jax.jit,
                   static_argnames=("tn", "lc", "interpret"))
def pair_scores_pallas(nbr: jax.Array, trav_m: jax.Array, trav_w: jax.Array,
                       trav_d: jax.Array, tn: int = 8, lc: int = 128,
                       interpret: bool = True):
    n, u = nbr.shape
    _, l = trav_m.shape
    assert n % tn == 0 and l % lc == 0, (n, l, tn, lc)
    grid = (n // tn, l // lc)
    return pl.pallas_call(
        _pair_scores_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn, u), lambda i, j: (i, 0)),
            pl.BlockSpec((tn, lc), lambda i, j: (i, j)),
            pl.BlockSpec((tn, lc), lambda i, j: (i, j)),
            pl.BlockSpec((tn, lc), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((tn, u), lambda i, j: (i, 0)),
            pl.BlockSpec((tn, u), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, u), jnp.float32),
            jax.ShapeDtypeStruct((n, u), jnp.int32),
        ],
        interpret=interpret,
    )(nbr, trav_m, trav_w, trav_d.astype(jnp.int32))
