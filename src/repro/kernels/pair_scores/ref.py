"""Pure-jnp oracle for the pair_scores kernel."""
from __future__ import annotations

import jax.numpy as jnp


def pair_scores_ref(nbr, trav_m, trav_w, trav_d):
    """nbr [N,U] (pad -1), trav_m [N,L] (pad -2), trav_w [N,L], trav_d [N,L].
    Returns (eta [N,U] f32, inter [N,U] i32)."""
    eq = trav_m[:, :, None] == nbr[:, None, :]
    eta = jnp.sum(eq * trav_w[:, :, None], axis=1).astype(jnp.float32)
    inter = jnp.sum(eq * (trav_d[:, :, None] != 0), axis=1).astype(jnp.int32)
    return eta, inter
