"""jit'd wrapper: CSR/pair-expansion -> dense node tiles -> pair_scores.

Returns (eta, inter) in *slot space* ([NBcap]) so `coarsen.propose` can use
it as a drop-in for the segment-sum path. Tile bounds (U = unique neighbors
per node, L = per-node traversal length) come from the level-0 Caps; they
are not guaranteed monotone under coarsening (two merged nodes can union
their neighborhoods), so the caller guards with a runtime `fits` predicate
and lax.cond-falls back to the segment path — on real inputs coarse levels
shrink and the kernel path keeps being taken (asserted in tests).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hypergraph import (Caps, DeviceHypergraph, Neighborhoods,
                                   PairExpansion, NSENT)
from repro.utils import segops
from repro.kernels.pair_scores.kernel import pair_scores_pallas

INTERPRET = jax.default_backend() != "tpu"
# plain numpy scalars: this module is lazily imported inside jitted callers
# (`coarsen.propose`'s use_kernels branch), and a module-level jnp constant
# created during that trace would be a leaked tracer for every later
# eager caller (UnexpectedTracerError)
NBR_PAD = np.int32(-1)
TRAV_PAD = np.int32(-2)


def _round_up(x: int, m: int) -> int:
    return ((max(x, 1) + m - 1) // m) * m


def tile_bounds(caps: Caps) -> tuple[int, int]:
    u = _round_up(caps.u0, 128)
    l = _round_up(caps.l0, 128)
    return u, l


def fits_kernel(d: DeviceHypergraph, nbrs: Neighborhoods,
                pairs: PairExpansion, caps: Caps) -> jax.Array:
    """Runtime predicate: every node's U/L within the level-0 tile bounds."""
    u_bound, l_bound = tile_bounds(caps)
    ucnt = nbrs.off[1:] - nbrs.off[:-1]
    lcnt = jax.ops.segment_sum(
        pairs.valid.astype(jnp.int32),
        jnp.where(pairs.valid, jnp.clip(pairs.n, 0, caps.n - 1), caps.n),
        num_segments=caps.n + 1)[: caps.n]
    return (jnp.max(ucnt) <= u_bound) & (jnp.max(lcnt) <= l_bound)


def score_slots_kernel(d: DeviceHypergraph, nbrs: Neighborhoods,
                       pairs: PairExpansion, caps: Caps):
    """(eta[NBcap], inter[NBcap]) via the Pallas kernel."""
    U, L = tile_bounds(caps)
    npad = _round_up(caps.n, 8)

    # dense unique-neighbor slots [npad, U]
    owner = segops.rows_from_offsets(nbrs.off, caps.nbrs, caps.n)
    owner_safe = jnp.clip(owner, 0, caps.n - 1)
    s = jnp.arange(caps.nbrs, dtype=jnp.int32)
    rank_u = s - nbrs.off[owner_safe]
    live_u = (nbrs.ids != NSENT) & (owner < caps.n) & (rank_u < U)
    pos_u = jnp.where(live_u, owner_safe * U + rank_u, npad * U)
    nbr_dense = jnp.full((npad * U + 1,), NBR_PAD, jnp.int32)
    nbr_dense = nbr_dense.at[pos_u].set(nbrs.ids, mode="drop")[:-1]
    nbr_dense = nbr_dense.reshape(npad, U)

    # dense traversal [npad, L] (rank via stable sort of pair entries by n)
    pn = jnp.where(pairs.valid, pairs.n, NSENT)
    t = jnp.arange(caps.pairs, dtype=jnp.int32)
    (_, _), (perm,) = segops.sort_by([pn, t], [t])
    sn = pn[perm]
    cnts = jax.ops.segment_sum(
        jnp.ones((caps.pairs,), jnp.int32),
        jnp.where(sn == NSENT, caps.n, jnp.clip(sn, 0, caps.n - 1)),
        num_segments=caps.n + 1)[: caps.n]
    starts = segops.offsets_from_counts(cnts)[:-1]
    rank_l = t - starts[jnp.clip(sn, 0, caps.n - 1)]
    live_l = (sn != NSENT) & (rank_l < L)
    pos_l = jnp.where(live_l, jnp.clip(sn, 0, caps.n - 1) * L + rank_l,
                      npad * L)
    def scatter(vals, fill, dtype):
        out = jnp.full((npad * L + 1,), fill, dtype)
        return out.at[pos_l].set(vals[perm].astype(dtype),
                                 mode="drop")[:-1].reshape(npad, L)

    m_dense = scatter(pairs.m, TRAV_PAD, jnp.int32)
    w_dense = scatter(pairs.w_norm, 0.0, jnp.float32)
    d_dense = scatter(pairs.both_dst.astype(jnp.int32), 0, jnp.int32)

    eta_dense, inter_dense = pair_scores_pallas(
        nbr_dense, m_dense, w_dense, d_dense, tn=8,
        lc=min(128, L), interpret=INTERPRET)

    # back to slot space
    gidx = jnp.where(live_u, owner_safe * U + rank_u, 0)
    eta = jnp.where(live_u, eta_dense.reshape(-1)[gidx], 0.0)
    inter = jnp.where(live_u, inter_dense.reshape(-1)[gidx], 0)
    return eta, inter
