"""jit'd wrapper: CSR/pair-expansion -> dense node tiles -> pair_scores.

Returns (eta, inter) in *slot space* ([NBcap]) so `coarsen.propose` can use
it as a drop-in for the segment-sum path. Tile bounds (U = unique neighbors
per node, L = per-node traversal length) come from the level-0 Caps clamped
by the capacity caps; they are not guaranteed monotone under coarsening
(two merged nodes can union their neighborhoods), so the caller guards with
the runtime `fits_kernel` predicate and lax.cond-falls back to the segment
path — on real inputs coarse levels shrink and the kernel path keeps being
taken (asserted via the `kernel_path_taken` counter in tests).

Sharded mode (``ctx.axis`` set, inside ``dist.partition``'s shard_map):
``pairs`` is this shard's contiguous lane stripe of the pair expansion.
The wrapper then runs *stripe-locally over node rows*: the global traversal
order comes from the distributed sample sort (``ctx.sort_by`` — only
splitter samples gathered, bit-identical to the gathered stable sort), each
shard scatters only its contiguous ``rows_per`` row stripe of the node axis
into ``[rows_per, U]`` / ``[rows_per, L]`` tiles, runs the Pallas kernel on
its tile, and the per-shard (eta, inter) row tiles concatenate in shard
order (``ctx.gather`` — disjoint rows, exact for floats and ints alike).
Per-row kernel arithmetic is independent of tile height and the L-chunk
boundaries (lc) are mesh-independent, so the sharded kernel output is
bit-identical to the single-device kernel output. ``fits_kernel`` combines
per-stripe traversal counts with an integer psum and evaluates the *same*
static bounds on every mesh shape, so the dispatch branch taken at a level
is mesh-independent — required by the `race=False` parity contract.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hypergraph import (Caps, DeviceHypergraph, Neighborhoods,
                                   PairExpansion, NSENT)
from repro.kernels import pallas_interpret
from repro.kernels.pair_scores.kernel import pair_scores_pallas
from repro.utils import segops

# plain numpy scalars: this module is lazily imported inside jitted callers
# (`coarsen.propose`'s use_kernels branch), and a module-level jnp constant
# created during that trace would be a leaked tracer for every later
# eager caller (UnexpectedTracerError)
NBR_PAD = np.int32(-1)
TRAV_PAD = np.int32(-2)


def tile_bounds(caps: Caps) -> tuple[int, int]:
    """(U, L) static tile bounds: the level-0 per-node maxima rounded up to
    the 128-lane tile, clamped by the capacity caps (a node can never have
    more unique neighbors than `caps.nbrs` slots or more traversal entries
    than `caps.pairs` lanes). Identical on every mesh shape by design — the
    dispatch predicate must take the same branch single-device and
    sharded."""
    u = min(segops.round_up(caps.u0, 128), segops.round_up(caps.nbrs, 128))
    l = min(segops.round_up(caps.l0, 128), segops.round_up(caps.pairs, 128))
    return u, l


def stripe_rows(caps: Caps, nshards: int) -> int:
    """Rows of the node axis each shard's tile holds: ceil-divided stripe,
    rounded up to the kernel's row-tile multiple (tn = 8). With one shard
    this is the full padded row count."""
    return segops.round_up(-(-caps.n // max(nshards, 1)), 8)


def fits_kernel(d: DeviceHypergraph, nbrs: Neighborhoods,
                pairs: PairExpansion, caps: Caps,
                ctx: segops.ShardCtx = segops.ShardCtx()) -> jax.Array:
    """Runtime predicate: every node's U/L within the static tile bounds.

    Sharded mode: ``pairs`` is one lane stripe and a node's pair entries
    span stripes, so the per-stripe traversal counts MUST psum before the
    max — a per-shard max would undercount and admit rows that overflow the
    tile (silently wrong eta). The result is replicated, making it a valid
    uniform `lax.cond` predicate under shard_map."""
    u_bound, l_bound = tile_bounds(caps)
    ucnt = nbrs.off[1:] - nbrs.off[:-1]
    lcnt = ctx.psum(jax.ops.segment_sum(
        pairs.valid.astype(jnp.int32),
        jnp.where(pairs.valid, jnp.clip(pairs.n, 0, caps.n - 1), caps.n),
        num_segments=caps.n + 1))[: caps.n]
    return (jnp.max(ucnt) <= u_bound) & (jnp.max(lcnt) <= l_bound)


def score_slots_kernel(d: DeviceHypergraph, nbrs: Neighborhoods,
                       pairs: PairExpansion, caps: Caps,
                       ctx: segops.ShardCtx = segops.ShardCtx()):
    """(eta[NBcap], inter[NBcap]) via the Pallas kernel (stripe-local on a
    mesh; see module docstring for the bit-exactness argument)."""
    U, L = tile_bounds(caps)
    rows_per = stripe_rows(caps, ctx.nshards)
    nrows = rows_per * max(ctx.nshards, 1)      # padded global row space
    row_lo = ctx.index() * rows_per

    # dense unique-neighbor slots for this shard's row stripe [rows_per, U]
    # (nbrs is replicated — build_neighbors psums its dense arrays)
    owner = segops.rows_from_offsets(nbrs.off, caps.nbrs, caps.n)
    owner_safe = jnp.clip(owner, 0, caps.n - 1)
    s = jnp.arange(caps.nbrs, dtype=jnp.int32)
    rank_u = s - nbrs.off[owner_safe]
    live_u = (nbrs.ids != NSENT) & (owner < caps.n) & (rank_u < U)
    row_rel = owner_safe - row_lo
    mine_u = live_u & (row_rel >= 0) & (row_rel < rows_per)
    pos_u = jnp.where(mine_u, row_rel * U + rank_u, rows_per * U)
    nbr_dense = jnp.full((rows_per * U + 1,), NBR_PAD, jnp.int32)
    nbr_dense = nbr_dense.at[pos_u].set(nbrs.ids, mode="drop")[:-1]
    nbr_dense = nbr_dense.reshape(rows_per, U)

    # traversal in global (node, lane) order. Single device: stable sort by
    # (n, lane). Mesh: the distributed sample sort over the lane stripes,
    # replicated out — its global-rank tie key reproduces exactly the same
    # stable order, with invalid lanes (pn = NSENT) sorted past every live
    # entry in both layouts, so the live prefix is bit-identical.
    pn = jnp.where(pairs.valid, pairs.n, NSENT)
    dst = pairs.both_dst.astype(jnp.int32)
    if ctx.axis is None:
        t = jnp.arange(caps.pairs, dtype=jnp.int32)
        (sn, _), (m_s, w_s, dd_s) = segops.sort_by(
            [pn, t], [pairs.m, pairs.w_norm, dst])
    else:
        (sn,), (m_s, w_s, dd_s) = ctx.sort_by(
            [pn], [pairs.m, pairs.w_norm, dst],
            striped_in=True, striped_out=False)

    total = sn.shape[0]
    t2 = jnp.arange(total, dtype=jnp.int32)
    sn_safe = jnp.clip(sn, 0, caps.n - 1)
    cnts = jax.ops.segment_sum(
        jnp.ones((total,), jnp.int32),
        jnp.where(sn == NSENT, caps.n, sn_safe),
        num_segments=caps.n + 1)[: caps.n]
    starts = segops.offsets_from_counts(cnts)[:-1]
    rank_l = t2 - starts[sn_safe]
    row_rel_l = sn_safe - row_lo
    live_l = (sn != NSENT) & (rank_l < L)
    mine_l = live_l & (row_rel_l >= 0) & (row_rel_l < rows_per)
    pos_l = jnp.where(mine_l, row_rel_l * L + rank_l, rows_per * L)

    def scatter(vals, fill, dtype):
        out = jnp.full((rows_per * L + 1,), fill, dtype)
        return out.at[pos_l].set(vals.astype(dtype),
                                 mode="drop")[:-1].reshape(rows_per, L)

    m_dense = scatter(m_s, TRAV_PAD, jnp.int32)
    w_dense = scatter(w_s, 0.0, jnp.float32)
    d_dense = scatter(dd_s, 0, jnp.int32)

    eta_tile, inter_tile = pair_scores_pallas(
        nbr_dense, m_dense, w_dense, d_dense, tn=8,
        lc=min(128, L), interpret=pallas_interpret())

    # row stripes are disjoint: shard-order concat is the exact combine for
    # the float eta tiles and the int inter tiles alike
    eta_dense = ctx.gather(eta_tile)            # [nrows, U]
    inter_dense = ctx.gather(inter_tile)

    # back to slot space (replicated; owner_safe < caps.n <= nrows)
    gidx = jnp.where(live_u, owner_safe * U + rank_u, 0)
    eta = jnp.where(live_u, eta_dense.reshape(-1)[gidx], 0.0)
    inter = jnp.where(live_u, inter_dense.reshape(-1)[gidx], 0)
    return eta, inter
