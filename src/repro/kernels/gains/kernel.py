"""Refinement gain kernel: conn_w[n, p] = sum_{e in I(n)} w(e)*[pins(p,e)>0].

CUDA original (Sec. VI-B): a warp per node allocates one gain variable per
partition in shared memory and streams the node's incident h-edges, reading
pins(p, e) columns. TPU redesign: the irregular gather of pins columns is
expressed with a *scalar-prefetched* grid — the node incidence list (edge
ids) is prefetched into SMEM and drives the BlockSpec index_map, so the
pins-matrix row for edge e = inc[n, j] is DMA-streamed from HBM while the
previous column accumulates. This is the idiomatic TPU analogue of the
paper's warp-sequential incident-edge loop (span = h), with the partition
axis vectorized across lanes.

  grid     = (N, H)                      (node-major, incidence-minor)
  inc      : int32[N*H] scalar-prefetch  (edge id per slot; pad -> row 0)
  w        : f32[N, H]   block (1, 1)    (pad slots carry w = 0)
  pins_nz  : f32[E, K]   block (1, K)    idx (i, j) -> (inc[i*H+j], 0)
  conn     : f32[N, K]   block (1, K)    idx (i, j) -> (i, 0)   (accum)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gains_kernel(inc_ref, w_ref, pins_ref, conn_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        conn_ref[...] = jnp.zeros_like(conn_ref)

    conn_ref[...] += w_ref[0, 0] * pins_ref[...]


@functools.partial(jax.jit, static_argnames=("h", "interpret"))
def gains_pallas(inc: jax.Array, w: jax.Array, pins_nz: jax.Array,
                 h: int, interpret: bool = True):
    """inc: [N*H] int32 edge ids (pad slots -> 0 with w 0). w: [N, H] f32.
    pins_nz: [E, K] f32 (1.0 where pins(p,e) > 0). Returns conn [N, K]."""
    n = w.shape[0]
    e, k = pins_nz.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n, h),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, inc_ref: (i, j)),
            pl.BlockSpec((1, k), lambda i, j, inc_ref: (inc_ref[i * h + j], 0)),
        ],
        out_specs=pl.BlockSpec((1, k), lambda i, j, inc_ref: (i, 0)),
    )
    return pl.pallas_call(
        _gains_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        interpret=interpret,
    )(inc, w, pins_nz)
