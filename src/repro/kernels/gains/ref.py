"""Pure-jnp oracle for the gains kernel."""
from __future__ import annotations

import jax.numpy as jnp


def gains_ref(inc, w, pins_nz, h: int):
    """inc [N*H] edge ids, w [N,H], pins_nz [E,K]. conn[n,k] =
    sum_j w[n,j] * pins_nz[inc[n,j], k]."""
    n = w.shape[0]
    cols = pins_nz[inc.reshape(n, h)]        # [N, H, K]
    return jnp.sum(w[:, :, None] * cols, axis=1).astype(jnp.float32)
