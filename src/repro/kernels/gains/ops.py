"""jit'd wrapper: node incidence CSR + pins matrix -> gains kernel.

Drop-in for the conn_w computation in `refine.propose_moves`. The incidence
tile bound H comes from level-0 Caps (same fallback contract as
pair_scores/ops.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.hypergraph import Caps, DeviceHypergraph
from repro.utils import segops
from repro.kernels.gains.kernel import gains_pallas

INTERPRET = jax.default_backend() != "tpu"


def _round_up(x: int, m: int) -> int:
    return ((max(x, 1) + m - 1) // m) * m


def h_bound(caps: Caps) -> int:
    return _round_up(caps.h0, 8)


def fits_kernel(d: DeviceHypergraph, caps: Caps) -> jax.Array:
    deg = d.node_off[1:] - d.node_off[:-1]
    ids = jnp.arange(caps.n)
    return jnp.max(jnp.where(ids < d.n_nodes, deg, 0)) <= h_bound(caps)


def conn_weights(d: DeviceHypergraph, parts: jax.Array, pins: jax.Array,
                 caps: Caps, kcap: int):
    """conn_w[n, p] = sum_{e in I(n)} w(e) * [pins(p, e) > 0], [Ncap, kcap]."""
    H = h_bound(caps)
    npad = _round_up(caps.n, 8)
    t = jnp.arange(caps.p, dtype=jnp.int32)
    live = t < d.n_pins
    n_of = segops.rows_from_offsets(d.node_off, caps.p, caps.n)
    n_safe = jnp.clip(n_of, 0, caps.n - 1)
    rank = t - d.node_off[n_safe]
    ok = live & (n_of < caps.n) & (rank < H)
    pos = jnp.where(ok, n_safe * H + rank, npad * H)
    e_ids = jnp.clip(d.node_edges, 0, caps.e - 1)
    inc = jnp.zeros((npad * H + 1,), jnp.int32).at[pos].set(
        e_ids, mode="drop")[:-1]
    w = jnp.zeros((npad * H + 1,), jnp.float32).at[pos].set(
        jnp.where(live, d.edge_w[e_ids], 0.0), mode="drop")[:-1]
    w = w.reshape(npad, H)
    pins_nz = (pins > 0).astype(jnp.float32).T  # [Ecap, kcap]
    conn = gains_pallas(inc, w, pins_nz, h=H, interpret=INTERPRET)
    return conn[: caps.n]
