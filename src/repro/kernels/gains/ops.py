"""jit'd wrapper: node incidence CSR + pins matrix -> gains kernel.

Drop-in for the conn_w computation in `refine.propose_moves`. The incidence
tile bound H comes from level-0 Caps clamped by the capacity caps (same
fallback contract as pair_scores/ops.py).

Sharded mode (``ctx.axis`` set): the incidence scatter runs over this
shard's pin-lane stripe (``ctx.lanes``/``gread`` — ``node_edges`` may be
striped storage), the disjoint integer scatters psum into the replicated
dense incidence tile, and each shard runs the kernel only on its contiguous
``rows_per`` row block of the node axis; the per-shard conn row tiles
concatenate in shard order (``ctx.gather`` — disjoint rows, exact for
floats). Per-row kernel arithmetic is independent of tile height, so the
sharded output is bit-identical to the single-device kernel output.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.hypergraph import Caps, DeviceHypergraph
from repro.kernels import pallas_interpret
from repro.kernels.gains.kernel import gains_pallas
from repro.utils import segops


def h_bound(caps: Caps) -> int:
    """Static incidence tile width: the level-0 max node degree rounded up
    to the 8-row tile, clamped by the pin capacity (a node can never be
    incident to more slots than ``caps.p`` pin lanes). Mesh-independent by
    design — see the dispatch contract in ``repro.kernels``."""
    return min(segops.round_up(caps.h0, 8), segops.round_up(caps.p, 8))


def stripe_rows(caps: Caps, nshards: int) -> int:
    """Node rows per shard tile (ceil-divided stripe, 8-row multiple)."""
    return segops.round_up(-(-caps.n // max(nshards, 1)), 8)


def fits_kernel(d: DeviceHypergraph, caps: Caps) -> jax.Array:
    """Runtime predicate: every node's incidence degree fits ``h_bound``.
    ``node_off`` is replicated even under a mesh, so no combine is needed
    and the result is a valid uniform `lax.cond` predicate."""
    deg = d.node_off[1:] - d.node_off[:-1]
    ids = jnp.arange(caps.n)
    return jnp.max(jnp.where(ids < d.n_nodes, deg, 0)) <= h_bound(caps)


def conn_weights(d: DeviceHypergraph, parts: jax.Array, pins: jax.Array,
                 caps: Caps, kcap: int,
                 ctx: segops.ShardCtx = segops.ShardCtx()):
    """conn_w[n, p] = sum_{e in I(n)} w(e) * [pins(p, e) > 0], [Ncap, kcap]
    (stripe-local on a mesh; see module docstring)."""
    H = h_bound(caps)
    rows_per = stripe_rows(caps, ctx.nshards)
    nrows = rows_per * max(ctx.nshards, 1)
    t, t_ok = ctx.lanes(caps.p)
    live = t_ok & (t < d.n_pins)
    n_of = ctx.rows(d.node_off, t, caps.p, caps.n)
    n_safe = jnp.clip(n_of, 0, caps.n - 1)
    rank = t - d.node_off[n_safe]
    ok = live & (n_of < caps.n) & (rank < H)
    pos = jnp.where(ok, n_safe * H + rank, nrows * H)
    e_ids = jnp.clip(ctx.gread(d.node_edges, t, live, 0), 0, caps.e - 1)
    # disjoint integer scatters (each global pin lane lives on exactly one
    # shard) -> the psum combine is exact; the float weight column is then
    # gathered replicated from the combined incidence, never psum'd
    inc = ctx.psum(jnp.zeros((nrows * H + 1,), jnp.int32).at[pos].set(
        e_ids, mode="drop")[:-1])
    flag = ctx.psum(jnp.zeros((nrows * H + 1,), jnp.int32).at[pos].set(
        jnp.where(ok, 1, 0), mode="drop")[:-1])
    w = jnp.where(flag > 0, d.edge_w[inc], 0.0)
    inc_own = ctx.stripe(inc.reshape(nrows, H)).reshape(-1)
    w_own = ctx.stripe(w.reshape(nrows, H))
    pins_nz = (pins > 0).astype(jnp.float32).T  # [Ecap, kcap]
    conn_tile = gains_pallas(inc_own, w_own, pins_nz, h=H,
                             interpret=pallas_interpret())
    return ctx.gather(conn_tile)[: caps.n]
