"""Flash attention Pallas kernel — the M-series §Perf follow-up.

The pure-XLA chunked attention (models/layers.flash_attention) streams
q_chunk x k_chunk score blocks through HBM at fusion boundaries; the
roofline shows that traffic dominating every *_32k cell. This kernel keeps
the online-softmax state and the score block in VMEM — HBM traffic drops to
q/k/v/o (+small m/l side outputs), the fused-kernel ideal.

Layout: MHA [BH, S, D] (the ops wrapper expands GQA groups). Grid
(BH, nq, nk), k-chunks innermost; the output block and the running max /
denominator revisit across the k dimension and accumulate in place
(same grid-accumulation idiom as kernels/pins_count). Final normalization
(acc / l) happens outside — it fuses with the caller's projection.

  q   : [BH, S, D]  block (1, qc, D) idx (b, i, 0->i)
  k,v : [BH, S, D]  block (1, kc, D) idx (b, j)
  acc : f32[BH, S, D]  block (1, qc, D) idx (b, i)   (accumulated)
  m,l : f32[BH, S]     block (1, qc)   idx (b, i)    (running max / denom)
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30  # python float: jnp constants would be captured by the kernel


def _flash_kernel(q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, qc: int, kc: int):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]                                    # [qc, D]
    k = k_ref[0]                                    # [kc, D]
    s = jnp.dot(q.astype(jnp.float32), k.astype(jnp.float32).T) * scale
    if causal:
        qpos = i * qc + jax.lax.broadcasted_iota(jnp.int32, (qc, kc), 0)
        kpos = j * kc + jax.lax.broadcasted_iota(jnp.int32, (qc, kc), 1)
        s = jnp.where(qpos >= kpos, s, NEG)

    m_prev = m_ref[0]                               # [qc]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[0] = l_ref[0] * corr + jnp.sum(p, axis=1)
    acc_ref[0] = (acc_ref[0] * corr[:, None]
                  + jnp.dot(p, v_ref[0].astype(jnp.float32)))
    m_ref[0] = m_new


@functools.partial(jax.jit,
                   static_argnames=("causal", "qc", "kc", "scale",
                                    "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True, qc: int = 128,
                           kc: int = 128, scale: float | None = None,
                           interpret: bool = True):
    """q/k/v: [BH, S, D]. Returns [BH, S, D] (same dtype as q)."""
    bh, s, d = q.shape
    qc = math.gcd(min(qc, s), s)
    kc = math.gcd(min(kc, s), s)
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    grid = (bh, s // qc, s // kc)
    kern = functools.partial(_flash_kernel, scale=scale, causal=causal,
                             qc=qc, kc=kc)
    acc, m, l = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, qc, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, kc, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, kc, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, qc, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, qc), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, qc), lambda b, i, j: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, d), jnp.float32),
            jax.ShapeDtypeStruct((bh, s), jnp.float32),
            jax.ShapeDtypeStruct((bh, s), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
