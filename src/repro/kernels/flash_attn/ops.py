"""GQA-aware wrapper: [B,S,H,Dh] x [B,S,KV,Dh] -> kernel MHA layout."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import pallas_interpret
from repro.kernels.flash_attn.kernel import flash_attention_pallas


def flash_attention_gqa(q, k, v, *, causal: bool = True, qc: int = 128,
                        kc: int = 128, scale: float | None = None):
    """q [B,S,H,Dh], k/v [B,S,KV,Dh] -> [B,S,H,Dh]."""
    b, s, h, dh = q.shape
    kv = k.shape[2]
    g = h // kv
    qm = jnp.transpose(q, (0, 2, 1, 3)).reshape(b * h, s, dh)
    krep = jnp.repeat(k, g, axis=2)
    vrep = jnp.repeat(v, g, axis=2)
    km = jnp.transpose(krep, (0, 2, 1, 3)).reshape(b * h, s, dh)
    vm = jnp.transpose(vrep, (0, 2, 1, 3)).reshape(b * h, s, dh)
    out = flash_attention_pallas(qm, km, vm, causal=causal, qc=qc, kc=kc,
                                 scale=scale, interpret=pallas_interpret())
    return jnp.transpose(out.reshape(b, h, s, dh), (0, 2, 1, 3))
