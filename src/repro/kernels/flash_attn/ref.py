"""Pure-jnp oracle for the flash_attn kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        scale: float | None = None):
    """q/k/v: [BH, S, D]."""
    bh, s_len, d = q.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s_len, s_len), bool))
        s = jnp.where(mask[None], s, -jnp.inf)
    a = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", a,
                      v.astype(jnp.float32)).astype(q.dtype)
