"""Pure-jnp oracle for the pins_count kernel."""
from __future__ import annotations

import jax.numpy as jnp


def pins_count_ref(parts_dense, dst_dense, kdim: int):
    """parts_dense: [E, dbar] int32 (>= kdim == padding). Returns
    (pins[E, kdim], pins_in[E, kdim]) int32."""
    onehot = parts_dense[:, :, None] == jnp.arange(kdim, dtype=jnp.int32)
    pins = jnp.sum(onehot, axis=1, dtype=jnp.int32)
    pins_in = jnp.sum(onehot & (dst_dense[:, :, None] != 0), axis=1,
                      dtype=jnp.int32)
    return pins, pins_in
