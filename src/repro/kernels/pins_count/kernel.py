"""pins(p, e) matrix kernel: per-edge partition pin counts.

CUDA original (paper Sec. VI-B): a warp per h-edge, one shared-memory
counter per partition, threads atomically increment counters after mapping
each pin through rho. TPU redesign: no atomics/scratchpad scatter — instead
a one-hot compare+reduce over VMEM tiles. The grid walks (edge tiles x
cardinality chunks); the output block for an edge tile is revisited across
the cardinality chunks (TPU grids iterate sequentially), accumulating in
place, so arbitrarily large cardinalities stream through a fixed VMEM
working set:

  grid  = (E/TE, dbar/DC)
  parts = int32[E, dbar]   partition id per (edge, pin slot), K = padding
  out   = int32[E, K]      pins / pins_in counts

Block shapes: parts (TE, DC), out (TE, K); VMEM working set is the one-hot
compare tile (TE, DC, K) held in vector registers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pins_kernel(parts_ref, dst_ref, pins_ref, pins_in_ref, *, kdim: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        pins_ref[...] = jnp.zeros_like(pins_ref)
        pins_in_ref[...] = jnp.zeros_like(pins_in_ref)

    parts = parts_ref[...]                       # [TE, DC] int32
    dst = dst_ref[...]                           # [TE, DC] int32 (0/1)
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (1, 1, kdim), 2)
    onehot = (parts[:, :, None] == iota_k).astype(jnp.int32)   # [TE, DC, K]
    pins_ref[...] += jnp.sum(onehot, axis=1)
    pins_in_ref[...] += jnp.sum(onehot * dst[:, :, None], axis=1)


@functools.partial(jax.jit, static_argnames=("kdim", "te", "dc", "interpret"))
def pins_count_pallas(parts_dense: jax.Array, dst_dense: jax.Array,
                      kdim: int, te: int = 8, dc: int = 128,
                      interpret: bool = True):
    """parts_dense/dst_dense: [E, dbar] (padding lanes must carry part id >=
    kdim so the one-hot drops them). Returns (pins, pins_in): [E, kdim]."""
    e, dbar = parts_dense.shape
    assert e % te == 0 and dbar % dc == 0, (e, dbar, te, dc)
    grid = (e // te, dbar // dc)
    kernel = functools.partial(_pins_kernel, kdim=kdim)
    out_shape = [jax.ShapeDtypeStruct((e, kdim), jnp.int32)] * 2
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((te, dc), lambda i, j: (i, j)),
            pl.BlockSpec((te, dc), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((te, kdim), lambda i, j: (i, 0)),
            pl.BlockSpec((te, kdim), lambda i, j: (i, 0)),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(parts_dense, dst_dense.astype(jnp.int32))
