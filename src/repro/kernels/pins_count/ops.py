"""jit'd wrapper: CSR hypergraph -> dense tiles -> pins_count kernel.

Produces the same [kcap, Ecap] pins / pins_in matrices as the pure-JAX
`repro.core.refine.pins_matrix`, routing the counting through the Pallas
kernel. Densification (CSR -> [E, dbar]) is a cheap scatter; dbar is bounded
by Caps.d_max, which is monotone non-increasing under coarsening, so one
static shape serves the whole run.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.hypergraph import Caps, DeviceHypergraph
from repro.kernels import pallas_interpret
from repro.kernels.pins_count.kernel import pins_count_pallas
from repro.utils import segops


def densify_edges(d: DeviceHypergraph, parts: jax.Array, caps: Caps,
                  kcap: int, dbar: int):
    """[Ecap_pad, dbar] partition id per (edge, slot); padding = kcap."""
    t = jnp.arange(caps.p, dtype=jnp.int32)
    live = t < d.n_pins
    e_of = segops.rows_from_offsets(d.edge_off, caps.p, caps.e)
    e_safe = jnp.clip(e_of, 0, caps.e - 1)
    rel = t - d.edge_off[e_safe]
    pin = jnp.clip(d.edge_pins, 0, caps.n - 1)
    p_of = parts[pin]
    is_dst = live & (rel >= d.edge_nsrc[e_safe])
    epad = segops.round_up(caps.e, 8)
    flat_pos = jnp.where(live & (rel < dbar), e_safe * dbar + rel,
                         epad * dbar)
    parts_dense = jnp.full((epad * dbar + 1,), kcap, jnp.int32)
    parts_dense = parts_dense.at[flat_pos].set(jnp.where(live, p_of, kcap),
                                               mode="drop")
    dst_dense = jnp.zeros((epad * dbar + 1,), jnp.int32)
    dst_dense = dst_dense.at[flat_pos].set(is_dst.astype(jnp.int32),
                                           mode="drop")
    return (parts_dense[:-1].reshape(epad, dbar),
            dst_dense[:-1].reshape(epad, dbar))


@partial(jax.jit, static_argnames=("caps", "kcap"))
def pins_matrix_kernel(d: DeviceHypergraph, parts: jax.Array, caps: Caps,
                       kcap: int):
    """Drop-in replacement for refine.pins_matrix via the Pallas kernel."""
    dc = min(128, segops.round_up(caps.d_max, 8))
    dbar = segops.round_up(caps.d_max, dc)
    parts_dense, dst_dense = densify_edges(d, parts, caps, kcap, dbar)
    kdim = max(kcap, 8)
    pins, pins_in = pins_count_pallas(parts_dense, dst_dense, kdim,
                                      te=8, dc=dc,
                                      interpret=pallas_interpret())
    pins = pins[: caps.e, :kcap].T
    pins_in = pins_in[: caps.e, :kcap].T
    return pins, pins_in
