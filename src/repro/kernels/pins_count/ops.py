"""Wrapper: CSR hypergraph -> dense tiles -> pins_count kernel.

Produces the same [kcap, Ecap] pins / pins_in matrices as the pure-JAX
`repro.core.refine.pins_matrix`, routing the counting through the Pallas
kernel. Densification (CSR -> [E, dbar]) is a cheap scatter; dbar is bounded
by Caps.d_max, which is monotone non-increasing under coarsening, so one
static shape serves a whole cold run. (Incremental deltas can break that
monotonicity — an inserted edge may exceed the stale ``d_max`` — which is
exactly what the runtime ``fits_kernel`` predicate guards: oversized edges
fall back to the segment path instead of silently truncating.)

Sharded mode (``ctx.axis`` set, inside ``dist.partition``'s shard_map —
same pattern as the `gains`/`pair_scores` wrappers): the densifying scatter
runs over this shard's pin-lane stripe (``ctx.lanes``/``gread`` —
``edge_pins`` may be striped storage), the disjoint integer scatters psum
into the replicated dense [Erows, dbar] tiles, and each shard runs the
kernel only on its contiguous ``rows_per`` row block of the edge axis; the
per-shard count tiles concatenate in shard order (``ctx.gather`` — disjoint
rows, exact). Per-row kernel arithmetic is independent of tile height, so
the sharded output is bit-identical to the single-device kernel output,
which remains the ``ctx=None`` degenerate case of the same code path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.hypergraph import Caps, DeviceHypergraph
from repro.kernels import pallas_interpret
from repro.kernels.pins_count.kernel import pins_count_pallas
from repro.utils import segops


def tile_bounds(caps: Caps) -> tuple[int, int]:
    """(dbar, dc): static per-edge slot width (cardinality bound rounded to
    the column tile) and the column tile size. Mesh-independent by design —
    see the dispatch contract in ``repro.kernels``."""
    dc = min(128, segops.round_up(caps.d_max, 8))
    return segops.round_up(caps.d_max, dc), dc


def stripe_rows(caps: Caps, nshards: int) -> int:
    """Edge rows per shard tile (ceil-divided stripe, 8-row multiple —
    te=8 is the kernel's row tile)."""
    return segops.round_up(-(-caps.e // max(nshards, 1)), 8)


def fits_kernel(d: DeviceHypergraph, caps: Caps) -> jax.Array:
    """Runtime predicate: every live edge's cardinality fits the static
    ``dbar`` slot width, so densification drops no pin. ``edge_off`` is
    replicated even under a mesh, so no combine is needed and the result is
    a valid uniform `lax.cond` predicate. Always true on a cold run
    (``dbar >= caps.d_max`` by construction); can go false after
    incremental deltas insert an edge wider than the stale bound."""
    dbar, _ = tile_bounds(caps)
    card = d.edge_off[1:] - d.edge_off[:-1]
    ids = jnp.arange(caps.e)
    return jnp.max(jnp.where(ids < d.n_edges, card, 0)) <= dbar


def pins_matrix_kernel(d: DeviceHypergraph, parts: jax.Array, caps: Caps,
                       kcap: int,
                       ctx: segops.ShardCtx = segops.ShardCtx()):
    """Drop-in replacement for refine.pins_matrix via the Pallas kernel
    (stripe-local on a mesh; see module docstring). Callers jit (it runs
    inside ``refine_step`` / the shard_map'd dist step), so the wrapper
    itself stays a plain function — ``ShardCtx`` is not a hashable static."""
    dbar, dc = tile_bounds(caps)
    rows_per = stripe_rows(caps, ctx.nshards)
    erows = rows_per * max(ctx.nshards, 1)
    t, t_ok = ctx.lanes(caps.p)
    live = t_ok & (t < d.n_pins)
    e_of = ctx.rows(d.edge_off, t, caps.p, caps.e)
    e_safe = jnp.clip(e_of, 0, caps.e - 1)
    rel = t - d.edge_off[e_safe]
    pin = jnp.clip(ctx.gread(d.edge_pins, t, live, 0), 0, caps.n - 1)
    p_of = parts[pin]
    is_dst = live & (rel >= d.edge_nsrc[e_safe])
    ok = live & (rel < dbar)
    pos = jnp.where(ok, e_safe * dbar + rel, erows * dbar)
    # disjoint integer scatters (each global pin lane lives on exactly one
    # shard) -> the psum combine is exact. Partition ids scatter as p+1
    # over a zeros base so unwritten slots read 0 = padding (mapped to the
    # out-of-range id kcap below), matching the single-device densify fill.
    pd = ctx.psum(jnp.zeros((erows * dbar + 1,), jnp.int32).at[pos].set(
        jnp.where(ok, p_of + 1, 0), mode="drop")[:-1])
    dd = ctx.psum(jnp.zeros((erows * dbar + 1,), jnp.int32).at[pos].set(
        is_dst.astype(jnp.int32), mode="drop")[:-1])
    parts_dense = jnp.where(pd > 0, pd - 1, kcap).reshape(erows, dbar)
    dst_dense = dd.reshape(erows, dbar)
    own_p = ctx.stripe(parts_dense)
    own_d = ctx.stripe(dst_dense)
    kdim = max(kcap, 8)
    pins, pins_in = pins_count_pallas(own_p, own_d, kdim, te=8, dc=dc,
                                      interpret=pallas_interpret())
    pins = ctx.gather(pins)[: caps.e, :kcap].T
    pins_in = ctx.gather(pins_in)[: caps.e, :kcap].T
    return pins, pins_in
