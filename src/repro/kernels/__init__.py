"""Pallas TPU kernels for the partitioner's compute hot spots.

The paper's dominant kernels (Fig. 8) are the two neighborhood traversals:
candidate-pairs proposal (coarsening) and refinement gain calculation, plus
the pins(p,e) matrix precomputation that feeds the latter. Each kernel here
is the TPU-native redesign of the corresponding CUDA kernel:

  pins_count  — shared-memory atomic counters      -> one-hot compare+reduce
                over VMEM tiles, grid-accumulated across cardinality chunks.
  pair_scores — warp shared-memory histogram with
                per-pin binary search (Fig. 3)      -> dense equality-matmul:
                eta[t,u] = sum_l w[t,l] * (trav[t,l] == nbr[t,u]), with the
                inter() counter accumulated from a dst-flag plane in the
                same pass (the paper's in-histogram constraint tracking).
  gains       — warp-per-node gain loops over the
                pins matrix                         -> scalar-prefetch grid:
                the incidence list is prefetched and drives the BlockSpec
                index_map that streams pins-matrix columns from HBM.
  flash_attn  — framework-side hot spot (EXPERIMENTS.md SPerf M-series):
                online-softmax attention with the score block and running
                max/denominator resident in VMEM, grid-accumulated over
                key chunks; HBM traffic collapses to q/k/v/o.

Each subpackage ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper + padding/layout glue) and ref.py (pure-jnp oracle). All kernels
validate in interpret mode on CPU; tests sweep shapes and dtypes against
the oracles.
"""
