"""Pallas TPU kernels for the partitioner's compute hot spots.

The paper's dominant kernels (Fig. 8) are the two neighborhood traversals:
candidate-pairs proposal (coarsening) and refinement gain calculation, plus
the pins(p,e) matrix precomputation that feeds the latter. Each kernel here
is the TPU-native redesign of the corresponding CUDA kernel:

  pins_count  — shared-memory atomic counters      -> one-hot compare+reduce
                over VMEM tiles, grid-accumulated across cardinality chunks.
  pair_scores — warp shared-memory histogram with
                per-pin binary search (Fig. 3)      -> dense equality-matmul:
                eta[t,u] = sum_l w[t,l] * (trav[t,l] == nbr[t,u]), with the
                inter() counter accumulated from a dst-flag plane in the
                same pass (the paper's in-histogram constraint tracking).
  gains       — warp-per-node gain loops over the
                pins matrix                         -> scalar-prefetch grid:
                the incidence list is prefetched and drives the BlockSpec
                index_map that streams pins-matrix columns from HBM.
  flash_attn  — framework-side hot spot (EXPERIMENTS.md SPerf M-series):
                online-softmax attention with the score block and running
                max/denominator resident in VMEM, grid-accumulated over
                key chunks; HBM traffic collapses to q/k/v/o.

Each subpackage ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper + padding/layout glue) and ref.py (pure-jnp oracle). All kernels
validate in interpret mode on CPU; tests sweep shapes and dtypes against
the oracles.

Dispatch contract
-----------------

The kernels are *routed*, never called unconditionally. With
``use_kernels=True`` the hot-loop call sites (``coarsen.propose`` for
``pair_scores``, ``refine.propose_moves`` for ``gains``,
``refine.refine_step_impl`` for ``pins_count``) dispatch through a runtime
``fits_kernel`` predicate under ``lax.cond``:

* **kernel branch** — taken when every node's live extent fits the static
  tile bounds (``tile_bounds`` / ``h_bound``, derived from the level-0
  ``Caps`` statistics, clamped by the capacity caps). Tile bounds are not
  monotone under coarsening (merged nodes union their neighborhoods), so
  coarse levels may legitimately outgrow them.
* **fallback branch** — the pure-XLA segment pipeline
  (``coarsen.score_slots`` / the ``_conn_segments`` closure), bit-identical
  to the ``use_kernels=False`` path. Falling back is silent at the
  arithmetic level but *not* at the accounting level: every dispatch
  reports a ``kernel_path_taken`` flag (the cond predicate as int32),
  aggregated per level into ``PartitionResult.kernel_path`` so tests and
  benchmarks assert coverage instead of trusting the routing.

Sharded mode: the ``pair_scores`` and ``gains`` wrappers accept a
``segops.ShardCtx`` and then run *stripe-locally* under ``shard_map`` —
each shard builds dense tiles only for its contiguous row stripe of the
node axis, runs the kernel on its tile, and the row stripes concatenate in
shard order (``ctx.gather`` — disjoint rows, so the combine is exact for
floats and ints alike). Per-row kernel arithmetic is independent of the
tile height and identical across mesh shapes, so the sharded kernel output
is bit-identical to the single-device kernel output. The ``fits_kernel``
predicates combine per-stripe counts with integer psums and use the *same*
static bounds on every mesh shape, so the cond branch taken at a given
level is mesh-independent — the invariant the ``race=False`` bit-exact
parity contract of ``dist.partition`` relies on.

Interpret policy
----------------

``pallas_interpret()`` below decides compiled-vs-interpret per trace:
compiled on any accelerator backend (TPU/GPU), interpret only when no
accelerator is present (CPU has no compiled Pallas path). The
``REPRO_PALLAS_INTERPRET`` env var overrides: ``1`` forces interpret
everywhere (debugging on accelerators), ``0`` asserts the compiled path on
accelerators and is a documented no-op on CPU. The policy is read at trace
time, so flip it before the first kernel call of a process (jit caches
traces).
"""
from __future__ import annotations

import os

import jax

_ACCEL_BACKENDS = ("tpu", "gpu", "cuda", "rocm")


def pallas_interpret() -> bool:
    """Whether pallas_call should run in interpret mode for this process.

    Default: interpret only when no accelerator backend is present —
    ``jax.default_backend()`` in ``("tpu", "gpu", "cuda", "rocm")`` compiles
    (the old ``backend != "tpu"`` policy silently paid interpret-mode
    overhead on every GPU kernel call). ``REPRO_PALLAS_INTERPRET=1`` forces
    interpret mode everywhere; ``=0`` requests the compiled path, which on
    CPU still degrades to interpret (jax raises "Only interpret mode is
    supported on CPU backend" otherwise), so host CI can exercise both
    override values safely. Evaluated at trace time — set the env var
    before the first kernel call of the process.
    """
    env = os.environ.get("REPRO_PALLAS_INTERPRET", "")
    accel = jax.default_backend() in _ACCEL_BACKENDS
    if env not in ("", None):
        if env in ("0", "false", "False"):
            return not accel  # CPU has no compiled Pallas path
        return True
    return not accel
