"""AdamW with mixed-precision master weights, from scratch (no optax).

TrainState layout (bytes/param): bf16 compute params (2) + fp32 master (4)
+ fp32 mu (4) + fp32 nu (4) = 14 — the standard large-model footprint; all
four shard identically (FSDP over the DP axes + TP over "model"), which is
what lets deepseek-v2-236b fit 16 GB/chip on the 512-chip mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import Spec, tree_map_specs


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any      # bf16 compute params
    master: Any      # fp32 master copy
    mu: Any          # fp32 first moment
    nu: Any          # fp32 second moment
    step: jax.Array  # int32


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr_peak: float = 3e-4
    warmup: int = 200
    total_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def state_shapes(param_specs) -> TrainState:
    """Spec tree for the full TrainState (for shardings / dry-run)."""
    zero = lambda s: Spec(s.shape, s.axes, init="zeros")
    return TrainState(
        params=param_specs,
        master=tree_map_specs(zero, param_specs),
        mu=tree_map_specs(zero, param_specs),
        nu=tree_map_specs(zero, param_specs),
        step=Spec((), (), init="zeros"),
    )


def init_state(params) -> TrainState:
    f32 = lambda t: jax.tree.map(lambda x: x.astype(jnp.float32), t)
    zeros = lambda t: jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), t)
    return TrainState(params=jax.tree.map(lambda x: x.astype(jnp.bfloat16),
                                          params),
                      master=f32(params), mu=zeros(params), nu=zeros(params),
                      step=jnp.int32(0))


def abstract_state(param_specs, compute_dtype=jnp.bfloat16) -> TrainState:
    from repro.models.common import abstracts
    ss = state_shapes(param_specs)
    return TrainState(
        params=abstracts(ss.params, compute_dtype),
        master=abstracts(ss.master, jnp.float32),
        mu=abstracts(ss.mu, jnp.float32),
        nu=abstracts(ss.nu, jnp.float32),
        step=jax.ShapeDtypeStruct((), jnp.int32),
    )


def lr_schedule(step, cfg: OptConfig):
    warm = cfg.lr_peak * (step + 1) / max(cfg.warmup, 1)
    prog = jnp.clip((step - cfg.warmup)
                    / max(cfg.total_steps - cfg.warmup, 1), 0.0, 1.0)
    cos = cfg.lr_peak * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup, warm, cos).astype(jnp.float32)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def adamw_update(state: TrainState, grads, cfg: OptConfig) -> TrainState:
    """grads: same tree as params (any float dtype; upcast here)."""
    step = state.step + 1
    lr = lr_schedule(step, cfg)
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, w):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        w = w - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                      + cfg.weight_decay * w)
        return m, v, w

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_w = treedef.flatten_up_to(state.master)
    new = [upd(g, m, v, w) for g, m, v, w in
           zip(flat_g, flat_m, flat_v, flat_w)]
    mu = jax.tree_util.tree_unflatten(treedef, [t[0] for t in new])
    nu = jax.tree_util.tree_unflatten(treedef, [t[1] for t in new])
    master = jax.tree_util.tree_unflatten(treedef, [t[2] for t in new])
    params = jax.tree.map(
        lambda w, p: w.astype(p.dtype), master, state.params)
    return TrainState(params=params, master=master, mu=mu, nu=nu, step=step)
