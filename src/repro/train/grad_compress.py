"""Gradient compression for the data-parallel all-reduce, with error
feedback — a distributed-optimization trick for bandwidth-bound multi-pod
training (the cross-pod DCN axis is the slow link).

The DP gradient sync normally rides implicitly on XLA's SPMD partitioner
(psum of bf16/f32 grads). This module provides an explicit shard_map
alternative: grads are quantized shard-locally to int8 with a per-tensor
scale, all-reduced in low precision, dequantized, and the quantization
residual is carried as error-feedback state so the compression bias
vanishes over steps (1-bit-Adam-style convergence behaviour).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import shard_map


def quantize_int8(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum_grads(grads, residuals, mesh, axes=("data",)):
    """All-reduce `grads` over `axes` in int8 with error feedback.

    grads/residuals: pytrees of replicated-over-`axes`... in SPMD practice
    the per-shard grads live inside shard_map; here we expose the functional
    core so both the shard_map path and unit tests share it.
    Returns (synced_grads, new_residuals).
    """
    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q, scale = quantize_int8(g32)
        deq = dequantize_int8(q, scale)
        new_r = g32 - deq
        return deq, new_r

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    deq = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    res = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    return deq, res


def make_compressed_allreduce(mesh, axis: str = "data"):
    """shard_map all-reduce: int8 quantize -> psum -> dequantize.

    Applied to a pytree of per-rank partial gradients (batch-sharded loss
    terms). Error feedback state is threaded by the caller.
    """
    def sync(grads, residuals):
        def local(g_tree, r_tree):
            def one(g, r):
                g32 = g.astype(jnp.float32) + r
                q, scale = quantize_int8(g32)
                qsum = jax.lax.psum(q.astype(jnp.int32), axis)
                ssum = jax.lax.psum(scale, axis)  # conservative shared scale
                n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
                deq = qsum.astype(jnp.float32) * (ssum / n)
                new_r = g32 - dequantize_int8(q, scale)
                return deq / n, new_r
            flat_g, treedef = jax.tree_util.tree_flatten(g_tree)
            flat_r = treedef.flatten_up_to(r_tree)
            outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
            return (jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs]),
                    jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs]))

        return shard_map(
            local, mesh=mesh,
            in_specs=(P(), P()), out_specs=(P(), P()),
            check=False)(grads, residuals)

    return sync
