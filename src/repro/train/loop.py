"""Training loop: jit'd step + pipeline + checkpoints + watchdog.

Works in two modes:
  * host mode (CPU smoke / examples): mesh=None, everything local;
  * mesh mode: params/opt-state sharded per Plan, batch device_put with the
    batch sharding, identical step code (SPMD handles the rest).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import ArchConfig
from repro.data import make_pipeline
from repro.dist.ft import StepWatchdog
from repro.launch.steps import make_train_step
from repro.models import common
from repro.models import transformer as T
from repro.train import optimizer as opt


@dataclasses.dataclass
class TrainResult:
    losses: list
    steps: int
    restarts: int
    wall_s: float


def train(cfg: ArchConfig, *, steps: int, global_batch: int, seq_len: int,
          plan=None, ckpt_dir: str | None = None, ckpt_every: int = 0,
          resume: bool = False, seed: int = 0, log_every: int = 10,
          ocfg: opt.OptConfig | None = None, deadline_s: float = 0.0,
          expert_perm=None, param_dtype=jnp.float32) -> TrainResult:
    t0 = time.time()
    ocfg = ocfg or opt.OptConfig(total_steps=steps,
                                 warmup=min(200, max(steps // 5, 1)))
    pspecs = T.lm_shapes(cfg)
    step_fn = make_train_step(cfg, plan, ocfg, expert_perm=expert_perm)

    in_sh = None
    if plan is not None:
        sspec = opt.state_shapes(pspecs)
        state_sh = opt.TrainState(
            params=plan.param_shardings(sspec.params),
            master=plan.param_shardings(sspec.master),
            mu=plan.param_shardings(sspec.mu),
            nu=plan.param_shardings(sspec.nu),
            step=plan.sharding())
        in_sh = (state_sh, {"tokens": plan.sharding("batch", None),
                            "labels": plan.sharding("batch", None)})
    jitted = jax.jit(step_fn, in_shardings=in_sh, donate_argnums=(0,))

    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    start_step = 0
    state = None
    if resume and mgr and mgr.latest_step() is not None:
        like = opt.abstract_state(pspecs, compute_dtype=param_dtype)
        sh = None
        if plan is not None:
            sh = state_sh
        start_step, state, extra = mgr.restore(like, shardings=sh)
        if plan is None:  # restored leaves are host numpy; commit to device
            state = jax.tree.map(jnp.asarray, state)
    if state is None:
        params = common.materialize(pspecs, jax.random.PRNGKey(seed),
                                    param_dtype)
        state = opt.init_state(params)
        if plan is not None:
            state = jax.device_put(state, state_sh)

    pipe = make_pipeline(cfg, global_batch, seq_len, seed=seed + 1,
                         start_step=start_step)
    stalls: list[int] = []
    wd = StepWatchdog(deadline_s, stalls.append) if deadline_s else None

    losses = []
    step = start_step
    try:
        while step < steps:
            s, host_batch = pipe.next()
            batch = {k: jnp.asarray(v) for k, v in host_batch.items()}
            if plan is not None:
                batch = jax.device_put(batch, in_sh[1])
            if wd:
                wd.arm(step)
            state, metrics = jitted(state, batch)
            if wd:
                # jit dispatch is async: a hung collective returns futures
                # and would disarm instantly — block while armed so the
                # countdown covers the step's actual execution
                jax.block_until_ready(metrics)
                wd.disarm()
            if step % log_every == 0 or step == steps - 1:
                loss = float(metrics["loss"])
                losses.append((step, loss))
            step += 1
            if mgr and ckpt_every and step % ckpt_every == 0:
                mgr.save(step, state, extra={"data_step": step})
    finally:
        pipe.stop()
        if wd:
            wd.stop()
    if mgr and ckpt_every:
        mgr.save(step, state, extra={"data_step": step})
    return TrainResult(losses=losses, steps=step, restarts=len(stalls),
                       wall_s=time.time() - t0)


def train_supervised(cfg: ArchConfig, *, max_restarts: int = 3,
                     **kw) -> TrainResult:
    """Crash-resilient `train`: on any exception (preemption, device loss,
    injected fault) re-enters the loop from the last checkpoint, up to
    `max_restarts` times. The deterministic step-indexed data pipeline makes
    the replay exact — every step's effect lands once relative to the
    restored state. (Per-step supervision with injectable save/restore is
    `dist.ft.TrainSupervisor`; here checkpoint restore already lives inside
    `train(resume=True)`, so a plain retry loop is the whole policy.)"""
    if not (kw.get("ckpt_dir") and kw.get("ckpt_every")):
        raise ValueError("train_supervised requires ckpt_dir and ckpt_every")
    restarts = 0
    while True:
        # first attempt honors the caller's resume flag; any restart resumes
        # from the checkpoint train() wrote before the failure
        resume = bool(kw.get("resume", False)) or restarts > 0
        try:
            res = train(cfg, **{**kw, "resume": resume})
        except Exception:
            if restarts >= max_restarts:
                raise
            restarts += 1
            continue
        res.restarts += restarts
        return res
