"""Host-side span tree: wall-time phase attribution lined up with XLA.

``span("refine_level", level=3)`` is a context manager recording one node of
a per-thread span tree. Spans follow the PR 5/6 timing discipline — a span
that owns device work must drain it before the timer stops, or the time
leaks into the next phase. Either the body already blocks (the partitioner
drivers block at every phase tail) or the caller hands the span its output
value via ``sp.sync(x)`` and the exit path runs ``jax.block_until_ready``
on it before reading the clock.

Each span body is additionally wrapped in ``jax.profiler.TraceAnnotation``
and ``jax.named_scope``, so host spans line up with device TraceMe rows in
an XLA profile and any tracing that happens inside the span scopes its HLO
op names.

Span exit also observes ``span.<name>.s`` into the default metrics registry
(`repro.obs.metrics.REGISTRY`), which is how ``--metrics-json`` dumps carry
per-phase timings; ``aggregate()`` returns per-name count/total/self-time
rollups from the retained trees.

Perfetto / chrome://tracing export is off unless ``REPRO_TRACE_DIR`` is set:
every completed *root* span then appends its subtree to
``<dir>/trace-<pid>.trace.json`` (Chrome trace "X" events, microseconds).

Everything here is host-side Python; spans never touch traced values (a
``span()`` inside a jitted function would record trace time, not run time —
don't do that), so telemetry on/off cannot change any computed result.
"""
from __future__ import annotations

import contextlib
import collections
import dataclasses
import json
import os
import threading
import time

SPAN_BUCKETS = (0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025,
                0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0,
                float("inf"))

# retained completed root spans (newest last); bounded so a long-lived
# service or pytest session cannot grow without bound
MAX_ROOTS = 64

_tls = threading.local()
_lock = threading.Lock()
_roots: collections.deque = collections.deque(maxlen=MAX_ROOTS)
_trace_files: dict[str, bool] = {}  # path -> header written


@dataclasses.dataclass
class Span:
    """One completed (or in-flight) region of the span tree."""

    name: str
    attrs: dict
    t0: float
    t1: float | None = None
    children: list = dataclasses.field(default_factory=list)
    _sync: object = None

    @property
    def duration(self) -> float:
        return (self.t1 if self.t1 is not None else time.perf_counter()) \
            - self.t0

    @property
    def self_time(self) -> float:
        return self.duration - sum(c.duration for c in self.children)

    def sync(self, value):
        """Register device value(s) to ``block_until_ready`` at span exit,
        so their execution time lands in this span. Returns ``value``."""
        self._sync = value
        return value

    def annotate(self, **attrs) -> None:
        self.attrs.update(attrs)

    def find(self, name: str) -> "Span | None":
        """First descendant (depth-first) named ``name``."""
        for c in self.children:
            if c.name == name:
                return c
            hit = c.find(name)
            if hit is not None:
                return hit
        return None

    def to_dict(self) -> dict:
        return dict(name=self.name, attrs=dict(self.attrs),
                    start_s=self.t0, duration_s=self.duration,
                    children=[c.to_dict() for c in self.children])


def _stack() -> list:
    st = getattr(_tls, "stack", None)
    if st is None:
        st = _tls.stack = []
    return st


@contextlib.contextmanager
def span(name: str, **attrs):
    """Record one span; nests under the innermost open span of this thread.
    Yields the `Span` — use ``sp.sync(device_value)`` when the body does not
    already drain its device work, and ``sp.annotate(k=v)`` for attributes
    known only mid-body."""
    import jax

    sp = Span(name=name, attrs=attrs, t0=0.0)
    st = _stack()
    st.append(sp)
    ann = jax.profiler.TraceAnnotation(name)
    scope = jax.named_scope(name)
    ann.__enter__()
    scope.__enter__()
    sp.t0 = time.perf_counter()
    try:
        yield sp
    finally:
        if sp._sync is not None:
            jax.block_until_ready(sp._sync)
            sp._sync = None
        sp.t1 = time.perf_counter()
        scope.__exit__(None, None, None)
        ann.__exit__(None, None, None)
        st.pop()
        if st:
            st[-1].children.append(sp)
        else:
            with _lock:
                _roots.append(sp)
            _maybe_emit_chrome(sp)
        from repro.obs import metrics
        metrics.observe(f"span.{name}.s", sp.duration, buckets=SPAN_BUCKETS)


def roots() -> list:
    """Completed root spans, oldest first (bounded at MAX_ROOTS)."""
    with _lock:
        return list(_roots)


def last_root(name: str | None = None) -> Span | None:
    """Most recent completed root span (optionally of a given name)."""
    with _lock:
        for sp in reversed(_roots):
            if name is None or sp.name == name:
                return sp
    return None


def reset() -> None:
    with _lock:
        _roots.clear()


def aggregate() -> list:
    """Per-name rollup over every retained tree: count, total and self
    seconds — the "spans" section of the metrics dump."""
    acc: dict[str, list] = {}

    def walk(sp: Span) -> None:
        a = acc.setdefault(sp.name, [0, 0.0, 0.0])
        a[0] += 1
        a[1] += sp.duration
        a[2] += max(sp.self_time, 0.0)
        for c in sp.children:
            walk(c)

    for root in roots():
        walk(root)
    return [dict(name=n, count=c, total_s=t, self_s=s)
            for n, (c, t, s) in sorted(acc.items())]


# ------------------------------------------------------------ chrome trace
def _maybe_emit_chrome(root: Span) -> None:
    tdir = os.environ.get("REPRO_TRACE_DIR")
    if not tdir:
        return
    try:
        os.makedirs(tdir, exist_ok=True)
        path = os.path.join(tdir, f"trace-{os.getpid()}.trace.json")
        events = []

        def walk(sp: Span) -> None:
            events.append(dict(
                name=sp.name, ph="X", ts=sp.t0 * 1e6,
                dur=max(sp.duration, 0.0) * 1e6, pid=os.getpid(),
                tid=threading.get_ident() % 2 ** 31,
                args={k: str(v) for k, v in sp.attrs.items()}))
            for c in sp.children:
                walk(c)

        walk(root)
        with _lock:
            fresh = not _trace_files.get(path)
            _trace_files[path] = True
        # chrome trace JSON-array format tolerates a missing close bracket,
        # so appending root subtrees keeps every dump loadable
        with open(path, "a") as f:
            if fresh:
                f.write("[\n")
            for ev in events:
                f.write(json.dumps(ev) + ",\n")
    except OSError:  # tracing must never take the solve down
        pass
