"""Structured telemetry: metrics registry, span tracing, V-cycle stats.

Three jit-safe, host-side layers (none ever touches a traced value, so
telemetry on/off is bit-identical — see docs/observability.md):

* `repro.obs.metrics` — labeled counters/gauges/histograms in a
  thread-safe `Registry` (process-global default ``REGISTRY``), with
  snapshot/reset, JSONL + Prometheus export, and the ``--metrics-json``
  dump format (`dump_json` / `PeriodicDumper`).
* `repro.obs.trace` — ``span(name, **attrs)`` wall-time span tree with
  device-drain discipline (``sp.sync``), `jax.profiler.TraceAnnotation` /
  `named_scope` alignment, and Perfetto/Chrome export under
  ``REPRO_TRACE_DIR``.
* `repro.obs.vcycle` — per-level `LevelStats` (structure, capacity
  occupancy, kernel path, connectivity/balance/distinct-incidence slack)
  assembled by the partitioner drivers onto `PartitionResult.level_stats`.
"""
from repro.obs import metrics, trace, vcycle  # noqa: F401
from repro.obs.metrics import (  # noqa: F401
    REGISTRY,
    PeriodicDumper,
    Registry,
    counter,
    dump_json,
    gauge,
    observe,
)
from repro.obs.trace import span  # noqa: F401
from repro.obs.vcycle import LevelStats  # noqa: F401
