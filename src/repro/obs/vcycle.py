"""Per-level V-cycle quality stats (`LevelStats`): the paper's Fig. 8 /
Table 2 per-level accounting as a first-class telemetry record.

Two halves, matched to where the drivers already have the data:

* **structural** side (nodes/edges/pins, pair/nbr expansion live counts and
  capacity occupancy, kernel-vs-segment path) comes from scalars the
  coarsening loop already syncs per level (`run_coarsen_loop` batches them
  into the one `device_get` it pays anyway for the stop/audit check) — free.
* **quality** side (connectivity/cut of the projected partition, per-block
  size and distinct-incident-hyperedge slack vs Omega/Delta) needs extra
  device reductions over each refined level's partition, so it is gated
  behind ``partition(collect_stats=True)``. `quality_scalars` dispatches a
  handful of scalar reductions per level (built on `refine.pins_matrix`,
  the same [kcap, Ecap] incidence counting the refiner itself uses) and the
  driver fetches them *once*, batched with the kernel-hit readback it
  already does after the last level — no new syncs on the hot path.

Telemetry never writes into the solve: `quality_scalars` only reads
``(d, parts)``, so collect_stats on/off is bit-identical (tested).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class LevelStats:
    """One V-cycle level, finest (level 0) first. Fields are ``None`` when
    the side that produces them did not run: coarsening fields on the
    coarsest level (it never re-enters coarsening), quality fields unless
    ``collect_stats=True`` (and on memory-sharded graphs, where the stats
    reductions would need their own shard_map plumbing)."""

    level: int
    nodes: int
    edges: int
    pins: int
    # coarsening-side (levels 0..n_levels-1)
    pairs_live: int | None = None
    nbr_entries: int | None = None
    pair_occupancy: float | None = None   # pairs_live / caps.pairs
    nbr_occupancy: float | None = None    # nbr_entries / caps.nbrs
    kernel_coarsen: int | None = None     # 0/1 Pallas path taken
    # refinement-side (every level incl. the coarsest)
    kernel_refine: int | None = None      # kernel reps (0..theta)
    connectivity: float | None = None     # of the level's refined partition
    cut_net: float | None = None
    max_size: int | None = None
    size_slack: int | None = None         # Omega - max block size
    max_inbound: int | None = None        # distinct incident h-edges
    inbound_slack: int | None = None      # Delta - max_inbound

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@functools.lru_cache(maxsize=None)
def _quality_fn(caps, kcap: int):
    """One jitted stats kernel per (caps, kcap) signature — the same cache
    discipline as the solver itself, so stats never add compile churn."""
    from repro.core.refine import partition_sizes, pins_matrix

    def f(d, parts, omega, delta):
        pins, pins_in = pins_matrix(d, parts, caps, kcap)
        e_live = jnp.arange(caps.e) < d.n_edges
        lam = jnp.where(e_live, jnp.sum((pins > 0).astype(jnp.int32),
                                        axis=0), 0)
        w = jnp.where(e_live, d.edge_w, jnp.float32(0))
        sizes = partition_sizes(d, parts, caps, kcap)
        inbound = jnp.sum((pins_in > 0).astype(jnp.int32), axis=1)
        max_size = jnp.max(sizes)
        max_inbound = jnp.max(inbound)
        return dict(
            connectivity=jnp.sum(w * jnp.maximum(lam - 1, 0)),
            cut_net=jnp.sum(w * (lam > 1)),
            max_size=max_size,
            size_slack=jnp.asarray(omega, jnp.int32) - max_size,
            max_inbound=max_inbound,
            inbound_slack=jnp.asarray(delta, jnp.int32) - max_inbound)

    return jax.jit(f)


def quality_scalars(d, parts, caps, kcap: int, omega, delta) -> dict | None:
    """Device-scalar quality stats of ``parts`` on level graph ``d`` — a
    dict of six 0-d arrays the caller batches into its existing end-of-run
    ``device_get``. Returns ``None`` for memory-sharded graph storage
    (`dist.graph.ShardedHypergraph`): its striped pins arrays can only be
    read under the shard_map the solver runs in, and stats are not worth a
    second one."""
    from repro.core.hypergraph import DeviceHypergraph

    if not isinstance(d, DeviceHypergraph):
        return None
    return _quality_fn(caps, kcap)(d, parts, jnp.int32(omega),
                                   jnp.int32(delta))


def assemble(coarsen_meta: list[dict], refine_meta: dict[int, dict]
             ) -> list[LevelStats]:
    """Zip the coarsening loop's per-level structural records with the
    refinement loop's per-level records (kernel hits + fetched quality
    scalars) into the finest-first `LevelStats` list on
    `PartitionResult.level_stats`."""
    n_levels = len(coarsen_meta)
    out = []
    for lvl in range(n_levels + 1):
        if lvl < n_levels:
            m = dict(coarsen_meta[lvl])
        else:
            m = dict(refine_meta.get(lvl, {}).get("structure") or {})
        r = refine_meta.get(lvl, {})
        q = r.get("quality") or {}
        out.append(LevelStats(
            level=lvl,
            nodes=int(m.get("nodes", 0)),
            edges=int(m.get("edges", 0)),
            pins=int(m.get("pins", 0)),
            pairs_live=m.get("pairs_live"),
            nbr_entries=m.get("nbr_entries"),
            pair_occupancy=m.get("pair_occupancy"),
            nbr_occupancy=m.get("nbr_occupancy"),
            kernel_coarsen=m.get("kernel_coarsen"),
            kernel_refine=r.get("kernel_refine"),
            connectivity=(float(q["connectivity"])
                          if "connectivity" in q else None),
            cut_net=float(q["cut_net"]) if "cut_net" in q else None,
            max_size=int(q["max_size"]) if "max_size" in q else None,
            size_slack=int(q["size_slack"]) if "size_slack" in q else None,
            max_inbound=(int(q["max_inbound"])
                         if "max_inbound" in q else None),
            inbound_slack=(int(q["inbound_slack"])
                           if "inbound_slack" in q else None)))
    return out
