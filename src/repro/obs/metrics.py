"""Process-global metrics registry: counters, gauges, fixed-bucket histograms.

Plain-Python host-side telemetry — nothing here touches a traced value, so
recording a metric can never perturb a jitted computation (the bit-exactness
contract is enforced by ``tests/test_obs.py``'s telemetry-on/off parity
test). All mutation happens under one lock, so the `StepWatchdog` thread,
`PeriodicDumper` thread, and driver threads can hammer the same registry
concurrently.

Series are keyed by ``(name, sorted labels)``:

    counter("service.requeues", route="bucket")      # += 1
    gauge("service.pending", 3.0)
    observe("service.solve_latency.s", 0.042, route="bucket")

A per-name series-cardinality cap guards against label explosions: past
``max_series`` distinct label sets, new series collapse into a single
``{"overflow": "true"}`` series and ``obs.series_overflow`` counts the
collapses — telemetry degrades instead of eating the heap.

Export paths: ``snapshot()`` (plain dict), ``to_jsonl()`` (one JSON object
per series line), ``render()`` (Prometheus text exposition), ``dump_json()``
(snapshot + span aggregates as one JSON document — the format the
``--metrics-json`` CLI flags write and ``tests/data/metrics_schema.json``
pins), and ``PeriodicDumper`` (background thread re-dumping every
``interval_s``).
"""
from __future__ import annotations

import json
import os
import threading
import time

# seconds-scale latency edges; every histogram bucket list ends at +inf
DEFAULT_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                   0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0, float("inf"))

_OVERFLOW_LABELS = (("overflow", "true"),)


def _label_key(labels: dict) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class Registry:
    """Thread-safe container of labeled counter/gauge/histogram series."""

    def __init__(self, max_series: int = 1024):
        self.max_series = max_series
        self._lock = threading.Lock()
        self._counters: dict[str, dict[tuple, float]] = {}
        self._gauges: dict[str, dict[tuple, float]] = {}
        # name -> (edges, {labels: [counts per edge, sum, count]})
        self._hists: dict[str, tuple[tuple, dict[tuple, list]]] = {}

    # ------------------------------------------------------------ recording
    def _series(self, table: dict, name: str, labels: dict) -> tuple:
        key = _label_key(labels)
        series = table.setdefault(name, {})
        if key not in series and len(series) >= self.max_series:
            self._counters.setdefault("obs.series_overflow", {})
            ov = self._counters["obs.series_overflow"]
            ov[(("name", name),)] = ov.get((("name", name),), 0.0) + 1.0
            return _OVERFLOW_LABELS
        return key

    def counter(self, name: str, value: float = 1.0, **labels) -> None:
        """Add ``value`` (default 1) to the counter series. ``value=0``
        pre-registers the series so dumps carry it before the first event."""
        with self._lock:
            key = self._series(self._counters, name, labels)
            tbl = self._counters[name]
            tbl[key] = tbl.get(key, 0.0) + float(value)

    def gauge(self, name: str, value: float, **labels) -> None:
        with self._lock:
            key = self._series(self._gauges, name, labels)
            self._gauges[name][key] = float(value)

    def observe(self, name: str, value: float,
                buckets: tuple | None = None, **labels) -> None:
        """Record ``value`` into the fixed-bucket histogram ``name``. Bucket
        edges are fixed at the first observation (``buckets=`` or the
        default latency ladder); later ``buckets=`` args are ignored so
        every series of one name shares comparable edges. Stored counts are
        per-bucket (``counts[i]`` counts values in ``(edges[i-1],
        edges[i]]``); `render` cumulates them into Prometheus ``le``
        buckets."""
        v = float(value)
        with self._lock:
            if name not in self._hists:
                edges = tuple(buckets) if buckets else DEFAULT_BUCKETS
                if edges[-1] != float("inf"):
                    edges = edges + (float("inf"),)
                self._hists[name] = (edges, {})
            edges, series = self._hists[name]
            key = _label_key(labels)
            if key not in series:
                if len(series) >= self.max_series:
                    # inline (lock already held — counter() would deadlock)
                    ov = self._counters.setdefault("obs.series_overflow", {})
                    k2 = (("name", name),)
                    ov[k2] = ov.get(k2, 0.0) + 1.0
                    key = _OVERFLOW_LABELS
                if key not in series:
                    series[key] = [[0] * len(edges), 0.0, 0]
            h = series[key]
            for i, edge in enumerate(edges):
                if v <= edge:
                    h[0][i] += 1
                    break
            h[1] += v
            h[2] += 1

    def histogram(self, name: str, buckets: tuple | None = None,
                  **labels) -> None:
        """Pre-register an empty histogram series (zero counts, sum 0,
        count 0) so dumps carry it before the first `observe` — the
        histogram analogue of ``counter(name, 0)``. Bucket edges fix here
        exactly as at a first observation; a series that already exists is
        left untouched."""
        with self._lock:
            if name not in self._hists:
                edges = tuple(buckets) if buckets else DEFAULT_BUCKETS
                if edges[-1] != float("inf"):
                    edges = edges + (float("inf"),)
                self._hists[name] = (edges, {})
            edges, series = self._hists[name]
            key = _label_key(labels)
            if key not in series:
                if len(series) >= self.max_series:
                    ov = self._counters.setdefault("obs.series_overflow", {})
                    k2 = (("name", name),)
                    ov[k2] = ov.get(k2, 0.0) + 1.0
                    key = _OVERFLOW_LABELS
                if key not in series:
                    series[key] = [[0] * len(edges), 0.0, 0]

    # ------------------------------------------------------------- reading
    def value(self, name: str, **labels) -> float:
        """Current value of one counter/gauge series (0 when absent)."""
        key = _label_key(labels)
        with self._lock:
            if name in self._counters:
                return self._counters[name].get(key, 0.0)
            if name in self._gauges:
                return self._gauges[name].get(key, 0.0)
        return 0.0

    def total(self, name: str) -> float:
        """Sum of a counter's value across all its label series."""
        with self._lock:
            return sum(self._counters.get(name, {}).values())

    def snapshot(self) -> dict:
        """Plain-dict view of every series (stable shapes — this is the
        object ``tests/data/metrics_schema.json`` describes)."""
        with self._lock:
            out = dict(counters={}, gauges={}, histograms={})
            for name, series in self._counters.items():
                out["counters"][name] = [
                    dict(labels=dict(k), value=v)
                    for k, v in sorted(series.items())]
            for name, series in self._gauges.items():
                out["gauges"][name] = [
                    dict(labels=dict(k), value=v)
                    for k, v in sorted(series.items())]
            for name, (edges, series) in self._hists.items():
                out["histograms"][name] = [
                    dict(labels=dict(k),
                         edges=[e if e != float("inf") else "inf"
                                for e in edges],
                         counts=list(h[0]), sum=h[1], count=h[2])
                    for k, h in sorted(series.items())]
            return out

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()

    # ------------------------------------------------------------- export
    def to_jsonl(self) -> str:
        """One JSON object per series line (counters/gauges: kind, name,
        labels, value; histograms: + edges/counts/sum/count)."""
        snap = self.snapshot()
        lines = []
        for kind_key, kind in (("counters", "counter"), ("gauges", "gauge"),
                               ("histograms", "histogram")):
            for name in sorted(snap[kind_key]):
                for s in snap[kind_key][name]:
                    rec = dict(kind=kind, name=name, **s)
                    lines.append(json.dumps(rec, sort_keys=True))
        return "\n".join(lines) + ("\n" if lines else "")

    def render(self) -> str:
        """Prometheus text exposition (dots in names become underscores;
        histogram series render as cumulative ``_bucket{le=}`` lines plus
        ``_sum`` / ``_count``)."""
        def prom_name(name: str) -> str:
            return name.replace(".", "_").replace("-", "_")

        def prom_labels(labels: tuple, extra: str = "") -> str:
            parts = [f'{k}="{v}"' for k, v in labels]
            if extra:
                parts.append(extra)
            return "{" + ",".join(parts) + "}" if parts else ""

        lines = []
        with self._lock:
            for name in sorted(self._counters):
                pn = prom_name(name)
                lines.append(f"# TYPE {pn} counter")
                for k, v in sorted(self._counters[name].items()):
                    lines.append(f"{pn}{prom_labels(k)} {v:g}")
            for name in sorted(self._gauges):
                pn = prom_name(name)
                lines.append(f"# TYPE {pn} gauge")
                for k, v in sorted(self._gauges[name].items()):
                    lines.append(f"{pn}{prom_labels(k)} {v:g}")
            for name in sorted(self._hists):
                edges, series = self._hists[name]
                pn = prom_name(name)
                lines.append(f"# TYPE {pn} histogram")
                for k, (counts, total, count) in sorted(series.items()):
                    cum = 0
                    for edge, c in zip(edges, counts):
                        cum += c
                        le = "+Inf" if edge == float("inf") else f"{edge:g}"
                        lbl = prom_labels(k, f'le="{le}"')
                        lines.append(f"{pn}_bucket{lbl} {cum}")
                    lines.append(f"{pn}_sum{prom_labels(k)} {total:g}")
                    lines.append(f"{pn}_count{prom_labels(k)} {count}")
        return "\n".join(lines) + ("\n" if lines else "")


# process-global default registry: spans and any caller that does not carry
# its own Registry record here (PartitionService instances default to a
# private Registry so per-service stats stay isolated — the CLIs pass this
# one in explicitly so one dump carries service + span + watchdog series)
REGISTRY = Registry()


def counter(name: str, value: float = 1.0, **labels) -> None:
    REGISTRY.counter(name, value, **labels)


def gauge(name: str, value: float, **labels) -> None:
    REGISTRY.gauge(name, value, **labels)


def observe(name: str, value: float, buckets: tuple | None = None,
            **labels) -> None:
    REGISTRY.observe(name, value, buckets, **labels)


def dump_json(path: str, registry: Registry | None = None) -> dict:
    """Write the one-file metrics dump: registry snapshot + span aggregates
    (the `--metrics-json` format; see docs/observability.md). Atomic
    (tmp + rename) so a `PeriodicDumper` overwrite never tears a reader."""
    from repro.obs import trace

    reg = registry if registry is not None else REGISTRY
    doc = dict(ts=time.time(), metrics=reg.snapshot(),
               spans=trace.aggregate())
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    os.replace(tmp, path)
    return doc


class PeriodicDumper:
    """Background thread re-writing ``dump_json(path)`` every
    ``interval_s`` — the long-lived-service dump mode behind
    ``--metrics-interval``. ``stop()`` writes one final dump."""

    def __init__(self, path: str, interval_s: float,
                 registry: Registry | None = None):
        self.path = path
        self.interval_s = float(interval_s)
        self.registry = registry
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="metrics-dumper")
        self._thread.start()

    def _run(self) -> None:
        while not self._stop.wait(self.interval_s):
            dump_json(self.path, self.registry)

    def stop(self) -> None:
        self._stop.set()
        self._thread.join(timeout=5.0)
        dump_json(self.path, self.registry)
