"""Fault-tolerant checkpointing with elastic re-mesh restore.

* atomic: write to <dir>/tmp-<step>, fsync, rename to <dir>/step-<step>
  (a crash mid-write never corrupts the latest checkpoint);
* keep-k garbage collection;
* layout-agnostic restore: arrays are saved as full logical values plus the
  pytree structure; `restore(..., shardings=)` device_puts each leaf with
  the *new* mesh's shardings, so a job can restart on a different topology
  (elastic scaling: 256 -> 512 chips or down to 1 CPU) without conversion;
* stores the data-pipeline step, so restarts replay the exact token stream.

Format: one .npz per checkpoint (leaf arrays keyed by flattened path) plus
a JSON manifest. No external deps.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile

import jax
import numpy as np


def _flatten(tree, materialize: bool = True):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    flat = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf) if materialize else leaf
    return flat, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, extra: dict | None = None) -> str:
        import ml_dtypes
        flat, _ = _flatten(tree)
        dtypes = {}
        for k, a in flat.items():
            dtypes[k] = str(a.dtype)
            if a.dtype == ml_dtypes.bfloat16:  # npz can't store bf16
                flat[k] = a.view(np.uint16)
        tmp = tempfile.mkdtemp(prefix=f"tmp-{step}-", dir=self.dir)
        try:
            np.savez(os.path.join(tmp, "arrays.npz"), **flat)
            manifest = dict(step=step, keys=sorted(flat), dtypes=dtypes,
                            extra=extra or {})
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            final = os.path.join(self.dir, f"step-{step:08d}")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step-{s:08d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step-(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name,
                                                 "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like, step: int | None = None,
                shardings=None) -> tuple[int, object, dict]:
        """tree_like: pytree of arrays/ShapeDtypeStructs giving structure.
        shardings: matching pytree of NamedShardings for elastic re-mesh
        placement (None -> default devices)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step-{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        arrays = np.load(os.path.join(d, "arrays.npz"))
        # tree_like may hold ShapeDtypeStructs — only structure is needed
        flat_keys, treedef = _flatten(tree_like, materialize=False)
        import ml_dtypes
        dtypes = manifest.get("dtypes", {})
        vals = []
        sh_leaves = (jax.tree_util.tree_leaves(shardings)
                     if shardings is not None else None)
        for i, key in enumerate(flat_keys):
            a = arrays[key]
            if dtypes.get(key) == "bfloat16":
                a = a.view(ml_dtypes.bfloat16)
            if sh_leaves is not None:
                vals.append(jax.device_put(a, sh_leaves[i]))
            else:
                vals.append(a)
        # preserve original key order = tree order
        tree = jax.tree_util.tree_unflatten(treedef, vals)
        return step, tree, manifest.get("extra", {})
