"""Jit-able step functions: train_step / prefill_step / serve_step, plus
the ShapeDtypeStruct input factories for the dry-run.

`input_specs(arch, shape)` follows the assignment contract: LM shapes are
seq_len x global_batch; decode_* / long_* lower `serve_step` (one new token
against a KV cache of seq_len); [audio]/[vlm] backbones take precomputed
frame/patch embeddings from the stub frontend.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.configs.registry import SHAPES, get_config
from repro.dist.sharding import Plan
from repro.models import common
from repro.models import transformer as T
from repro.train import optimizer as opt

BF16 = jnp.bfloat16


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------
def make_train_step(cfg: ArchConfig, plan: Plan | None,
                    ocfg: opt.OptConfig = opt.OptConfig(),
                    expert_perm=None):
    def train_step(state: opt.TrainState, batch: dict):
        def lf(p):
            return T.loss_fn(p, batch, cfg, plan, expert_perm=expert_perm)
        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(
            state.params)
        new_state = opt.adamw_update(state, grads, ocfg)
        return new_state, {"loss": loss, **metrics}
    return train_step


def make_prefill_step(cfg: ArchConfig, plan: Plan | None, expert_perm=None):
    def prefill_step(params, batch: dict, cache):
        return T.prefill(params, batch["tokens"], cache, cfg, plan,
                         vision=batch.get("vision"),
                         enc_frames=batch.get("enc_frames"),
                         expert_perm=expert_perm)
    return prefill_step


def make_serve_step(cfg: ArchConfig, plan: Plan | None, expert_perm=None):
    def serve_step(params, token, pos, cache):
        return T.decode_step(params, token, pos, cache, cfg, plan,
                             expert_perm=expert_perm)
    return serve_step


# ---------------------------------------------------------------------------
# abstract inputs (no allocation)
# ---------------------------------------------------------------------------
def batch_specs(cfg: ArchConfig, batch: int, seq: int) -> dict:
    s: dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }
    if cfg.vision_dim:
        s["vision"] = jax.ShapeDtypeStruct(
            (batch, cfg.vision_tokens, cfg.vision_dim), BF16)
    if cfg.encoder_layers:
        enc_len = min(cfg.max_source_positions, seq)
        s["enc_frames"] = jax.ShapeDtypeStruct(
            (batch, enc_len, cfg.d_model), BF16)
    return s


def _dp_size(plan: Plan) -> int:
    return plan.dp_size()


def _bsh(plan: Plan, batch: int, ndim: int):
    """Batch sharding with small-batch fallback (e.g. long_500k B=1)."""
    if batch % _dp_size(plan) != 0:
        return plan.sharding(*([None] * ndim))
    return plan.sharding(*(["batch"] + [None] * (ndim - 1)))


def batch_shardings(cfg: ArchConfig, plan: Plan, batch: int) -> dict:
    s: dict[str, Any] = {"tokens": _bsh(plan, batch, 2),
                         "labels": _bsh(plan, batch, 2)}
    if cfg.vision_dim:
        s["vision"] = _bsh(plan, batch, 3)
    if cfg.encoder_layers:
        s["enc_frames"] = _bsh(plan, batch, 3)
    return s


def input_specs(arch: str, shape: str, plan: Plan | None = None) -> dict:
    """Abstract (ShapeDtypeStruct) inputs + shardings for one dry-run cell.

    Returns dict(kind, args=(...), in_shardings=(...)) matching the step fn
    built by `make_*_step`.
    """
    cfg = get_config(arch)
    sh = SHAPES[shape]
    B, S = sh["global_batch"], sh["seq_len"]
    kind = sh["kind"]
    pspecs = T.lm_shapes(cfg)

    if kind == "train":
        state = opt.abstract_state(pspecs)
        batch = batch_specs(cfg, B, S)
        if plan is None:
            return dict(kind=kind, cfg=cfg, args=(state, batch),
                        in_shardings=None)
        sspec = opt.state_shapes(pspecs)
        state_sh = opt.TrainState(
            params=plan.param_shardings(sspec.params),
            master=plan.param_shardings(sspec.master),
            mu=plan.param_shardings(sspec.mu),
            nu=plan.param_shardings(sspec.nu),
            step=plan.sharding())
        return dict(kind=kind, cfg=cfg, args=(state, batch),
                    in_shardings=(state_sh, batch_shardings(cfg, plan, B)))

    params = common.abstracts(pspecs, BF16)
    cache_len = S + (cfg.vision_tokens if cfg.vision_dim else 0)
    cspecs = T.cache_shapes(cfg, B, cache_len)
    cache = common.abstracts(cspecs, BF16)
    if kind == "prefill":
        batch = batch_specs(cfg, B, S)
        if plan is None:
            return dict(kind=kind, cfg=cfg, args=(params, batch, cache),
                        in_shardings=None)
        return dict(kind=kind, cfg=cfg, args=(params, batch, cache),
                    in_shardings=(plan.param_shardings(pspecs),
                                  batch_shardings(cfg, plan, B),
                                  plan.param_shardings(cspecs)))
    # decode
    token = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    if plan is None:
        return dict(kind=kind, cfg=cfg, args=(params, token, pos, cache),
                    in_shardings=None)
    return dict(kind=kind, cfg=cfg, args=(params, token, pos, cache),
                in_shardings=(plan.param_shardings(pspecs),
                              _bsh(plan, B, 2), plan.sharding(),
                              plan.param_shardings(cspecs)))


def make_step(arch: str, shape: str, plan: Plan | None,
              expert_perm=None):
    cfg = get_config(arch)
    kind = SHAPES[shape]["kind"]
    if kind == "train":
        return make_train_step(cfg, plan, expert_perm=expert_perm)
    if kind == "prefill":
        return make_prefill_step(cfg, plan, expert_perm=expert_perm)
    return make_serve_step(cfg, plan, expert_perm=expert_perm)
