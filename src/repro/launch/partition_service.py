"""Multi-tenant partition service launcher CLI.

  PYTHONPATH=src python -m repro.launch.partition_service --requests 16 \
      --nodes 48 --edges 64 --pins 4 --omega 16 --delta 256 [--mixed] \
      [--mesh host --replicas 2] [--route-threshold 2048] [--json out.json]

Feeds a flood of generated requests through `serve.PartitionService`:
small/medium graphs batch into capacity buckets (one vmapped device solve
per bucket batch), anything above --route-threshold takes the host-driven
V-cycle — mesh-sharded when --mesh host (force a multi-device CPU run with
XLA_FLAGS=--xla_force_host_platform_device_count=8). --mixed interleaves a
few over-threshold graphs into the flood to exercise both lanes.

--metrics-json PATH dumps the full telemetry document (metric registry
snapshot + aggregated span tree — see docs/observability.md) on exit;
--metrics-interval N additionally rewrites it every N seconds while the
flood drains (a `PeriodicDumper` thread).
"""
from __future__ import annotations

import argparse
import json
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--nodes", type=int, default=48)
    ap.add_argument("--edges", type=int, default=64)
    ap.add_argument("--pins", type=int, default=4,
                    help="pins per hyperedge of the generated requests")
    ap.add_argument("--omega", type=int, default=16)
    ap.add_argument("--delta", type=int, default=256)
    ap.add_argument("--theta", type=int, default=8)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--bucket-base", type=int, default=64)
    ap.add_argument("--route-threshold", type=int, default=2048)
    ap.add_argument("--deadline", type=float, default=300.0,
                    help="per-solve StepWatchdog deadline (s)")
    ap.add_argument("--max-restarts", type=int, default=3)
    ap.add_argument("--mixed", action="store_true",
                    help="make every 4th request over-threshold so the "
                         "routed V-cycle lane runs too")
    ap.add_argument("--mesh", choices=["none", "host"], default="none")
    ap.add_argument("--replicas", type=int, default=1)
    ap.add_argument("--no-race", action="store_true")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--json", default=None)
    ap.add_argument("--metrics-json", default=None,
                    help="write the telemetry dump (registry snapshot + "
                         "span aggregate) to this path on exit")
    ap.add_argument("--metrics-interval", type=float, default=0.0,
                    help="also rewrite --metrics-json every N seconds "
                         "while running (0: only the final dump)")
    ap.add_argument("--collect-stats", action="store_true",
                    help="collect per-level LevelStats on the routed lane")
    args = ap.parse_args(argv)

    from repro import obs
    from repro.core.generate import random_kuniform
    from repro.launch.partition import build_plan
    from repro.serve import PartitionService

    plan = build_plan(args.replicas) if args.mesh == "host" else None
    # the CLI joins the process-global registry so one --metrics-json dump
    # carries service + span + watchdog series together
    svc = PartitionService(
        theta=args.theta, batch_slots=args.batch_slots,
        bucket_base=args.bucket_base, route_threshold=args.route_threshold,
        plan=plan, race=not args.no_race, deadline_s=args.deadline,
        max_restarts=args.max_restarts, registry=obs.metrics.REGISTRY,
        collect_stats=args.collect_stats)
    dumper = None
    if args.metrics_json and args.metrics_interval > 0:
        dumper = obs.PeriodicDumper(args.metrics_json,
                                    args.metrics_interval)

    reqs = []
    for i in range(args.requests):
        if args.mixed and i % 4 == 3:
            n = 2 * args.route_threshold
            hg = random_kuniform(n, 2 * n, args.pins, seed=args.seed + i)
            reqs.append((hg, max(args.omega, n // 8), args.delta * 4))
        else:
            hg = random_kuniform(args.nodes, args.edges, args.pins,
                                 seed=args.seed + i)
            reqs.append((hg, args.omega, args.delta))

    t0 = time.perf_counter()
    rids = [svc.submit(hg, omega=o, delta=d) for hg, o, d in reqs]
    res = svc.drain()
    wall = time.perf_counter() - t0
    svc.close()
    if dumper is not None:
        dumper.stop()          # writes the final dump
    elif args.metrics_json:
        from repro.obs.metrics import dump_json
        dump_json(args.metrics_json)

    assert sorted(res) == sorted(rids), "lost rids"
    routes: dict[str, int] = {}
    for r in res.values():
        routes[r.route] = routes.get(r.route, 0) + 1
    out = dict(
        requests=args.requests, wall_s=wall,
        req_per_s=args.requests / wall, routes=routes,
        all_size_ok=all(r.audit["size_ok"] for r in res.values()),
        all_inbound_ok=all(r.audit["inbound_ok"] for r in res.values()),
        mean_connectivity=sum(r.connectivity for r in res.values())
        / len(res),
        mean_queue_wait_s=sum(r.queue_wait_s for r in res.values())
        / len(res),
        mean_solve_s=sum(r.solve_s for r in res.values()) / len(res),
        stats=svc.stats,
        mesh=(dict(plan.mesh.shape) if plan is not None else None),
    )
    print(json.dumps(out, indent=2))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)


if __name__ == "__main__":
    main()
