import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract roofline terms from the compiled artifact.

This is the proof that the distribution config is coherent without real
hardware: a sharding mismatch, OOM-at-compile or unsupported collective
fails here. Usage:

  PYTHONPATH=src python -m repro.launch.dryrun --arch minitron-8b \
      --shape train_4k [--mesh single,multi] [--json out.json]
  PYTHONPATH=src python -m repro.launch.dryrun --list
"""
import argparse
import json
import re
import sys
import time

import jax
import numpy as np

# TPU v5e-class hardware constants (per chip)
PEAK_FLOPS = 197e12    # bf16 FLOP/s
HBM_BW = 819e9         # B/s
ICI_BW = 50e9          # B/s per link

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
                "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1}
_COLL_OPS = {
    "all-reduce": 2.0,          # ring: 2 (n-1)/n x bytes
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo: str) -> dict:
    """Per-chip collective traffic from the post-SPMD optimized HLO.

    Shapes in the partitioned module are already per-device; we sum output
    bytes per op with a ring-cost multiplier for all-reduce."""
    totals = {k: 0.0 for k in _COLL_OPS}
    counts = {k: 0 for k in _COLL_OPS}
    for line in hlo.splitlines():
        ls = line.strip()
        if "=" not in ls:
            continue
        rhs = ls.split("=", 1)[1]
        for op, mult in _COLL_OPS.items():
            tok = f" {op}("
            idx = rhs.find(tok)
            if idx < 0:
                # fusion-wrapped or start-done pairs: match "-start("
                tok = f" {op}-start("
                idx = rhs.find(tok)
                if idx < 0:
                    continue
            head = rhs[:idx]
            b = sum(_shape_bytes(m.group(1), m.group(2))
                    for m in _SHAPE_RE.finditer(head))
            totals[op] += mult * b
            counts[op] += 1
            break
    return dict(bytes_by_op=totals, counts=counts,
                total_bytes=float(sum(totals.values())))


def count_params(pspecs, cfg) -> tuple[int, int]:
    """(total, active) parameter counts; MoE expert tensors scale by
    top_k/n_experts for the active count."""
    from repro.models.common import is_spec
    total = active = 0
    for path, spec in jax.tree_util.tree_flatten_with_path(
            pspecs, is_leaf=is_spec)[0]:
        n = int(np.prod(spec.shape))
        total += n
        if cfg.moe and "experts" in (spec.axes or ()):
            active += n * cfg.moe.top_k // cfg.moe.n_experts
        else:
            active += n
    return total, active


def model_flops(cfg, shape_name: str) -> float:
    """MODEL_FLOPS: 6*N_active*D for train, 2*N_active*D for prefill,
    2*N_active*B for one decode step."""
    from repro.configs.registry import SHAPES
    from repro.models import transformer as T
    sh = SHAPES[shape_name]
    _, active = count_params(T.lm_shapes(cfg), cfg)
    if sh["kind"] == "train":
        return 6.0 * active * sh["global_batch"] * sh["seq_len"]
    if sh["kind"] == "prefill":
        return 2.0 * active * sh["global_batch"] * sh["seq_len"]
    return 2.0 * active * sh["global_batch"]


def run_cell(arch: str, shape: str, multi_pod: bool,
             fsdp: bool = True, seq_shard_kv: bool = True,
             donate: bool = True, moe_local: bool = False,
             seq_parallel_attn: bool = False,
             attn_p_bf16: bool = False, mla_flash: bool = False,
             q_chunk: int = 0, k_chunk: int = 0) -> dict:
    from repro.configs import registry
    from repro.configs.registry import cell_is_runnable, get_config
    from repro.dist.sharding import Plan
    from repro.launch import steps
    from repro.launch.mesh import make_production_mesh

    if q_chunk or k_chunk:
        base = registry.CONFIGS[arch]
        registry.CONFIGS[arch] = base.scaled(
            q_chunk=q_chunk or base.q_chunk, k_chunk=k_chunk or base.k_chunk)

    ok, why = cell_is_runnable(arch, shape)
    if not ok:
        return dict(arch=arch, shape=shape,
                    mesh="multi" if multi_pod else "single",
                    status="skipped", reason=why)

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    plan = Plan.make(mesh, fsdp=fsdp, seq_shard_kv=seq_shard_kv,
                     moe_local=moe_local,
                     seq_parallel_attn=seq_parallel_attn,
                     attn_p_bf16=attn_p_bf16, mla_flash=mla_flash)
    spec = steps.input_specs(arch, shape, plan)
    fn = steps.make_step(arch, shape, plan)
    n_chips = int(np.prod(list(mesh.shape.values())))

    donate_args = ()
    if donate and spec["kind"] in ("train",):
        donate_args = (0,)
    elif donate and spec["kind"] == "decode":
        donate_args = (3,)
    jitted = jax.jit(fn, in_shardings=spec["in_shardings"],
                     donate_argnums=donate_args)
    with mesh:
        lowered = jitted.lower(*spec["args"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # jax<0.5: per-device list of dicts
        cost = cost[0] if cost else {}
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            mem[k] = int(getattr(ma, k, 0) or 0)
    except Exception as e:  # CPU backend may not expose it
        mem["error"] = str(e)

    # trip-count-corrected per-chip costs from the optimized HLO (XLA's
    # cost_analysis visits scan bodies once; see launch/hlo_cost.py)
    from repro.launch import hlo_cost
    walked = hlo_cost.analyze(compiled.as_text())
    coll = dict(total_bytes=walked["collective_bytes"],
                counts=walked["collective_counts"],
                bytes_by_op=walked["collective_bytes_by_op"])

    flops_total = float(walked["flops"])
    bytes_total = float(walked["bytes"])
    raw_flops = float(cost.get("flops", 0.0))
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    cfg = get_config(arch)
    mf = model_flops(cfg, shape)
    compute_s = flops_total / PEAK_FLOPS
    memory_s = bytes_total / HBM_BW
    coll_s = coll["total_bytes"] / ICI_BW
    dominant = max(("compute", compute_s), ("memory", memory_s),
                   ("collective", coll_s), key=lambda kv: kv[1])[0]
    return dict(
        arch=arch, shape=shape, mesh="multi" if multi_pod else "single",
        status="ok", n_chips=n_chips,
        t_lower_s=round(t_lower, 1), t_compile_s=round(t_compile, 1),
        hlo_flops=flops_total, hlo_bytes=bytes_total,
        xla_cost_flops=raw_flops, xla_cost_bytes=raw_bytes,
        collective_bytes=coll["total_bytes"],
        collective_counts=coll["counts"],
        collective_bytes_by_op=coll["bytes_by_op"],
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        dominant=dominant,
        model_flops=mf, model_flops_per_chip=mf / n_chips,
        useful_flop_ratio=(mf / n_chips) / flops_total if flops_total else 0,
        memory=mem,
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single,multi")
    ap.add_argument("--json", default=None)
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--no-seq-shard-kv", action="store_true")
    ap.add_argument("--no-donate", action="store_true")
    ap.add_argument("--moe-local", action="store_true",
                    help="hillclimb D1: rank-local MoE dispatch")
    ap.add_argument("--sp-attn", action="store_true",
                    help="hillclimb Q1: sequence-parallel attention")
    ap.add_argument("--p-bf16", action="store_true",
                    help="hillclimb M1: bf16 PV probabilities")
    ap.add_argument("--mla-flash", action="store_true",
                    help="hillclimb D2: chunked latent MLA attention")
    ap.add_argument("--q-chunk", type=int, default=0)
    ap.add_argument("--k-chunk", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.configs.registry import SHAPES, list_configs
    if args.list:
        for a in list_configs():
            for s in SHAPES:
                print(f"{a} {s}")
        return 0

    results = []
    for mesh_kind in args.mesh.split(","):
        r = run_cell(args.arch, args.shape, multi_pod=(mesh_kind == "multi"),
                     fsdp=not args.no_fsdp,
                     seq_shard_kv=not args.no_seq_shard_kv,
                     donate=not args.no_donate, moe_local=args.moe_local,
                     seq_parallel_attn=args.sp_attn,
                     attn_p_bf16=args.p_bf16, mla_flash=args.mla_flash,
                     q_chunk=args.q_chunk, k_chunk=args.k_chunk)
        results.append(r)
        if r["status"] == "ok":
            print(f"[{r['mesh']}] {args.arch} x {args.shape}: "
                  f"compile {r['t_compile_s']}s | "
                  f"compute {r['compute_s']*1e3:.2f}ms "
                  f"memory {r['memory_s']*1e3:.2f}ms "
                  f"collective {r['collective_s']*1e3:.2f}ms "
                  f"-> {r['dominant']}-bound | "
                  f"useful-flop ratio {r['useful_flop_ratio']:.2f}")
            print("  memory_analysis:", r["memory"])
            print("  collectives:", r["collective_counts"])
        else:
            print(f"[{r['mesh']}] {args.arch} x {args.shape}: SKIP "
                  f"({r['reason']})")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
