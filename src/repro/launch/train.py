"""Training launcher CLI.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
      --steps 50 --batch 8 --seq 128 [--ckpt-dir /tmp/ck --resume]

On a real fleet this binary runs once per host (jax.distributed.initialize
picks up the coordinator from the env); on this container it runs
single-process over local devices.
"""
from __future__ import annotations

import argparse
import json

from repro.configs import get_config


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", choices=["none", "host"], default="none")
    ap.add_argument("--deadline-s", type=float, default=0.0)
    ap.add_argument("--max-restarts", type=int, default=0,
                    help="supervise the run: restart from the last "
                         "checkpoint on failure, up to N times "
                         "(requires --ckpt-dir and --ckpt-every)")
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)

    from repro.train.loop import train, train_supervised
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()

    plan = None
    if args.mesh == "host":
        from repro.dist.sharding import Plan
        from repro.launch.mesh import make_host_mesh
        plan = Plan.make(make_host_mesh())

    kw = dict(steps=args.steps, global_batch=args.batch,
              seq_len=args.seq, plan=plan, ckpt_dir=args.ckpt_dir,
              ckpt_every=args.ckpt_every, resume=args.resume,
              seed=args.seed, deadline_s=args.deadline_s)
    if args.max_restarts > 0:
        res = train_supervised(cfg, max_restarts=args.max_restarts, **kw)
    else:
        res = train(cfg, **kw)
    print(f"steps={res.steps} wall={res.wall_s:.1f}s "
          f"first_loss={res.losses[0][1]:.4f} last_loss={res.losses[-1][1]:.4f}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(dataclasses_asdict(res), f)
    return 0


def dataclasses_asdict(res):
    return dict(losses=res.losses, steps=res.steps, restarts=res.restarts,
                wall_s=res.wall_s)


if __name__ == "__main__":
    raise SystemExit(main())
