"""Serving launcher CLI (batched requests against a smoke-scale model).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
      --batch 4 --prompt-len 16 --max-new 16 --policy continuous --slots 4

--metrics-json PATH dumps the engine telemetry (slot occupancy, admitted /
evicted counters, tokens/sec, per-step latency histogram — see
docs/observability.md) on exit; --metrics-interval N rewrites it every N
seconds while generating.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import obs
from repro.configs import get_config
from repro.models import common
from repro.models import transformer as T
from repro.serve import ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--policy", choices=["continuous", "static"],
                    default="continuous")
    ap.add_argument("--slots", type=int, default=0,
                    help="decode slots (0: one per batch row)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="paged-KV page size in tokens")
    ap.add_argument("--metrics-json", default=None,
                    help="write the telemetry dump to this path on exit")
    ap.add_argument("--metrics-interval", type=float, default=0.0,
                    help="also rewrite --metrics-json every N seconds "
                         "while running (0: only the final dump)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    pos_off = cfg.vision_tokens if cfg.vision_dim else 0
    params = common.materialize(T.lm_shapes(cfg), jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params,
                      cache_len=args.prompt_len + pos_off + args.max_new,
                      temperature=args.temperature, seed=args.seed,
                      policy=args.policy, n_slots=args.slots,
                      page_size=args.page_size)
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(2, cfg.vocab, size=(args.batch, args.prompt_len),
                           dtype=np.int32)
    dumper = None
    if args.metrics_json and args.metrics_interval > 0:
        dumper = obs.PeriodicDumper(args.metrics_json,
                                    args.metrics_interval)
    t0 = time.time()
    with obs.span("serve.generate", batch=args.batch,
                  max_new=args.max_new) as sp:
        out = eng.generate(prompts, max_new=args.max_new)
        sp.sync(out)
    dt = time.time() - t0
    if dumper is not None:
        dumper.stop()          # writes the final dump
    elif args.metrics_json:
        obs.dump_json(args.metrics_json)
    n_tok = out.size
    print(f"generated {n_tok} tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s incl. prefill+compile)")
    print("sample:", out[0][:16].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
