"""Trip-count-corrected cost extraction from optimized (post-SPMD) HLO text.

XLA's built-in `compiled.cost_analysis()` visits every while-loop body
exactly once, so scan-over-layers models under-report FLOPs/bytes by ~L x.
The optimized HLO carries `backend_config={"known_trip_count":{"n":K}}` on
each while op; this module walks the computation call graph (while bodies,
fusions, calls, conditionals) multiplying costs by enclosing trip counts:

  flops            — dot ops: 2 * prod(output dims) * prod(contracting dims)
  bytes accessed   — per real op: operand bytes + output bytes (fusions at
                     their boundary, metadata ops free) — XLA's convention
  collective bytes — per-chip traffic by op type with ring multipliers
                     (all-reduce 2x, others 1x), shapes are per-partition

All numbers are per-chip (the SPMD module is the per-partition program).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c64": 8, "f32": 4, "s32": 4,
                "u32": 4, "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
                "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
                "s4": 1, "u4": 1}
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_OPKIND_RE = re.compile(r"^\s*((?:\([^)]*\)|[a-z0-9\[\]{},/* ]+?))\s*"
                        r"([a-z][a-z0-9\-]*)\(")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_CALLS_RE = re.compile(r"(?:calls|to_apply|body|condition|branch_computations)="
                       r"{?([%\w.\-, ]+)}?")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

_COLL_MULT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
              "all-to-all": 1.0, "collective-permute": 1.0}
_META_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "partition-id", "replica-id", "iota",
             "opt-barrier"}

# HBM-traffic ops: on TPU, elementwise chains fuse and never round-trip HBM;
# counting every unfused CPU-HLO op would wildly overstate the memory term.
# We count ops that genuinely move data on TPU: contractions, fusion
# boundaries, layout changes, gathers/scatters, reductions, sorts, DUS.
_BYTES_KINDS = {"dot", "convolution", "fusion", "copy", "transpose",
                "dynamic-slice", "dynamic-update-slice", "gather", "scatter",
                "reduce", "reduce-window", "sort", "select-and-scatter",
                "pad", "concatenate", "cholesky", "triangular-solve",
                "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute"}


def _shapes_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        n = 1
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(m.group(1), 4)
    return total


def _prod_dims(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class _Op:
    name: str
    kind: str
    out_text: str       # LHS type text
    rhs: str            # full RHS after '='


class HloCost:
    def __init__(self, hlo_text: str):
        self.comps: dict[str, list[_Op]] = {}
        self.shapes: dict[str, dict[str, str]] = {}   # comp -> op -> out text
        self.entry = None
        self._parse(hlo_text)
        self._memo: dict[str, tuple] = {}

    def _parse(self, text: str):
        cur = None
        for raw in text.splitlines():
            line = raw.rstrip()
            s = line.strip()
            if not s or s.startswith("//") or s.startswith("HloModule"):
                continue
            if (line.startswith("%") or line.startswith("ENTRY")) and s.endswith("{"):
                name = s.split()[1] if line.startswith("ENTRY") else s.split()[0]
                name = name.lstrip("%").split("(")[0].rstrip()
                # handle 'ENTRY %main.1 (...) -> ... {'
                if line.startswith("ENTRY"):
                    self.entry = name
                cur = name
                self.comps[cur] = []
                self.shapes[cur] = {}
                continue
            if s == "}" or cur is None:
                continue
            m = _DEF_RE.match(line)
            if not m:
                continue
            opname, rhs = m.group(1), m.group(2)
            km = _OPKIND_RE.match(rhs)
            if not km:
                continue
            out_text, kind = km.group(1), km.group(2)
            self.comps[cur].append(_Op(opname, kind, out_text, rhs))
            self.shapes[cur][opname] = out_text

    # ---- per-op costs ----------------------------------------------------
    def _dot_flops(self, comp: str, op: _Op) -> float:
        out_elems = 1
        for m in _SHAPE_RE.finditer(op.out_text):
            out_elems *= _prod_dims(m.group(2))
        cm = re.search(r"lhs_contracting_dims={([0-9,]*)}", op.rhs)
        if not cm:
            return 2.0 * out_elems
        # resolve lhs operand shape
        par = op.rhs[op.rhs.find("(") + 1:]
        om = _OPERAND_RE.search(par)
        k = 1
        if om:
            lhs_shape = self.shapes[comp].get(om.group(1), "")
            sm = _SHAPE_RE.search(lhs_shape)
            if sm:
                dims = [int(d) for d in sm.group(2).split(",") if d]
                for ci in cm.group(1).split(","):
                    if ci and int(ci) < len(dims):
                        k *= dims[int(ci)]
        return 2.0 * out_elems * k

    def _conv_flops(self, comp: str, op: _Op) -> float:
        out_elems = 1
        for m in _SHAPE_RE.finditer(op.out_text):
            out_elems *= _prod_dims(m.group(2))
        par = op.rhs[op.rhs.find("(") + 1:]
        ops = _OPERAND_RE.findall(par)
        k = 1
        if len(ops) >= 2:
            rhs_shape = self.shapes[comp].get(ops[1], "")
            sm = _SHAPE_RE.search(rhs_shape)
            if sm:
                dims = [int(d) for d in sm.group(2).split(",") if d]
                k = max(1, _prod_dims(",".join(map(str, dims))) //
                        max(dims[-1] if dims else 1, 1))
        return 2.0 * out_elems * k

    def _op_bytes(self, comp: str, op: _Op) -> float:
        base = op.kind[:-6] if op.kind.endswith("-start") else op.kind
        if base not in _BYTES_KINDS:
            return 0.0
        out_b = float(_shapes_bytes(op.out_text))
        par = op.rhs[op.rhs.find("(") + 1: op.rhs.find(")", op.rhs.find("("))]
        op_bytes = [
            float(_shapes_bytes(self.shapes[comp].get(om.group(1), "")))
            for om in _OPERAND_RE.finditer(par)]
        if base in ("dynamic-update-slice", "fusion"):
            # in-place update pattern (scan carries / cache writes): an
            # operand with the same size as the output aliases it — only the
            # updated slice moves, not the whole buffer.
            for i, b in enumerate(op_bytes):
                if b == out_b and out_b > 0:
                    rest = sum(op_bytes) - b
                    return 2.0 * rest  # read-modify-write of the slice(s)
        return out_b + sum(op_bytes)

    def _children(self, op: _Op) -> tuple[list[str], float]:
        """(called computations, trip multiplier)."""
        called: list[str] = []
        for cm in re.finditer(
                r"(?:calls|to_apply|condition|body)=%([\w.\-]+)", op.rhs):
            called.append(cm.group(1))
        bm = re.search(r"branch_computations={([^}]*)}", op.rhs)
        if bm:
            called += [c.strip().lstrip("%") for c in bm.group(1).split(",")]
        trip = 1.0
        if op.kind == "while":
            tm = _TRIP_RE.search(op.rhs)
            trip = float(tm.group(1)) if tm else 1.0
        return called, trip

    # ---- walk ---------------------------------------------------------------
    def _comp_cost(self, comp: str):
        if comp in self._memo:
            return self._memo[comp]
        flops = 0.0
        bytes_ = 0.0
        coll = defaultdict(float)
        coll_n = defaultdict(float)
        for op in self.comps.get(comp, []):
            if op.kind == "dot":
                flops += self._dot_flops(comp, op)
            elif op.kind == "convolution":
                flops += self._conv_flops(comp, op)
            base = op.kind[:-6] if op.kind.endswith("-start") else op.kind
            if base in _COLL_MULT:
                b = float(_shapes_bytes(op.out_text)) * _COLL_MULT[base]
                coll[base] += b
                coll_n[base] += 1
            bytes_ += self._op_bytes(comp, op)
            called, trip = self._children(op)
            for c in called:
                if c not in self.comps:
                    continue
                cf, cb, cc, cn = self._comp_cost(c)
                # fusions: costs at the boundary, but dots inside count
                if op.kind == "fusion":
                    flops += cf
                    for k, v in cc.items():
                        coll[k] += v
                        coll_n[k] += cn[k]
                else:
                    flops += trip * cf
                    bytes_ += trip * cb
                    for k, v in cc.items():
                        coll[k] += trip * v
                        coll_n[k] += trip * cn[k]
        res = (flops, bytes_, dict(coll), dict(coll_n))
        self._memo[comp] = res
        return res

    def totals(self) -> dict:
        f, b, c, n = self._comp_cost(self.entry)
        return dict(flops=f, bytes=b,
                    collective_bytes=float(sum(c.values())),
                    collective_bytes_by_op=c, collective_counts=n)


def analyze(hlo_text: str) -> dict:
    return HloCost(hlo_text).totals()
