"""Production mesh builders.

Functions, not module-level constants: importing this module never touches
jax device state (required for the dry-run's forced 512-host-device setup).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod ("data","model"); multi-pod adds a leading
    2-pod axis (DCN) -> (2,16,16) ("pod","data","model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Degenerate mesh over the actually-available local devices (smoke
    tests / CPU examples)."""
    n = len(jax.devices())
    mp = max(1, min(model_parallel, n))
    return jax.make_mesh((n // mp, mp), ("data", "model"))
