"""Partitioner launcher CLI (single-device or mesh-sharded refinement).

  PYTHONPATH=src python -m repro.launch.partition --graph snn --nodes 400 \
      --omega 32 --delta 128 --theta 8 [--mesh host --replicas 2] \
      [--no-race] [--json out.json]

--mesh none runs the classic single-device `core.partitioner.partition`;
--mesh host builds a (replicas, n_local_devices // replicas) Plan over the
locally visible devices and routes the whole V-cycle on-mesh: coarsening
through `dist.partition.coarsen_level`/`contract_level` (sharded pairs/pins
pipelines over "model"; `--single-coarsen` keeps coarsening on one device)
and refinement through `dist.partition.refine_level` (replica racing over
"data", sharded pins pipelines over "model"). `--repartition-from prev.json`
warm-starts from an earlier run's `--json` dump (refine-only, no
coarsening; `--perturb-edges N` applies a synthetic incremental delta
first, and drift / audit failures fall back to a cold V-cycle
automatically). `--shard-graph` additionally
memory-shards the graph *storage* (pins-sized arrays as per-shard stripes
over "model", shared by the racing replicas — `dist.graph`). Force a
multi-device CPU run with
XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
from __future__ import annotations

import argparse
import json


def build_plan(replicas: int):
    import jax
    from repro.dist.sharding import Plan

    n = len(jax.devices())
    r = max(1, min(replicas, n))
    mesh = jax.make_mesh((r, n // r), ("data", "model"))
    return Plan.make(mesh)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", choices=["snn", "smallworld", "ispd"],
                    default="snn")
    ap.add_argument("--nodes", type=int, default=400)
    ap.add_argument("--omega", type=int, default=32)
    ap.add_argument("--delta", type=int, default=128)
    ap.add_argument("--theta", type=int, default=8)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--mesh", choices=["none", "host"], default="none")
    ap.add_argument("--replicas", type=int, default=1,
                    help="data-axis size (racing repetitions) of the host "
                         "mesh; remaining devices shard the pipelines")
    ap.add_argument("--no-race", action="store_true",
                    help="identity tie-breaks on every replica "
                         "(deterministic parity mode)")
    ap.add_argument("--single-coarsen", action="store_true",
                    help="keep coarsening single-device (refinement still "
                         "runs on the mesh)")
    ap.add_argument("--shard-graph", action="store_true",
                    help="memory-shard the graph storage: pins-sized arrays "
                         "live as per-shard stripes over the mesh's model "
                         "axis (racing replicas share the one sharded copy); "
                         "bit-identical results, O(pins/shards) storage per "
                         "device (requires --mesh host)")
    ap.add_argument("--compensated-psum", action="store_true",
                    help="combine the coarsening eta / matching-sum0 float "
                         "reductions with the Neumaier-compensated psum "
                         "(O(dense) traffic, ~1 ulp; drops bit-exact parity "
                         "with the single-device run)")
    ap.add_argument("--use-kernels", action="store_true",
                    help="dispatch the pair-scores / gains / pins-count hot "
                         "loops through the Pallas kernels where the "
                         "fits_kernel bounds allow (stripe-local under a "
                         "mesh); the per-level outcome is reported as "
                         "kernel_path in the output")
    ap.add_argument("--race-seed", type=int, default=0)
    ap.add_argument("--json", default=None)
    ap.add_argument("--repartition-from", default=None, metavar="PREV.json",
                    help="warm-start from a previous --json dump: skip "
                         "coarsening and re-refine from its parts vector "
                         "(core.partitioner.repartition; drift/audit "
                         "fallbacks to a cold V-cycle are automatic). The "
                         "dump must come from the same --graph/--nodes/"
                         "--seed so the parts align")
    ap.add_argument("--perturb-edges", type=int, default=0, metavar="N",
                    help="apply a synthetic GraphDelta before solving: "
                         "delete N random h-edges and insert N fresh "
                         "similar-shaped ones (generate.perturb_delta; "
                         "deterministic in --perturb-seed)")
    ap.add_argument("--perturb-seed", type=int, default=0)
    ap.add_argument("--drift-threshold", type=float, default=0.25,
                    help="fraction of pins touched by deltas above which a "
                         "warm --repartition-from solve falls back to the "
                         "cold V-cycle")
    args = ap.parse_args(argv)

    from repro.core import generate
    from repro.core.partitioner import partition, repartition

    if args.graph == "snn":
        hg = generate.snn_layered(n_layers=5, width=max(args.nodes // 5, 4),
                                  fanout=10, seed=args.seed)
    elif args.graph == "smallworld":
        hg = generate.snn_smallworld(n_nodes=args.nodes, fanout=10,
                                     seed=args.seed)
    else:
        hg = generate.ispd_like(n_nodes=args.nodes, seed=args.seed)
    print("hypergraph:", hg.stats())

    plan = build_plan(args.replicas) if args.mesh == "host" else None
    if args.shard_graph and plan is None:
        raise SystemExit("--shard-graph requires --mesh host (graph stripes "
                         "live on the mesh's model axis)")

    deltas = []
    if args.perturb_edges > 0:
        deltas.append(generate.perturb_delta(hg, n_edges=args.perturb_edges,
                                             seed=args.perturb_seed))
    common = dict(theta=args.theta, plan=plan, race=not args.no_race,
                  race_seed=args.race_seed,
                  dist_coarsen=not args.single_coarsen,
                  compensated_psum=args.compensated_psum,
                  shard_graph=args.shard_graph,
                  use_kernels=args.use_kernels)
    if args.repartition_from:
        with open(args.repartition_from) as f:
            prev = json.load(f)
        if prev.get("parts") is None:
            raise SystemExit(f"{args.repartition_from} carries no parts "
                             "vector (written by an older run?)")
        if len(prev["parts"]) != hg.n_nodes:
            raise SystemExit(
                f"previous parts vector has {len(prev['parts'])} entries "
                f"for {hg.n_nodes} nodes — same --graph/--nodes/--seed?")
        res = repartition(hg, prev["parts"], args.omega, args.delta,
                          deltas=deltas,
                          drift_threshold=args.drift_threshold, **common)
        print(f"repartition mode={res.mode} "
              f"(warm refine {res.timings['refine']:.3f}s, "
              f"total {res.timings['total']:.3f}s vs previous total "
              f"{prev.get('timings', {}).get('total', float('nan')):.3f}s)")
    else:
        for dl in deltas:
            from repro.core.hypergraph import apply_delta
            apply_delta(hg, dl)
        res = partition(hg, omega=args.omega, delta=args.delta, **common)
    out = dict(
        connectivity=res.connectivity, cut_net=res.cut_net,
        n_parts=res.n_parts, n_levels=res.n_levels,
        size_ok=bool(res.audit["size_ok"]),
        inbound_ok=bool(res.audit["inbound_ok"]),
        timings=res.timings,
        mode=res.mode,
        parts=[int(p) for p in res.parts],
        kernel_path=res.kernel_path if args.use_kernels else None,
        mesh=(dict(plan.mesh.shape) if plan is not None else None),
        race=(not args.no_race) if plan is not None else None,
        dist_coarsen=(not args.single_coarsen) if plan is not None else None,
        shard_graph=args.shard_graph if plan is not None else None,
    )
    # stdout skips the parts vector (noise at scale); the --json dump keeps
    # it — that is what --repartition-from reloads
    print(json.dumps({k: v for k, v in out.items() if k != "parts"},
                     indent=2))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)


if __name__ == "__main__":
    main()
