"""Assigned architecture config: deepseek-v2-236b (see registry.py for the
exact hyperparameters and source citation)."""
from repro.configs.registry import get_config

ARCH = "deepseek-v2-236b"
CONFIG = get_config(ARCH)
SMOKE = CONFIG.smoke()
