"""Assigned architecture config: jamba-v0.1-52b (see registry.py for the
exact hyperparameters and source citation)."""
from repro.configs.registry import get_config

ARCH = "jamba-v0.1-52b"
CONFIG = get_config(ARCH)
SMOKE = CONFIG.smoke()
