"""The 10 assigned architectures (+ the paper's own SNN workloads live in
repro.core.generate). Exact configs from the assignment table; sources and
verification tiers recorded in `notes`.
"""
from __future__ import annotations

from repro.configs.base import (ArchConfig, LayerSpec, MLACfg, MambaCfg,
                                MoECfg)

_L = LayerSpec


def _dense(name, n_layers, d_model, n_heads, n_kv, d_ff, vocab, **kw):
    return ArchConfig(name=name, family="dense", n_layers=n_layers,
                      d_model=d_model, n_heads=n_heads, n_kv=n_kv, d_ff=d_ff,
                      vocab=vocab, pattern=(_L("attn", "mlp"),), **kw)


CONFIGS: dict[str, ArchConfig] = {}


def _reg(cfg: ArchConfig) -> ArchConfig:
    CONFIGS[cfg.name] = cfg
    return cfg


# --- dense ------------------------------------------------------------------
_reg(_dense("minitron-8b", 32, 4096, 32, 8, 16384, 256000,
            notes="pruned nemotron [arXiv:2407.14679; hf]"))
_reg(_dense("yi-34b", 60, 7168, 56, 8, 20480, 64000,
            notes="llama-arch GQA [arXiv:2403.04652; hf]"))
_reg(_dense("phi4-mini-3.8b", 32, 3072, 24, 8, 8192, 200064,
            notes="RoPE SwiGLU GQA [arXiv:2412.08905; hf]"))
_reg(_dense("qwen2-1.5b", 28, 1536, 12, 2, 8960, 151936, qkv_bias=True,
            notes="GQA, QKV bias [arXiv:2407.10671; hf]"))

# --- ssm: xLSTM (7 mLSTM : 1 sLSTM interleave) -------------------------------
_reg(ArchConfig(
    name="xlstm-350m", family="ssm", n_layers=24, d_model=1024, n_heads=4,
    n_kv=4, d_ff=0, vocab=50304, subquadratic=True,
    pattern=tuple([_L("mlstm", "none")] * 7 + [_L("slstm", "none")]),
    notes="sLSTM + mLSTM blocks [arXiv:2405.04517; unverified]; d_ff=0: "
          "xLSTM blocks carry their own up/down projections"))

# --- moe ---------------------------------------------------------------------
_reg(ArchConfig(
    name="llama4-scout-17b-16e", family="moe", n_layers=48, d_model=5120,
    n_heads=40, n_kv=8, d_ff=8192, vocab=202048,
    pattern=(_L("attn", "moe"),),
    moe=MoECfg(n_experts=16, top_k=1, d_ff_expert=8192, n_shared=1),
    notes="MoE 16e top-1 + shared expert, early fusion "
          "[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"))
_reg(ArchConfig(
    name="deepseek-v2-236b", family="moe", n_layers=60, d_model=5120,
    n_heads=128, n_kv=128, d_ff=1536, vocab=102400, d_head=192,
    pattern=(_L("mla", "moe"),), first_k_dense=1,
    mla=MLACfg(kv_lora=512, q_lora=1536, d_nope=128, d_rope=64, d_v=128),
    moe=MoECfg(n_experts=160, top_k=6, d_ff_expert=1536, n_shared=2),
    notes="MLA kv_lora=512, 2 shared + 160 routed top-6 "
          "[arXiv:2405.04434; hf]"))

# --- audio (enc-dec; conv frontend is a stub per the assignment) -------------
_reg(ArchConfig(
    name="whisper-tiny", family="audio", n_layers=4, d_model=384, n_heads=6,
    n_kv=6, d_ff=1536, vocab=51865, pos="learned", norm="ln",
    pattern=(_L("attn", "mlp"),), encoder_layers=4,
    max_source_positions=1500, tie_embeddings=True,
    notes="enc-dec, conv frontend stub [arXiv:2212.04356; unverified]"))

# --- vlm (InternViT frontend is a stub per the assignment) -------------------
_reg(ArchConfig(
    name="internvl2-2b", family="vlm", n_layers=24, d_model=2048, n_heads=16,
    n_kv=8, d_ff=8192, vocab=92553, qkv_bias=False,
    pattern=(_L("attn", "mlp"),), vision_tokens=256, vision_dim=1024,
    notes="InternViT(stub) + InternLM2 [arXiv:2404.16821; hf]"))

# --- hybrid: jamba (mamba:attn 7:1 interleave, MoE every other layer) --------
_reg(ArchConfig(
    name="jamba-v0.1-52b", family="hybrid", n_layers=32, d_model=4096,
    n_heads=32, n_kv=8, d_ff=14336, vocab=65536, subquadratic=True,
    pattern=(
        _L("mamba", "mlp"), _L("mamba", "moe"), _L("mamba", "mlp"),
        _L("mamba", "moe"), _L("attn", "mlp"), _L("mamba", "moe"),
        _L("mamba", "mlp"), _L("mamba", "moe"),
    ),
    moe=MoECfg(n_experts=16, top_k=2, d_ff_expert=14336),
    mamba=MambaCfg(d_state=16, d_conv=4, expand=2),
    notes="Mamba+attn 1:7 interleave, MoE 16e top-2 every other layer "
          "[arXiv:2403.19887; hf]"))


def get_config(name: str) -> ArchConfig:
    if name not in CONFIGS:
        raise KeyError(f"unknown arch '{name}'; have {sorted(CONFIGS)}")
    return CONFIGS[name]


def list_configs() -> list[str]:
    return sorted(CONFIGS)


# shape cells from the assignment (LM shapes: seq_len x global_batch)
SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


def cell_is_runnable(arch: str, shape: str) -> tuple[bool, str]:
    cfg = get_config(arch)
    if shape == "long_500k" and not cfg.subquadratic:
        return False, ("skip: pure full-attention arch; 512k dense-KV decode "
                       "requires sub-quadratic mixer (DESIGN.md "
                       "SArch-applicability)")
    return True, ""
