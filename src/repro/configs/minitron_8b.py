"""Assigned architecture config: minitron-8b (see registry.py for the
exact hyperparameters and source citation)."""
from repro.configs.registry import get_config

ARCH = "minitron-8b"
CONFIG = get_config(ARCH)
SMOKE = CONFIG.smoke()
