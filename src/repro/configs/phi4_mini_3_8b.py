"""Assigned architecture config: phi4-mini-3.8b (see registry.py for the
exact hyperparameters and source citation)."""
from repro.configs.registry import get_config

ARCH = "phi4-mini-3.8b"
CONFIG = get_config(ARCH)
SMOKE = CONFIG.smoke()
