"""Assigned architecture config: llama4-scout-17b-16e (see registry.py for the
exact hyperparameters and source citation)."""
from repro.configs.registry import get_config

ARCH = "llama4-scout-17b-16e"
CONFIG = get_config(ARCH)
SMOKE = CONFIG.smoke()
