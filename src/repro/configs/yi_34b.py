"""Assigned architecture config: yi-34b (see registry.py for the
exact hyperparameters and source citation)."""
from repro.configs.registry import get_config

ARCH = "yi-34b"
CONFIG = get_config(ARCH)
SMOKE = CONFIG.smoke()
