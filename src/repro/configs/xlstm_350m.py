"""Assigned architecture config: xlstm-350m (see registry.py for the
exact hyperparameters and source citation)."""
from repro.configs.registry import get_config

ARCH = "xlstm-350m"
CONFIG = get_config(ARCH)
SMOKE = CONFIG.smoke()
