"""Assigned architecture config: qwen2-1.5b (see registry.py for the
exact hyperparameters and source citation)."""
from repro.configs.registry import get_config

ARCH = "qwen2-1.5b"
CONFIG = get_config(ARCH)
SMOKE = CONFIG.smoke()
