"""Assigned architecture config: whisper-tiny (see registry.py for the
exact hyperparameters and source citation)."""
from repro.configs.registry import get_config

ARCH = "whisper-tiny"
CONFIG = get_config(ARCH)
SMOKE = CONFIG.smoke()
