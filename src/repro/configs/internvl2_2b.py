"""Assigned architecture config: internvl2-2b (see registry.py for the
exact hyperparameters and source citation)."""
from repro.configs.registry import get_config

ARCH = "internvl2-2b"
CONFIG = get_config(ARCH)
SMOKE = CONFIG.smoke()
