"""Architecture configuration schema for the assigned model pool."""
from __future__ import annotations

import dataclasses
from typing import Literal


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0           # shared experts (deepseek: 2, llama4: 1)
    capacity_factor: float = 1.25
    router_aux_coef: float = 1e-2


@dataclasses.dataclass(frozen=True)
class MLACfg:
    kv_lora: int = 512
    q_lora: int = 1536
    d_nope: int = 128
    d_rope: int = 64
    d_v: int = 128


@dataclasses.dataclass(frozen=True)
class MambaCfg:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: Literal["attn", "mla", "mamba", "mlstm", "slstm"] = "attn"
    ffn: Literal["mlp", "moe", "none"] = "mlp"


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    d_head: int = 0              # 0 -> d_model // n_heads
    qkv_bias: bool = False
    pos: str = "rope"            # rope | learned | none
    rope_theta: float = 1e4
    norm: str = "rms"            # rms | ln
    tie_embeddings: bool = False
    pattern: tuple[LayerSpec, ...] = (LayerSpec(),)
    first_k_dense: int = 0       # leading unscanned dense layers (deepseek)
    moe: MoECfg | None = None
    mla: MLACfg | None = None
    mamba: MambaCfg | None = None
    # encoder-decoder (whisper): encoder layer count; frontend is a stub
    encoder_layers: int = 0
    max_source_positions: int = 1500
    # vlm: stub frontend provides [B, vision_tokens, vision_dim] embeddings
    vision_tokens: int = 0
    vision_dim: int = 0
    subquadratic: bool = False   # eligible for long_500k
    max_seq: int = 524288
    # attention compute chunking (flash-style online softmax in pure JAX)
    q_chunk: int = 1024
    k_chunk: int = 1024
    notes: str = ""

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)
        assert self.n_layers % len(self.pattern) == 0 or self.first_k_dense, \
            (self.name, self.n_layers, len(self.pattern))

    @property
    def n_superblocks(self) -> int:
        return (self.n_layers - self.first_k_dense) // len(self.pattern)

    def scaled(self, **overrides) -> "ArchConfig":
        return dataclasses.replace(self, **overrides)

    def smoke(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        moe = None
        if self.moe:
            moe = dataclasses.replace(
                self.moe, n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2), d_ff_expert=64,
                n_shared=min(self.moe.n_shared, 1))
        mla = dataclasses.replace(self.mla, kv_lora=32, q_lora=48, d_nope=16,
                                  d_rope=8, d_v=16) if self.mla else None
        mamba = dataclasses.replace(self.mamba, d_state=4) if self.mamba else None
        return dataclasses.replace(
            self, n_layers=2 * len(self.pattern) + self.first_k_dense,
            d_model=64, n_heads=4, n_kv=min(self.n_kv, 2), d_head=16,
            d_ff=128, vocab=256, moe=moe, mla=mla, mamba=mamba,
            encoder_layers=min(self.encoder_layers, 2),
            vision_tokens=min(self.vision_tokens, 8),
            vision_dim=min(self.vision_dim, 32) if self.vision_dim else 0,
            max_seq=512, q_chunk=32, k_chunk=32, max_source_positions=64)


# FLOPs accounting: 6 * N_active * D for training; N from specs at runtime.
def active_param_fraction(cfg: ArchConfig) -> float:
    """Rough active/total ratio for MoE archs (dense: 1.0)."""
    if not cfg.moe:
        return 1.0
    act = cfg.moe.top_k + cfg.moe.n_shared
    return act / (cfg.moe.n_experts + cfg.moe.n_shared)
