"""Mesh-sharded V-cycle drivers: sharded coarsening + raced/sharded
refinement.

The paper's 380x refinement speedup (Sec. VI) comes from two levels of
parallelism that a single-device run serializes: the Theta independent
repetitions per level, and the massive pins/pairs-sized kernels inside each
repetition. This module maps both onto a `Plan` mesh with one `shard_map`:

* **"data" axis — replicated racing repetitions.** Every device runs a full
  repetition from the same partition vector but with a *distinct tie-break
  permutation* threaded through chain construction (`build_sequence`'s sort
  keys, successor-claim argmax, and cycle-cut anchor). Replica 0 keeps the
  identity permutation, so the single-device trajectory is always in the
  race. After the events check, a tiny all-gather of the per-replica applied
  gains + argmax (ties -> lowest replica) picks the winner, whose applied
  prefix is broadcast with a psum of the masked partition vector — no
  partition-sized gather. Mt-KaHyPar-style independent repetitions, raced
  instead of sequenced.

* **"model" axis — sharded pins-sized pipelines.** Each pins/pairs-sized
  stage of `core.refine` processes one contiguous lane stripe per device
  (`segops.ShardCtx.lanes`) and combines *dense* per-node / per-partition
  segment outputs with psum — the all-gather-free segment reduction.
  Segmented scans over the sorted events run stripe-local with cross-shard
  carries (`segops.sharded_segmented_scan`).

Paper Sec. VI kernel -> sharded counterpart:

  pins(p,e) matrix precompute (VI-B)   -> `refine.pins_matrix(ctx)`: lane
      stripes + psum of the dense [kcap, Ecap] count matrices
  warp-per-node gain loops (VI-B)      -> `refine.propose_moves(ctx)`:
      striped incidence traversal, psum'd saving / w_tot / conn_w
  grade claims via atomics (VI-C)      -> replicated `build_sequence` with
      per-replica `tie_rank` (node-sized; raced, not sharded)
  pair-expansion Eq. 14/15 (VI-C)      -> `refine.inseq_gains(ctx)`: pair
      lanes striped via `build_pairs(idx)`, psum'd (n,e) counts
  CUB sort + segmented scan (VI-D)     -> `refine.events_validity(ctx)`:
      striped event construction, distributed sample sort (`dist.sort` via
      `ShardCtx.sort_by` — stripes in/out, only splitter samples gathered),
      stripe-local scans with cross-shard carries, psum'd violation deltas

Coarsening (`coarsen_level` / `contract_level`, paper Sec. V-B..V-E) shards
the same way over "model" and is deterministic, so it never races — on a
mesh with a data axis the replica rows simply compute identical levels.
Paper kernel -> sharded counterpart:

  pair-expansion scoring Eq. 5 (V-B/C)  -> `coarsen.score_slots(ctx)`: lane
      stripes + stripe-local segmented binary search
  in-histogram inter counter (Fig. 3)   -> same stripes, psum'd dense
      integer counts
  top-Pi candidate selection (V-C)      -> `coarsen.propose(ctx)`: Pi-round
      `segment_argmax` on slot-lane stripes, cross-shard lexicographic
      (value, id) pmax, winner slot retired on its owning shard
  matching DP wavefront Eq. 7-12 (V-D)  -> `matching.match_pseudoforest
      (ctx)`: replicated state, child-lane stripes per iteration
  contraction dedup + packing (V-E)     -> `contract.contract_impl(ctx)`:
      striped key construction, distributed sample sorts, stripe-local rank
      scans with cross-shard carries, psum'd disjoint scatters

What travels how — the exactness contract. Float32 addition is not
associative, so a psum of float partial sums lands within an ulp of — but
not bit-identical to — the single-device accumulation (measured: tens of
mismatched slots per level at 8 shards), which is enough to flip an argmax
and diverge the whole V-cycle. Every sharded reduction therefore picks one
of three combines:

  * psum     — integer counts only (inter, matching cnt ticks, contraction
               counts and disjoint pin scatters): exact in any order.
  * pmax     — (value, id) lexicographic claims (candidate rounds, matching
               best-child): pure maxes, exact in any order.
  * gather   — float sums (eta histograms, matching sum0 pushes) gather
               their lane columns in stripe order, i.e. the global lane
               order, and reduce replicated: the scatter-add order is then
               bit-identical to the single-device sweep. (Opt-in
               `compensated` trades this for a Neumaier-compensated psum
               of dense partials — O(dense) traffic, ~1 ulp, not
               bit-identical.) Sorts no longer gather at all: every sort is
               the distributed sample sort of `dist.sort`, whose global-rank
               tie key makes it bit-identical to the gathered stable
               `lax.sort` by construction.

Contraction is bit-exact by construction — its whole pipeline is integer —
so the contracted hypergraph, not just the final parts vector, matches the
single-device level byte-for-byte; refinement then starts each level from
identical state.

**Sharded graph storage** (`dist.graph.ShardedHypergraph`): the pins-sized
storage arrays may additionally arrive as per-shard lane stripes over
"model" instead of replicated copies (`--shard-graph`; racing replicas
then share the one sharded graph across "data"). The exactness rules
extend unchanged, because striping is pure layout:

  * own-stripe reads (`ShardCtx.gread`) return exactly the replicated
    array's values at this shard's lane positions — every pins/pairs
    pipeline stage already indexed only its own lanes;
  * the one arbitrary-position access (`build_pairs` joining two pin
    slots per pair lane) transiently rebuilds the pins column with the
    bit-preserving `ShardCtx.gfull` (psum of disjoint int32 stripes, the
    `unstripe` combine — never a float psum);
  * contraction emits the coarse pins arrays as stripes (reduce-scatter
    of the integer packing scatter + stripe-kept incidence sort) — the
    same integers the replicated path scatters, in the same slots, so
    levels stay striped end-to-end and stay bit-exact.

Exactness: with racing off (or on the 1-replica data axis) every replica
uses the identity permutation, and with the combine discipline above every
sharded stage of both coarsening and refinement reproduces the
single-device arithmetic exactly, so the full V-cycle is bit-identical to
`core.partitioner.partition` — with replicated *or* memory-sharded graph
storage — enforced by the parity tests in tests/test_dist_partition.py
under 8 forced host devices on (2, 4) and (1, 8) meshes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.coarsen import CoarsenParams, coarsen_step_impl
from repro.core.contract import contract_impl
from repro.core.hypergraph import Caps
from repro.core.refine import RefineParams, refine_step_impl
from repro.dist.graph import ShardedHypergraph, graph_pspecs
from repro.dist.sharding import Plan
from repro.models import common
from repro.utils import segops


def _graph_arg(d):
    """(inner DeviceHypergraph, storage-striped?) — the drivers accept
    replicated `DeviceHypergraph`s and memory-sharded `ShardedHypergraph`s
    interchangeably; the wrapper is the dispatch marker."""
    if isinstance(d, ShardedHypergraph):
        return d.g, True
    return d, False


def plan_axes(plan: Plan) -> tuple[str | None, str | None, int]:
    """(replica axis or None, pipeline-shard axis or None, shard count).

    The replica axis must be distinct from the pipeline-shard axis: the
    sharded pipelines psum partial sums over "model" assuming every shard
    holds the *same* move sequence, so replicas may never diverge along it.
    On a mesh whose only axis is "model" the driver therefore shards the
    pipelines and skips racing."""
    names = tuple(plan.mesh.axis_names)
    model_axis = ("model" if "model" in names
                  and plan.mesh.shape["model"] > 1 else None)
    nshards = plan.mesh.shape["model"] if model_axis else 1
    if "data" in names:
        data_axis = "data"
    else:
        cands = [a for a in names if a != "model"]
        data_axis = cands[0] if cands else None
    # a 1-replica axis cannot race: collapse it so the step skips the
    # per-repetition permutation + winner collectives entirely
    if data_axis is not None and plan.mesh.shape[data_axis] <= 1:
        data_axis = None
    return data_axis, model_axis, nshards


@functools.lru_cache(maxsize=None)
def _build_step(mesh, data_axis: str, model_axis: str | None, nshards: int,
                caps: Caps, kcap: int, params: RefineParams, race: bool,
                striped: bool = False):
    """One raced+sharded repetition, jitted; cached per static signature so
    the host-driven level loop compiles once per capacity bucket (exactly
    like `core.refine.refine_step`). ``striped``: the graph's pins-sized
    arrays enter as per-shard stripes over "model" (`dist.graph`)."""
    ctx = segops.ShardCtx(axis=model_axis, nshards=nshards,
                          graph_striped=striped and model_axis is not None)

    def body(d, parts, n_parts, key, enforce):
        ids = jnp.arange(caps.n, dtype=jnp.int32)
        if race and data_axis is not None:
            r = jax.lax.axis_index(data_axis)
            perm = jax.random.permutation(
                jax.random.fold_in(key, r), caps.n).astype(jnp.int32)
            # replica 0 races the identity (single-device) ordering
            tie_rank = jnp.where(r == 0, ids, perm)
        else:
            tie_rank = ids
        parts_new, gain, nmv, kt, pt = refine_step_impl(
            d, parts, n_parts, caps, kcap, params, enforce, ctx, tie_rank)
        if data_axis is None:   # shard-only mesh: nothing to race
            return parts_new, gain, nmv, kt, pt
        # race resolution: scalar gains all-gathered, winner's partition
        # vector broadcast by psum of the masked vector (no parts gather)
        gains = jax.lax.all_gather(gain, data_axis)        # [n_replicas]
        best = jnp.argmax(gains).astype(jnp.int32)         # tie -> replica 0
        win = jax.lax.axis_index(data_axis) == best
        parts_out = jax.lax.psum(jnp.where(win, parts_new, 0), data_axis)
        nmv_out = jax.lax.psum(jnp.where(win, nmv, 0), data_axis)
        kt_out = jax.lax.psum(jnp.where(win, kt, 0), data_axis)
        pt_out = jax.lax.psum(jnp.where(win, pt, 0), data_axis)
        return parts_out, gains[best], nmv_out, kt_out, pt_out

    fn = common.shard_map(body, mesh=mesh,
                          in_specs=(graph_pspecs(striped), P(), P(), P(), P()),
                          out_specs=(P(), P(), P(), P(), P()))
    return jax.jit(fn)


def refine_level(d, parts, n_parts, caps: Caps, kcap: int,
                 params: RefineParams, plan: Plan, *, race: bool = True,
                 seed: int = 0, log: list | None = None):
    """Drop-in for `core.refine.refine_level` on a mesh: Theta rounds, each
    an R-way replica race (R = data-axis size) over pipelines sharded
    M-way (M = model-axis size). `race=False` pins every replica to the
    identity tie-break — deterministic parity mode. ``d`` may be a
    replicated `DeviceHypergraph` or a memory-sharded
    `dist.graph.ShardedHypergraph` (racing replicas then share the one
    striped copy of the pins arrays).

    With ``use_kernels=True`` the gains/pins dispatches of
    ``core.refine`` stay live on the mesh: the `gains` kernel runs
    stripe-locally per shard (see `repro.kernels`), bit-identical to the
    single-device kernel path. Returns ``(parts, kernel_hits)`` — the
    device-scalar count of repetitions whose gains dispatch took the
    Pallas branch (0..theta; mesh-independent by the branch-parity
    invariant). The same holds for the stripe-local pins-count dispatch;
    ``refine_level`` returns ``(parts, (kernel_hits, pins_hits))``."""
    d, striped = _graph_arg(d)
    data_axis, model_axis, nshards = plan_axes(plan)
    step = _build_step(plan.mesh, data_axis, model_axis, nshards,
                       caps, kcap, params, bool(race), striped)
    n_parts = jnp.asarray(n_parts, jnp.int32)
    key = jax.random.PRNGKey(seed)
    hits = jnp.int32(0)
    phits = jnp.int32(0)
    for rep in range(params.theta):
        enforce = jnp.asarray(rep >= params.theta // 2)
        parts, g, nmv, kt, pt = step(d, parts, n_parts,
                                     jax.random.fold_in(key, rep), enforce)
        hits = hits + kt
        phits = phits + pt
        if log is not None:
            log.append(dict(rep=rep, gain=float(g), applied=int(nmv),
                            raced=bool(race), kernel=int(kt)))
    return parts, (hits, phits)


@functools.lru_cache(maxsize=None)
def _build_coarsen_step(mesh, model_axis: str | None, nshards: int,
                        caps: Caps, cparams: CoarsenParams,
                        compensated: bool = False, striped: bool = False):
    """One sharded coarsening level (proposal + matching), jitted; cached
    per static signature like `_build_step`. ``compensated`` opts the eta /
    matching-sum0 float reductions into `ShardCtx.psum_compensated`
    (O(dense) traffic, ~1 ulp, not bit-identical — see segops)."""
    ctx = segops.ShardCtx(axis=model_axis, nshards=nshards,
                          compensated=compensated,
                          graph_striped=striped and model_axis is not None)

    def body(d):
        match, n_pairs, props = coarsen_step_impl(d, caps, cparams, ctx)
        return (match, n_pairs, props.n_pairs_live, props.n_nbr_entries,
                props.kernel_path_taken)

    fn = common.shard_map(body, mesh=mesh, in_specs=(graph_pspecs(striped),),
                          out_specs=(P(), P(), P(), P(), P()))
    return jax.jit(fn)


@functools.lru_cache(maxsize=None)
def _build_contract(mesh, model_axis: str | None, nshards: int, caps: Caps,
                    striped: bool = False):
    ctx = segops.ShardCtx(axis=model_axis, nshards=nshards,
                          graph_striped=striped and model_axis is not None)

    def body(d, match):
        return contract_impl(d, match, caps, ctx)

    fn = common.shard_map(body, mesh=mesh,
                          in_specs=(graph_pspecs(striped), P()),
                          out_specs=(graph_pspecs(striped), P()))
    return jax.jit(fn)


def coarsen_level(d, caps: Caps, cparams: CoarsenParams, plan: Plan,
                  compensated: bool = False):
    """Drop-in for `core.coarsen.coarsen_step` on a mesh (without the
    proposals debug output): one coarsening level with the pairs/slot
    pipelines sharded over the plan's model axis. Deterministic — never
    raced — and bit-exact with the single-device step (see the module
    docstring for the psum / pmax / gather combine discipline).
    Returns (match[Ncap], n_matched_pairs).

    ``compensated=True`` trades that bit-exactness for traffic: the eta and
    matching-sum0 float reductions combine per-shard dense partials with a
    Neumaier-compensated psum (within ~1 ulp of the true sum) instead of
    gathering their lane columns in stripe order.

    With `use_kernels=True` the `pair_scores` dispatch of `coarsen.propose`
    stays live on the mesh: the kernel runs stripe-locally per shard and
    its per-row output is bit-identical to the single-device kernel path
    (see `repro.kernels` for the dispatch contract), so sharded-vs-single
    parity holds kernels-on against kernels-on. (Kernel eta sums in a
    different fp order than the segment pipeline, so kernels-on vs
    kernels-off remains an fp-tolerance comparison — same as on one
    device.)

    Returns ``(match, n_matched_pairs, (n_pairs_live, n_nbr_entries,
    kernel_path_taken))`` — the first two diagnostics feed the drivers'
    host-side capacity-overflow audit
    (`core.hypergraph.check_expansion_caps`); the trailing flag is 1 iff
    the pair_scores dispatch took the Pallas branch at this level."""
    d, striped = _graph_arg(d)
    _, model_axis, nshards = plan_axes(plan)
    step = _build_coarsen_step(plan.mesh, model_axis, nshards, caps, cparams,
                               bool(compensated), striped)
    match, n_pairs, pairs_live, nbr_entries, kernel_hit = step(d)
    return match, n_pairs, (pairs_live, nbr_entries, kernel_hit)


def contract_level(d, match, caps: Caps, plan: Plan):
    """Drop-in for `core.contract.contract` on a mesh: integer-only
    pipeline, bit-exact sharded contraction. Returns (d_coarse, gamma).
    With a memory-sharded input graph the coarse graph comes back
    memory-sharded too (its pins arrays are emitted as "model" stripes),
    so the level loop stays striped end-to-end."""
    d, striped = _graph_arg(d)
    _, model_axis, nshards = plan_axes(plan)
    fn = _build_contract(plan.mesh, model_axis, nshards, caps, striped)
    d2, gamma = fn(d, match)
    if striped:
        d2 = ShardedHypergraph(g=d2, nshards=nshards)
    return d2, gamma


def partition(hg, omega: int, delta: int, plan: Plan, *, race: bool = True,
              seed: int = 0, **kw):
    """Multi-level constrained partitioning with the whole V-cycle on the
    mesh: `core.partitioner.partition` with every coarsening level sharded
    (`coarsen_level`/`contract_level`) and every refinement level raced and
    sharded over `plan`."""
    from repro.core.partitioner import partition as _partition
    return _partition(hg, omega, delta, plan=plan, race=race,
                      race_seed=seed, **kw)
