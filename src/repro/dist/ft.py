"""Fault-tolerant supervision: straggler watchdog + restartable train driver.

On a 1000-host fleet the two dominant failure modes are (a) a host that
*stalls* (network partition, hung collective — no exception, just silence)
and (b) a host that *dies* (preemption, hardware fault — an exception
surfaces at the next dispatch). `StepWatchdog` covers (a): a timer thread
armed around each step fires a callback with the stuck step number so the
driver can alert or abort the collective. `TrainSupervisor` covers (b):
it re-enters the step loop from the last checkpoint, replaying the
deterministic data pipeline forward — every step's effect lands exactly
once relative to the restored state.
"""
from __future__ import annotations

import collections
import contextlib
import threading
import time
from typing import Any, Callable


class StepWatchdog:
    """Fires `on_stall(step)` once if an armed step exceeds `deadline_s`.

    arm(step)  — start (or restart) the countdown for `step`;
    disarm()   — step finished in time, cancel the countdown;
    stop()     — shut the thread down (idempotent);
    reset()    — disarm and forget all fired history.

    One callback per arm: after firing, the watchdog disarms itself until
    the next `arm` call. The callback runs on the watchdog thread — keep it
    cheap (append to a list, set an event, signal an abort).

    `fired_steps` records the steps the watchdog fired for — a bounded
    deque (`max_fired`, default 1024) so a supervisor that stalls for
    months cannot grow it without bound — and `watch(step)` is the
    arm/disarm pair as a context manager: drivers wrap each blocking device
    solve in `with wd.watch(step):` and check `wd.fired_steps` afterwards
    to requeue stalled work (this is how `serve.partition_service` turns a
    stall into a supervised restart).

    When a `repro.obs.metrics.Registry` is passed, each fire increments the
    ``watchdog.stalls`` counter and each *late disarm* (the armed work
    finally completed after the deadline fired) observes the measured stall
    duration into the ``watchdog.stall.s`` histogram.
    """

    def __init__(self, deadline_s: float, on_stall: Callable[[int], Any],
                 registry=None, max_fired: int = 1024):
        self.deadline_s = float(deadline_s)
        self.on_stall = on_stall
        self.registry = registry
        if registry is not None:
            # pre-register so dumps carry the series before the first stall
            registry.counter("watchdog.stalls", 0)
        self.fired_steps: collections.deque[int] = collections.deque(
            maxlen=max_fired)
        self._cv = threading.Condition()
        self._step: int | None = None
        self._deadline: float | None = None
        self._arm_time: float | None = None
        self._fired_armed = False   # current armed step already fired
        self._stopped = False
        self._thread = threading.Thread(target=self._watch, daemon=True,
                                        name="step-watchdog")
        self._thread.start()

    def arm(self, step: int) -> None:
        with self._cv:
            self._step = step
            self._deadline = time.monotonic() + self.deadline_s
            self._arm_time = time.monotonic()
            self._fired_armed = False
            self._cv.notify_all()

    def disarm(self) -> None:
        with self._cv:
            late = self._fired_armed
            arm_time = self._arm_time
            self._step = None
            self._deadline = None
            self._arm_time = None
            self._fired_armed = False
            self._cv.notify_all()
        if late and self.registry is not None and arm_time is not None:
            self.registry.observe("watchdog.stall.s",
                                  time.monotonic() - arm_time)

    def reset(self) -> None:
        """Disarm and clear the fired-step history (keeps the thread)."""
        with self._cv:
            self._step = None
            self._deadline = None
            self._arm_time = None
            self._fired_armed = False
            self.fired_steps.clear()
            self._cv.notify_all()

    @contextlib.contextmanager
    def watch(self, step: int):
        """Arm around a blocking unit of work; disarms on exit (even when
        the work raises). After the block, `step in self.fired_steps` tells
        whether the deadline elapsed while the work was still running."""
        self.arm(step)
        try:
            yield self
        finally:
            self.disarm()

    def stop(self) -> None:
        with self._cv:
            self._stopped = True
            self._cv.notify_all()
        if threading.current_thread() is not self._thread:
            self._thread.join(timeout=5.0)

    def _watch(self) -> None:
        while True:
            fire_step = None
            with self._cv:
                if self._stopped:
                    return
                if self._step is None:
                    self._cv.wait()
                    continue
                wait = self._deadline - time.monotonic()
                if wait > 0:
                    self._cv.wait(wait)
                    continue
                fire_step = self._step
                self._step = None
                self._deadline = None
                self._fired_armed = True   # _arm_time kept for late disarm
                self.fired_steps.append(fire_step)
            # outside the lock: the callback may call arm/disarm/stop
            if self.registry is not None:
                self.registry.counter("watchdog.stalls")
            self.on_stall(fire_step)


class TrainSupervisor:
    """Restarts a step loop from the last checkpoint on failure.

    init_fn   — () -> initial state (used when no checkpoint exists yet);
    save      — (step, state) -> None, called every `ckpt_every` steps;
    restore   — () -> (step, state) of the newest checkpoint;
    max_restarts — give up (re-raise) after this many restarts.

    `run(step_fn, n_steps, ckpt_every)` drives `state = step_fn(state, step)`
    for step in [0, n_steps). On an exception it restores and resumes from
    the checkpointed step; steps after the checkpoint are re-executed
    against the restored state, so each step's effect is applied exactly
    once relative to it. Exposes `restarts` for telemetry.
    """

    def __init__(self, init_fn: Callable[[], Any],
                 save: Callable[[int, Any], None],
                 restore: Callable[[], tuple[int, Any]],
                 max_restarts: int = 3):
        self.init_fn = init_fn
        self.save = save
        self.restore = restore
        self.max_restarts = max_restarts
        self.restarts = 0
        self.failures: list[tuple[int, str]] = []

    def run(self, step_fn: Callable[[Any, int], Any], n_steps: int,
            ckpt_every: int = 0) -> tuple[int, Any]:
        step = 0
        state = self.init_fn()
        while step < n_steps:
            try:
                state = step_fn(state, step)
            except Exception as e:  # noqa: BLE001 — any step failure restarts
                self.failures.append((step, repr(e)))
                if self.restarts >= self.max_restarts:
                    raise
                self.restarts += 1
                try:
                    step, state = self.restore()
                except Exception:
                    # no checkpoint yet: restart the run from scratch
                    step, state = 0, self.init_fn()
                continue
            step += 1
            if ckpt_every and step % ckpt_every == 0:
                self.save(step, state)
        return step, state
