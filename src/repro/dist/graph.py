"""Memory-sharded hypergraph storage over the Plan mesh.

The paper's GPU design materializes the incidence structure and the
deduplicated neighborhoods in device memory to exploit set sparsity
(Sec. V-B). Our mesh port sharded the *compute* (PR 2-4) but still
replicated every O(pins) array on every device, so the largest
partitionable hypergraph shrank as the mesh grew — the opposite of what
distribution should buy. `ShardedHypergraph` fixes the storage side: the
three pins-sized arrays (`edge_pins`, `node_edges`, `node_is_in`) live as
**contiguous per-shard lane stripes over the mesh's "model" axis**
(`NamedSharding` + `jax.device_put`), padded to the stripe total
``ceil(caps.p / nshards) * nshards`` with the usual sentinels. Node/edge
sized arrays (offsets, weights, sizes, scalars) stay replicated — they are
O(N)/O(E), not the memory bottleneck — and so does everything along the
"data" axis: racing replicas *share the one sharded graph* instead of each
holding a private copy.

What stays striped vs what transiently doesn't (the memory contract):

* storage         — the three pins arrays of *every retained level* (the
                    V-cycle keeps each level's graph alive for
                    uncoarsening, so storage, not per-level temporaries,
                    dominates peak memory) hold O(pins / nshards) per
                    device.
* pipelines       — every pins/pairs-sized pipeline stage reads its own
                    lane stripe directly (`ShardCtx.gread`); the pairs
                    sized intermediates (the largest temporaries) are lane
                    stripes by construction; contraction emits the coarse
                    pins arrays as stripes (reduce-scatter packing +
                    stripe-kept incidence sort), so levels stay striped
                    end-to-end without ever materializing replicated.
* documented transients — `build_pairs` joins two *arbitrary* pin slots
                    per pair lane, the one access no lane striping can
                    serve: it rebuilds the pins column via
                    `ShardCtx.gfull` (bit-preserving psum of disjoint
                    stripes), live only inside the expansion. The dense
                    neighborhood arrays of one coarsening level
                    (`build_neighbors` output, O(nbrs)) likewise combine
                    replicated — they feed arbitrary-segment binary
                    searches — and are freed with the level step.

Exactness: striping is pure layout. `gread` returns exactly the values the
replicated array holds at this shard's lane positions, `gfull` rebuilds
bit-identical columns, and the contraction stripe outputs are the same
integers the replicated path scatters — so the `race=False` V-cycle parity
contract of `dist.partition` (bit-exact vs the single-device partitioner)
holds unchanged with sharded storage, and is regression-tested under 8
forced host devices on (2, 4) and (1, 8) meshes.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.hypergraph import (Caps, DeviceHypergraph, GraphDelta,
                                   HostHypergraph, apply_delta,
                                   check_fits_caps, host_from_device,
                                   packed_host_arrays)
from repro.dist.sharding import Plan
from repro.models import common

# the pins-sized storage arrays that stripe over "model"; everything else
# in DeviceHypergraph is O(N)/O(E) or scalar and stays replicated
PINS_FIELDS = ("edge_pins", "node_edges", "node_is_in")


@dataclasses.dataclass
class ShardedHypergraph:
    """A `DeviceHypergraph` whose pins-sized arrays are stripe-sharded over
    the mesh's "model" axis (and replicated over every other axis). The
    wrapper is the explicit marker the `dist.partition` drivers dispatch
    on — no shape-sniffing — and `nshards` is static pytree metadata so it
    can ride through jit untouched."""

    g: DeviceHypergraph
    nshards: int

    # ---- driver-facing passthroughs (host level loop reads these) --------
    @property
    def n_nodes(self):
        return self.g.n_nodes

    @property
    def n_edges(self):
        return self.g.n_edges

    @property
    def n_pins(self):
        return self.g.n_pins

    @property
    def edge_off(self):
        return self.g.edge_off

    @property
    def node_size(self):
        return self.g.node_size

    def pins_bytes_per_device(self) -> int:
        """Live bytes of the pins-sized storage arrays held by one device —
        the quantity that scales ~1/nshards (charted by
        benchmarks/dist_scaling.py as `graph_B`)."""
        total = 0
        for f in PINS_FIELDS:
            arr = getattr(self.g, f)
            shards = arr.addressable_shards
            total += shards[0].data.nbytes if shards else arr.nbytes
        return total


jax.tree_util.register_dataclass(ShardedHypergraph, data_fields=["g"],
                                 meta_fields=["nshards"])


def stripe_total(caps: Caps, nshards: int) -> int:
    """Padded pins-array length whose contiguous stripes tile the model
    axis: lanes are ceil-divided exactly like ``ShardCtx.lanes(caps.p)``,
    so shard i's storage stripe is shard i's compute stripe."""
    per = -(-caps.p // max(nshards, 1))
    return per * max(nshards, 1)


def model_shards(plan: Plan) -> int:
    names = tuple(plan.mesh.axis_names)
    if "model" not in names:
        raise ValueError(
            "sharded graph storage stripes over the 'model' mesh axis, but "
            f"the plan's mesh has axes {names}")
    return plan.mesh.shape["model"]


def graph_pspecs(striped: bool) -> DeviceHypergraph:
    """Per-field PartitionSpecs for a DeviceHypergraph as a shard_map
    in/out_specs pytree: pins-sized arrays stripe over "model" when
    ``striped``, everything else replicates."""
    sp = P("model") if striped else P()
    return DeviceHypergraph(
        edge_off=P(), edge_pins=sp, edge_nsrc=P(), edge_w=P(),
        node_off=P(), node_edges=sp, node_is_in=sp, node_nin=P(),
        node_size=P(), n_nodes=P(), n_edges=P(), n_pins=P())


def sharded_from_host(hg: HostHypergraph, caps: Caps,
                      plan: Plan) -> ShardedHypergraph:
    """Sharded sibling of `core.hypergraph.device_from_host`: same packed
    numpy staging arrays, but the pins-sized ones are padded to the stripe
    total and `device_put` with a "model"-striped NamedSharding (one
    host->device transfer per stripe, no replicated intermediate); all
    other arrays are placed replicated on the same mesh."""
    nshards = model_shards(plan)
    arrays = packed_host_arrays(hg, caps, pcap=stripe_total(caps, nshards))
    repl = NamedSharding(plan.mesh, P())
    striped = NamedSharding(plan.mesh, P("model"))
    placed = {
        k: jax.device_put(v, striped if k in PINS_FIELDS else repl)
        for k, v in arrays.items()
    }
    return ShardedHypergraph(g=DeviceHypergraph(**placed), nshards=nshards)


def host_from_sharded(d: ShardedHypergraph) -> HostHypergraph:
    """Host readback; fully-addressable sharded arrays assemble directly
    and `host_from_device` slices the live prefixes (stripe padding beyond
    ``caps.p`` carries sentinels past ``n_pins``, so it never surfaces)."""
    return host_from_device(d.g)


# --------------------------------------------------------------------------
# Incremental updates (streaming repartitioning)
# --------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _stripe_scatter(mesh, per: int):
    """shard_map'd sparse update of one "model"-striped pins array: each
    shard rebases the global update positions onto its own stripe and drops
    the rest (``mode="drop"``) — no cross-shard traffic at all, since every
    global lane lives on exactly one shard. Cached per (mesh, stripe size);
    jit re-specializes per update-batch shape/dtype."""

    def body(stripe, pos, val):
        i = jax.lax.axis_index("model").astype(jnp.int32)
        lo = i * per
        lp = jnp.where((pos >= lo) & (pos < lo + per), pos - lo, per)
        return stripe.at[lp].set(val, mode="drop")

    fn = common.shard_map(body, mesh=mesh,
                          in_specs=(P("model"), P(), P()),
                          out_specs=P("model"))
    return jax.jit(fn)


def apply_delta_sharded(sh: ShardedHypergraph, hg: HostHypergraph,
                        delta: GraphDelta, caps: Caps,
                        plan: Plan) -> ShardedHypergraph:
    """Apply one ``GraphDelta`` batch to the host mirror ``hg`` (in place)
    *and* to the sharded device storage ``sh``, in place of a full
    re-upload.

    The replicated O(N)/O(E) arrays (offsets, weights, sizes, scalars)
    refresh wholesale — they are cheap and a delta shifts offsets globally
    anyway. The three O(pins) striped arrays update by **stripe-local
    scatters** of only the changed lanes: the host computes the packed-array
    diff, pads the (position, value) batch to a power of two, and each
    shard writes the updates that land in its own stripe (``mode="drop"``
    discards the rest). A striped array with no changed lanes is kept
    untouched (same device buffer); a batch touching more than half the
    lanes falls back to a fresh striped ``device_put``.

    Raises ``CapacityError`` when the post-delta graph no longer fits
    ``caps`` (the PR 5 resize trigger) **before touching device state**;
    the host mirror is still updated either way, so the caller rebuilds
    device storage from it at fresh caps."""
    nshards = model_shards(plan)
    ptot = stripe_total(caps, nshards)
    per = ptot // nshards
    old = packed_host_arrays(hg, caps, pcap=ptot)
    apply_delta(hg, delta)
    check_fits_caps(hg, caps)
    new = packed_host_arrays(hg, caps, pcap=ptot)

    repl = NamedSharding(plan.mesh, P())
    striped = NamedSharding(plan.mesh, P("model"))
    updates = {k: jax.device_put(v, repl) for k, v in new.items()
               if k not in PINS_FIELDS}
    for f in PINS_FIELDS:
        changed = np.nonzero(old[f] != new[f])[0]
        if changed.size == 0:
            continue
        if changed.size > ptot // 2:
            updates[f] = jax.device_put(new[f], striped)
            continue
        ucap = max(8, 1 << int(changed.size - 1).bit_length())
        pos = np.full((ucap,), ptot, np.int32)
        pos[: changed.size] = changed
        val = np.zeros((ucap,), new[f].dtype)
        val[: changed.size] = new[f][changed]
        fn = _stripe_scatter(plan.mesh, per)
        updates[f] = fn(getattr(sh.g, f), jnp.asarray(pos), jnp.asarray(val))
    return ShardedHypergraph(g=dataclasses.replace(sh.g, **updates),
                             nshards=nshards)
