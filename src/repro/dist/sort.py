"""Distributed stable multi-key sample sort over a ``segops.ShardCtx``.

This is the missing CUB-device-radix-sort analogue for the mesh: every sort
in the V-cycle (refinement events, mover/holder chain orderings, coarsening
neighborhood builds, both contraction key sorts) used to all-gather its
compact key columns to every shard and run the stable ``lax.sort``
replicated — O(pins) communication per sort. This module replaces that with
a PSRS-style sample sort whose only *gathered* key data is the splitter
sample (O(nshards^2 * oversample) keys); the payload moves through
static-shape ``all_to_all`` exchanges sized O(len/nshards) per shard.

Pipeline (inside ``shard_map``, each shard holding stripe ``i`` of the
global concatenation order):

  1. **Rank-extend + local sort.** A global-rank column (``stripe_start +
     arange``) is appended as the least-significant key. Float32 key
     columns are mapped through ``segops.f32_sort_key`` — the uint32 image
     of ``lax.sort``'s canonicalized float total order (-0.0 == +0.0, all
     NaNs one class after +inf) — so integer comparisons agree with the
     gathered float sort everywhere; the original float bits ride along as
     payloads. Extended keys are globally unique, so *any* correct sort of
     them equals the stable sort of the original keys: bit-identity with
     the gathered ``lax.sort(..., is_stable=True)`` is by construction, not
     by luck.
  2. **Splitters from a gathered sample** (regular sampling): each shard
     contributes ``oversample`` evenly spaced locally-sorted keys; the
     ``nshards * oversample`` sample tuples are all-gathered, sorted
     replicated, and every ``oversample``-th tuple becomes a splitter.
  3. **Bucketing** by vectorized lexicographic splitter comparison (the
     multi-key ``searchsorted``): bucket(x) = #splitters <= x.
  4. **Static-shape all_to_all exchange.** Per-destination counts are
     all-gathered (``[s, s]`` ints) into send/recv offsets. Own-bucket
     elements stay local; off-diagonal elements pack into ``[s, C]``
     blocks (C = ``exchange_capacity``) and ride one all_to_all.
  5. **Local merge** (sort of kept + received by extended key), then a
     second offset-computed all_to_all **rebalances** bucket boundaries to
     exact stripe boundaries, so shard ``i`` ends holding precisely global
     sorted positions ``[i*per, (i+1)*per)`` — the same stripe the old
     gather-sort-stripe pattern produced.

Skew safety: per-pair block counts are data-dependent and unbounded in the
worst case (regular sampling only bounds *totals*), so both exchanges'
off-diagonal counts — all derivable replicated from the ``[s, s]`` count
matrix *before* any data moves — are checked against the static capacity,
and on overflow the whole sort takes a uniform ``lax.cond`` branch that
gathers and sorts replicated (the legacy pattern, still bit-identical).
Nearly-sorted inputs (the common case here: event keys correlate with lane
order) are diagonal-heavy, which costs nothing — the diagonal never rides
the exchange.

Entry point for pipeline code is ``segops.ShardCtx.sort_by``; this module
is the implementation plus its diagnostics hook.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils import segops

# NB: no module-level jnp constants here — this module is lazily imported
# inside jitted traces (ShardCtx.sort_by), where a module-level jnp value
# would be born a tracer and leak to later eager callers.


def exchange_capacity(per: int, nshards: int, pad: int = 16) -> int:
    """Static per-(source, destination) off-diagonal block capacity: twice
    the balanced share plus slack, clamped to the stripe length (at which
    point overflow is impossible and the fallback branch is dead)."""
    return int(min(per, 2 * (-(-per // nshards)) + pad))


def _to_comparable(col: jax.Array) -> jax.Array:
    """Key column -> dtype whose ``<``/``==`` reproduce ``lax.sort``'s key
    order (floats via the canonicalizing ``f32_sort_key``)."""
    if jnp.issubdtype(col.dtype, jnp.floating):
        return segops.f32_sort_key(col)
    if col.dtype == jnp.bool_:
        return col.astype(jnp.int32)
    return col


def _pack_i32(col: jax.Array) -> jax.Array:
    if col.dtype == jnp.int32:
        return col
    if col.dtype in (jnp.uint32, jnp.float32):
        return jax.lax.bitcast_convert_type(col, jnp.int32)
    if col.dtype == jnp.bool_:
        return col.astype(jnp.int32)
    raise TypeError(f"unsupported sort column dtype {col.dtype}")


def _unpack_i32(col: jax.Array, dtype) -> jax.Array:
    if dtype == jnp.int32:
        return col
    if dtype in (jnp.uint32, jnp.float32):
        return jax.lax.bitcast_convert_type(col, dtype)
    if dtype == jnp.bool_:
        return col != 0
    raise TypeError(f"unsupported sort column dtype {dtype}")


def _lex_le(splitter_cols, elem_cols):
    """[per, s-1] bool: splitter tuple <= element tuple, lexicographic over
    the column list (most-significant first)."""
    lt = None
    eq = None
    for tc, xc in zip(splitter_cols, elem_cols):
        t = tc[None, :]
        x = xc[:, None]
        c_lt = t < x
        c_eq = t == x
        if lt is None:
            lt, eq = c_lt, c_eq
        else:
            lt = lt | (eq & c_lt)
            eq = eq & c_eq
    return lt | eq


def sample_sort_stripes(ctx, keys, payloads, *, oversample: int | None = None,
                        with_stats: bool = False,
                        _tie_rank: bool = True):
    """Sort stripes of the global concatenation order; returns
    ``(key_stripes, payload_stripes)`` of the globally stable-sorted order
    (shard ``i`` holds sorted positions ``[i*per, (i+1)*per)``),
    bit-identical to the gathered stable ``lax.sort``.

    ``with_stats`` additionally returns a replicated ``fell_back`` scalar
    (True when skew overflowed the static exchange capacity and the
    gathered branch ran). ``_tie_rank=False`` drops the global-rank tie key
    — only for the mutation-demo tests: equal keys then merge in
    buffer order instead of stripe order and stability is lost.
    """
    axis, s = ctx.axis, ctx.nshards
    assert axis is not None and keys, (axis, len(keys))
    per = keys[0].shape[0]
    n = per * s
    m = len(keys)
    idx = ctx.index()
    grank = idx * per + jnp.arange(per, dtype=jnp.int32)

    cmp_cols = [_to_comparable(k) for k in keys]
    n_tie = 1 if _tie_rank else 0
    # float/bool key columns lose bits in the comparable image -> originals
    # ride as carried payloads; int columns come back from the keys.
    carried_ix = [i for i, k in enumerate(keys)
                  if cmp_cols[i].dtype != k.dtype]
    carried = [keys[i] for i in carried_ix]
    data_cols = carried + list(payloads)

    # ---- 1. local sort by (cmp..., grank) --------------------------------
    ops = cmp_cols + [grank] + data_cols
    ops = jax.lax.sort(ops, num_keys=m + n_tie, is_stable=True)
    cmp_s = list(ops[:m])
    grank_s = ops[m]
    data_s = list(ops[m + 1:])
    sort_keys = cmp_s + ([grank_s] if _tie_rank else [])

    # ---- 2. splitters from a gathered regular sample ---------------------
    # oversampling 4x tightens bucket balance enough that the stripe
    # rebalance stays within capacity on uniform data (measured: q = s
    # overflows at mid sizes); sample traffic stays O(s^2 * q) scalars
    q = oversample or max(1, min(per, 4 * s))
    qpos = (jnp.arange(q, dtype=jnp.int32) * per) // q
    sample = jnp.stack([_pack_i32(c[qpos]) for c in sort_keys], axis=-1)
    sample = jax.lax.all_gather(sample, axis).reshape(s * q, -1)  # [s*q, mk]
    samp_cols = [_unpack_i32(sample[:, j], k.dtype)
                 for j, k in enumerate(sort_keys)]
    samp_cols = jax.lax.sort(samp_cols, num_keys=len(samp_cols),
                             is_stable=True)
    spos = (jnp.arange(s - 1, dtype=jnp.int32) + 1) * q
    splitters = [c[spos] for c in samp_cols]                       # [s-1]

    # ---- 3. bucket by lexicographic splitter comparison ------------------
    if s > 1:
        bucket = jnp.sum(_lex_le(splitters, sort_keys), axis=1,
                         dtype=jnp.int32)                          # [per]
    else:
        bucket = jnp.zeros((per,), jnp.int32)
    # local data is sorted, so buckets are non-decreasing runs
    pos_in_bucket = (jnp.arange(per, dtype=jnp.int32)
                     - jnp.searchsorted(bucket, bucket,
                                        side="left").astype(jnp.int32))

    # ---- 4. counts -> offsets; capacity check (all replicated) -----------
    counts = jax.ops.segment_sum(jnp.ones((per,), jnp.int32), bucket,
                                 num_segments=s)                   # [s]
    cnt_mat = jax.lax.all_gather(counts, axis)                     # [s, s]
    btot = jnp.sum(cnt_mat, axis=0)                                # [s]
    boff = jnp.cumsum(btot) - btot          # bucket global start   [s]
    cap = exchange_capacity(per, s)
    eye = jnp.eye(s, dtype=bool)
    stripe_lo = jnp.arange(s, dtype=jnp.int32) * per
    # rebalance per-pair counts: overlap of bucket i's global interval with
    # stripe j — known before any data moves
    lo2 = jnp.maximum(boff[:, None], stripe_lo[None, :])
    hi2 = jnp.minimum((boff + btot)[:, None], (stripe_lo + per)[None, :])
    c2_mat = jnp.maximum(hi2 - lo2, 0).astype(jnp.int32)           # [s, s]
    fell_back = (jnp.any(jnp.where(eye, 0, cnt_mat) > cap)
                 | jnp.any(jnp.where(eye, 0, c2_mat) > cap))

    packed = jnp.stack([_pack_i32(c) for c in cmp_s + [grank_s] + data_s],
                       axis=-1)                                    # [per, nc]
    ncols = packed.shape[1]
    # sentinel tuple that sorts after every real extended key: cmp columns
    # at their dtype maximum (uint32 max bitcasts to int32 -1), grank at
    # int32 max — real granks are < n, so even all-max real keys sort first
    sent_row = jnp.asarray(
        [-1 if c.dtype == jnp.uint32 else int(jnp.iinfo(jnp.int32).max)
         for c in cmp_s] + [int(jnp.iinfo(jnp.int32).max)] * (ncols - m),
        jnp.int32)

    def _merge_sort(rows):
        """Sort packed rows by (cmp..., grank) with original dtypes."""
        cols = [_unpack_i32(rows[:, j], c.dtype)
                for j, c in enumerate(cmp_s)]
        cols += [rows[:, j] for j in range(m, ncols)]
        out = jax.lax.sort(cols, num_keys=m + n_tie, is_stable=True)
        return jnp.stack([_pack_i32(c) if j < m else c
                          for j, c in enumerate(out)], axis=-1)

    def _exchange(packed):
        me = idx
        keep = bucket == me
        send_ok = ~keep & (pos_in_bucket < cap)
        dest = jnp.where(send_ok, bucket * cap + pos_in_bucket, s * cap)
        send = jnp.broadcast_to(sent_row, (s * cap + 1, ncols))
        send = send.at[dest].set(packed, mode="drop")[:-1]
        recv = jax.lax.all_to_all(send.reshape(s, cap, ncols), axis,
                                  split_axis=0, concat_axis=0, tiled=True)
        rv = ((jnp.arange(cap, dtype=jnp.int32)[None, :]
               < cnt_mat[:, me][:, None])
              & ~eye[:, me][:, None])                              # [s, cap]
        kept = jnp.where(keep[:, None], packed, sent_row[None, :])
        recv = jnp.where(rv.reshape(-1)[:, None],
                         recv.reshape(s * cap, ncols), sent_row[None, :])
        merged = _merge_sort(jnp.concatenate([kept, recv], axis=0))

        # ---- 5. rebalance bucket boundaries to exact stripes -------------
        bt_me = btot[me]
        r = jnp.arange(per + s * cap, dtype=jnp.int32)
        ok = r < bt_me
        g = boff[me] + r                       # global sorted position
        dj = jnp.clip(g // per, 0, s - 1)
        keep2 = ok & (dj == me)
        pos2 = g - jnp.maximum(dj * per, boff[me])  # rank in (me, dj) block
        send2_ok = ok & (dj != me) & (pos2 < cap)
        dest2 = jnp.where(send2_ok, dj * cap + pos2, s * cap)
        send2 = jnp.broadcast_to(sent_row, (s * cap + 1, ncols))
        send2 = send2.at[dest2].set(merged, mode="drop")[:-1]
        recv2 = jax.lax.all_to_all(send2.reshape(s, cap, ncols), axis,
                                   split_axis=0, concat_axis=0, tiled=True)
        # scatter into the output stripe: kept rows land at g - me*per,
        # received block i lands contiguously at its bucket/stripe overlap
        out = jnp.zeros((per + 1, ncols), jnp.int32)
        kpos = jnp.where(keep2, g - me * per, per)
        out = out.at[kpos].set(merged, mode="drop")
        rpos2 = (jnp.maximum(boff, me * per) - me * per)[:, None] \
            + jnp.arange(cap, dtype=jnp.int32)[None, :]            # [s, cap]
        rv2 = ((jnp.arange(cap, dtype=jnp.int32)[None, :]
                < c2_mat[:, me][:, None]) & ~eye[:, me][:, None])
        rpos2 = jnp.where(rv2, rpos2, per).reshape(-1)
        out = out.at[rpos2].set(recv2.reshape(s * cap, ncols), mode="drop")
        return out[:per]

    def _gathered(packed):
        full = jax.lax.all_gather(packed, axis).reshape(n, ncols)
        full = _merge_sort(full)
        return jax.lax.dynamic_slice_in_dim(full, idx * per, per, axis=0)

    if s == 1:
        out = packed
        fell_back = jnp.asarray(False)
    else:
        out = jax.lax.cond(fell_back, _gathered, _exchange, packed)

    # ---- unpack back into (keys, payloads) -------------------------------
    out_keys = []
    ci = iter(range(m + 1, m + 1 + len(carried)))
    for i, k in enumerate(keys):
        if i in carried_ix:
            out_keys.append(_unpack_i32(out[:, next(ci)], k.dtype))
        else:
            out_keys.append(_unpack_i32(out[:, i], k.dtype))
    base = m + 1 + len(carried)
    out_pay = [_unpack_i32(out[:, base + j], p.dtype)
               for j, p in enumerate(payloads)]
    if with_stats:
        return out_keys, out_pay, fell_back
    return out_keys, out_pay
