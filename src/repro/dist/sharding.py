"""Parallelism plans: logical-axis -> mesh-axis rules + sharding helpers.

A `Plan` is the single object the rest of the system consults for layout
decisions. It owns the mesh and a `rules` dict mapping *logical* axes
(declared on parameter `Spec`s and activation constraints) to mesh axes:

  batch     -> the data-parallel axes ("data", or ("pod", "data") multi-pod)
  embed     -> "data" under FSDP (params ZeRO-sharded over DP), else None
  heads/kv_heads/mlp/experts/vocab -> "model" (megatron TP / EP / vocab-par)
  kv_seq    -> "model" when the KV cache is sequence-sharded (flash-decode)
  kv_pages  -> "model" for the paged serving KV pool (pages striped over TP)
  attn_seq  -> "model" for sequence-parallel attention (hillclimb Q1)

Boolean feature flags (attn_p_bf16, mla_flash, moe_local_dispatch) ride in
the same dict — model code reads them with `plan.rules.get(...)`; they never
appear as Spec axes so the resolver ignores them.

Resolution itself (divisibility fallback, one-dim-per-mesh-axis) lives in
`models.common._resolve_pspec`; this module only decides the mapping.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.models import common


@dataclasses.dataclass(frozen=True)
class Plan:
    mesh: Mesh
    rules: dict[str, Any]

    # ------------------------------------------------------------- factory
    @classmethod
    def make(cls, mesh: Mesh, *, fsdp: bool = True, seq_shard_kv: bool = True,
             moe_local: bool = False, seq_parallel_attn: bool = False,
             attn_p_bf16: bool = False, mla_flash: bool = False) -> "Plan":
        """Standard 2D (+pod) plan: DP over every non-"model" axis, megatron
        TP over "model", FSDP (params over DP) when `fsdp`."""
        names = tuple(mesh.axis_names)
        dp_axes = tuple(a for a in names if a != "model")
        dp = dp_axes[0] if len(dp_axes) == 1 else dp_axes
        tp = "model" if "model" in names else None
        # FSDP stays intra-pod: the "pod" axis is DCN, too slow for the
        # per-step param all-gathers.
        fsdp_axis = ("data" if "data" in names else dp) if fsdp else None
        rules: dict[str, Any] = {
            "batch": dp,
            "embed": fsdp_axis,
            "heads": tp,
            "kv_heads": tp,
            "mlp": tp,
            "experts": tp,
            "vocab": tp,
            "layers": None,               # scan axis is never sharded
            "kv_seq": tp if seq_shard_kv else None,
            # paged serving KV ([n_pages, page_size, ...]): stripe the
            # physical-page pool over TP; gathers/scatters stay jit-global
            "kv_pages": tp if seq_shard_kv else None,
            "attn_seq": tp if seq_parallel_attn else None,
            "attn_p_bf16": attn_p_bf16 or None,
            "mla_flash": mla_flash or None,
            "moe_local_dispatch": moe_local or None,
        }
        return cls(mesh=mesh, rules=rules)

    # ------------------------------------------------------------ resolvers
    def pspec(self, *axes: str | None) -> PartitionSpec:
        """Resolve logical axis names to a PartitionSpec (no shape knowledge,
        so no divisibility fallback — use `constraint` for activations)."""
        entries = []
        used: set[str] = set()
        for name in axes:
            mapped = self.rules.get(name) if name else None
            if mapped is None:
                entries.append(None)
                continue
            mesh_axes = ((mapped,) if isinstance(mapped, str)
                         else tuple(mapped))
            if any(ax in used for ax in mesh_axes):
                entries.append(None)
                continue
            used.update(mesh_axes)
            entries.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
        while entries and entries[-1] is None:
            entries.pop()
        return PartitionSpec(*entries)

    def sharding(self, *axes: str | None) -> NamedSharding:
        """NamedSharding for logical axes; `plan.sharding()` = replicated."""
        return NamedSharding(self.mesh, self.pspec(*axes))

    def param_shardings(self, spec_tree):
        """NamedShardings for a tree of `Spec`s (divisibility-aware)."""
        return common.shardings(spec_tree, self.rules, self.mesh)

    def param_pspecs(self, spec_tree):
        return common.pspecs(spec_tree, self.rules, self.mesh)

    def constraint(self, x, *axes: str | None):
        """with_sharding_constraint by logical axes, with the same
        divisibility fallback as parameter resolution (a dim that does not
        divide its mesh axes stays replicated instead of erroring)."""
        spec = common.Spec(tuple(x.shape), tuple(axes))
        ps = common._resolve_pspec(spec, self.rules, self.mesh)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, ps))

    # ------------------------------------------------------------- helpers
    def dp_size(self) -> int:
        dp = self.rules["batch"]
        axes = (dp,) if isinstance(dp, str) else tuple(dp)
        n = 1
        for a in axes:
            n *= self.mesh.shape[a]
        return n

    def n_devices(self) -> int:
        n = 1
        for a in self.mesh.axis_names:
            n *= self.mesh.shape[a]
        return n
