from repro.dist.ft import StepWatchdog, TrainSupervisor  # noqa: F401
from repro.dist.sharding import Plan  # noqa: F401
