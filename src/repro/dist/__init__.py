from repro.dist.ft import StepWatchdog, TrainSupervisor  # noqa: F401
from repro.dist.sharding import Plan  # noqa: F401
# bound as the submodule (not its `partition` function) so that
# `repro.dist.partition.refine_level` / `.partition` both resolve
from repro.dist import partition  # noqa: F401
from repro.dist import sort  # noqa: F401  (distributed sample sort)
from repro.dist import graph  # noqa: F401  (memory-sharded graph storage)
