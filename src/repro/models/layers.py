"""Transformer building blocks: GQA attention (flash-chunked), MLA
(DeepSeek-V2 compressed KV), SwiGLU MLP, MoE (sort-based capacity dispatch),
embeddings. Pure functions: `*_shapes(cfg)` declares parameter Specs,
`*_apply(params, ...)` computes. No framework dependencies.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import (Spec, apply_rope, rms_norm, shard_map,
                                 swiglu)

F32 = jnp.float32


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------
def attn_shapes(cfg: ArchConfig, cross: bool = False) -> dict:
    H, KV, DH, D = cfg.n_heads, cfg.n_kv, cfg.d_head, cfg.d_model
    s = {
        "wq": Spec((D, H * DH), ("embed", "heads")),
        "wk": Spec((D, KV * DH), ("embed", "kv_heads")),
        "wv": Spec((D, KV * DH), ("embed", "kv_heads")),
        "wo": Spec((H * DH, D), ("heads", "embed")),
    }
    if cfg.qkv_bias:
        s["bq"] = Spec((H * DH,), ("heads",), init="zeros")
        s["bk"] = Spec((KV * DH,), ("kv_heads",), init="zeros")
        s["bv"] = Spec((KV * DH,), ("kv_heads",), init="zeros")
    return s


def qkv_project(p: dict, x, xkv, cfg: ArchConfig):
    H, KV, DH = cfg.n_heads, cfg.n_kv, cfg.d_head
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", xkv, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", xkv, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    B, S = x.shape[:2]
    Skv = xkv.shape[1]
    return (q.reshape(B, S, H, DH), k.reshape(B, Skv, KV, DH),
            v.reshape(B, Skv, KV, DH))


def flash_attention(q, k, v, *, causal: bool = True, q_offset=0,
                    q_chunk: int = 1024, k_chunk: int = 1024, plan=None,
                    seq_parallel: bool = False, p_bf16: bool = False,
                    scale: float | None = None):
    """Flash-style chunked attention with online softmax, pure JAX.

    q [B,Sq,H,Dh], k/v [B,Sk,KV,Dh], GQA groups g = H//KV. Query chunks are
    *vectorized* (a leading nq dim) while key chunks stream through one
    sequential scan — the score working set stays q_chunk x k_chunk and,
    unlike a double scan, the nq dim can be sharded over the "model" axis
    (sequence-parallel attention, hillclimb Q1) for archs whose head count
    doesn't divide the TP axis. `p_bf16` (hillclimb M1) casts softmax
    probabilities to bf16 for the PV matmul, halving the dominant
    score-side HBM traffic at negligible accuracy cost (accumulation stays
    f32).
    """
    B, Sq, H, Dh = q.shape
    _, Sk, KV, Dhk = k.shape
    Dv = v.shape[-1]
    g = H // KV
    qc = math.gcd(min(q_chunk, Sq), Sq)
    kc = math.gcd(min(k_chunk, Sk), Sk)
    nq, nk = Sq // qc, Sk // kc
    scale = scale if scale is not None else 1.0 / math.sqrt(Dh)

    qr = q.reshape(B, nq, qc, KV, g, Dh)
    if plan is not None and seq_parallel:
        qr = plan.constraint(qr, "batch", "attn_seq", None, None, None, None)
    kr = jnp.moveaxis(k.reshape(B, nk, kc, KV, Dh), 1, 0)   # [nk,B,kc,KV,Dh]
    vr = jnp.moveaxis(v.reshape(B, nk, kc, KV, Dh), 1, 0)

    iq = jnp.arange(qc)
    ik = jnp.arange(kc)
    qpos = q_offset + (jnp.arange(nq) * qc)[:, None] + iq[None, :]  # [nq,qc]

    def k_body(carry, ki_kv):
        m, l, acc = carry                       # [B,nq,KV,g,qc](,Dh)
        ki, kblk, vblk = ki_kv
        s = jnp.einsum("bnqkgd,bckd->bnkgqc", qr, kblk,
                       preferred_element_type=F32) * scale
        if causal:
            kpos = ki * kc + ik
            mask = qpos[:, :, None] >= kpos[None, None, :]   # [nq,qc,kc]
            s = jnp.where(mask[None, :, None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        pv = p.astype(q.dtype) if p_bf16 else p
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bnkgqc,bckd->bnkgqd", pv, vblk, preferred_element_type=F32)
        return (m_new, l, acc), None

    m0 = jnp.full((B, nq, KV, g, qc), -jnp.inf, F32)
    l0 = jnp.zeros((B, nq, KV, g, qc), F32)
    a0 = jnp.zeros((B, nq, KV, g, qc, Dv), F32)
    (m, l, acc), _ = jax.lax.scan(k_body, (m0, l0, a0),
                                  (jnp.arange(nk), kr, vr))
    out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
    # [B,nq,KV,g,qc,Dv] -> [B,nq,qc,KV,g,Dv] -> [B,Sq,H,Dv]
    out = jnp.transpose(out, (0, 1, 4, 2, 3, 5))
    return out.reshape(B, Sq, H, Dv)


def decode_attention(q, kcache, vcache, length=None):
    """Single-step attention over a dense cache. q [B,1,H,Dh],
    cache [B,S,KV,Dh]."""
    B, _, H, Dh = q.shape
    _, S, KV, _ = kcache.shape
    g = H // KV
    qr = q.reshape(B, KV, g, Dh)
    s = jnp.einsum("bkgd,bckd->bkgc", qr, kcache,
                   preferred_element_type=F32) / math.sqrt(Dh)
    if length is not None:
        mask = jnp.arange(S)[None] < length[:, None]
        s = jnp.where(mask[:, None, None], s, -jnp.inf)
    a = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgc,bckd->bkgd", a, vcache,
                   preferred_element_type=F32)
    return o.reshape(B, 1, H, Dh).astype(q.dtype)


def decode_attention_seqsharded(plan, q, kcache, vcache, length=None):
    """Flash-decoding over a sequence-sharded KV cache: each "model" rank
    holds S/m of the cache, computes a partial softmax, and the partials
    combine with psum — the TPU analogue of flash-decoding, required for
    the 32k/500k decode cells where a replicated cache cannot fit HBM."""
    from jax.sharding import PartitionSpec as P
    mesh = plan.mesh
    if "model" not in mesh.axis_names or plan.rules.get("kv_seq") is None:
        return decode_attention(q, kcache, vcache, length)
    dp = plan.rules["batch"]

    def local(qb, kb, vb):
        B, _, H, Dh = qb.shape
        _, Sl, KV, _ = kb.shape
        g = H // KV
        qr = qb.reshape(B, KV, g, Dh)
        s = jnp.einsum("bkgd,bckd->bkgc", qr, kb,
                       preferred_element_type=F32) / math.sqrt(Dh)
        m_loc = jnp.max(s, axis=-1)
        m = jax.lax.pmax(m_loc, "model")
        p = jnp.exp(s - m[..., None])
        l = jax.lax.psum(jnp.sum(p, axis=-1), "model")
        o = jnp.einsum("bkgc,bckd->bkgd", p.astype(qb.dtype), vb,
                       preferred_element_type=F32)
        o = jax.lax.psum(o, "model") / jnp.maximum(l, 1e-30)[..., None]
        return o.reshape(B, 1, H, Dh).astype(qb.dtype)

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(dp), P(dp, "model"), P(dp, "model")),
        out_specs=P(dp),
        check=False)(q, kcache, vcache)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------
def mla_shapes(cfg: ArchConfig) -> dict:
    m = cfg.mla
    H, D = cfg.n_heads, cfg.d_model
    return {
        "w_dq": Spec((D, m.q_lora), ("embed", None)),
        "q_norm": Spec((m.q_lora,), (None,), init="ones"),
        "w_uq": Spec((m.q_lora, H * (m.d_nope + m.d_rope)), (None, "heads")),
        "w_dkv": Spec((D, m.kv_lora), ("embed", None)),
        "kv_norm": Spec((m.kv_lora,), (None,), init="ones"),
        "w_kr": Spec((D, m.d_rope), ("embed", None)),
        "w_uk": Spec((m.kv_lora, H * m.d_nope), (None, "heads")),
        "w_uv": Spec((m.kv_lora, H * m.d_v), (None, "heads")),
        "wo": Spec((H * m.d_v, D), ("heads", "embed")),
    }


def mla_project_q(p, x, cfg: ArchConfig, positions):
    m = cfg.mla
    H = cfg.n_heads
    B, S, _ = x.shape
    cq = rms_norm(jnp.einsum("bsd,dq->bsq", x, p["w_dq"]), p["q_norm"])
    q = jnp.einsum("bsq,qh->bsh", cq, p["w_uq"]).reshape(
        B, S, H, m.d_nope + m.d_rope)
    q_nope, q_rope = q[..., : m.d_nope], q[..., m.d_nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_compress_kv(p, x, cfg: ArchConfig, positions):
    """Returns the compressed cache entries: c_kv [B,S,kv_lora],
    k_rope [B,S,d_rope] (shared across heads)."""
    c = rms_norm(jnp.einsum("bsd,dq->bsq", x, p["w_dkv"]), p["kv_norm"])
    kr = jnp.einsum("bsd,dr->bsr", x, p["w_kr"])[:, :, None, :]
    kr = apply_rope(kr, positions, cfg.rope_theta)[:, :, 0, :]
    return c, kr


def mla_attention_flash(p, q_nope, q_rope, c_kv, k_rope, cfg: ArchConfig,
                        causal: bool, q_offset=0, plan=None):
    """Hillclimb D2 (EXPERIMENTS.md §Perf): chunked MLA via the flash path.

    Absorbed form in latent space: q' = [W_uk^T q_nope || q_rope] per head,
    k' = [c_kv || k_rope] with ONE shared KV head, values = c_kv; the
    latent combine up-projects after attention. The S x S probability
    matrix never materializes — the baseline `mla_attention` holds
    [B,H,Sq,Sk] f32, the dominant memory term of deepseek train_4k."""
    m = cfg.mla
    H = cfg.n_heads
    B, Sq = q_nope.shape[:2]
    w_uk = p["w_uk"].reshape(m.kv_lora, H, m.d_nope)
    q_eff = jnp.einsum("bshn,qhn->bshq", q_nope, w_uk)
    qq = jnp.concatenate([q_eff, q_rope], axis=-1)      # [B,Sq,H,lora+rope]
    kk = jnp.concatenate([c_kv, k_rope], axis=-1)[:, :, None, :]
    vv = c_kv[:, :, None, :]
    lat = flash_attention(qq, kk, vv, causal=causal, q_offset=q_offset,
                          q_chunk=cfg.q_chunk, k_chunk=cfg.k_chunk,
                          plan=plan,
                          scale=1.0 / math.sqrt(m.d_nope + m.d_rope))
    w_uv = p["w_uv"].reshape(m.kv_lora, H, m.d_v)
    return jnp.einsum("bshq,qhv->bshv", lat, w_uv)


def mla_attention(p, q_nope, q_rope, c_kv, k_rope, cfg: ArchConfig,
                  causal: bool, q_offset=0):
    """Absorbed-matrix MLA attention: scores use q_nope.(W_uk c) folded as
    (W_uk^T q_nope).c so only the compressed cache is traversed; values
    combine in latent space then up-project (DeepSeek-V2 Sec. 2.1)."""
    m = cfg.mla
    H = cfg.n_heads
    B, Sq = q_nope.shape[:2]
    Sk = c_kv.shape[1]
    w_uk = p["w_uk"].reshape(m.kv_lora, H, m.d_nope)
    q_eff = jnp.einsum("bshn,qhn->bshq", q_nope, w_uk)       # [B,Sq,H,kv_lora]
    s = (jnp.einsum("bshq,btq->bhst", q_eff, c_kv, preferred_element_type=F32)
         + jnp.einsum("bshr,btr->bhst", q_rope, k_rope,
                      preferred_element_type=F32))
    s = s / math.sqrt(m.d_nope + m.d_rope)
    if causal:
        qpos = q_offset + jnp.arange(Sq)
        kpos = jnp.arange(Sk)
        s = jnp.where((qpos[:, None] >= kpos[None, :])[None, None], s, -jnp.inf)
    a = jax.nn.softmax(s, axis=-1).astype(q_nope.dtype)
    lat = jnp.einsum("bhst,btq->bshq", a, c_kv)              # latent combine
    w_uv = p["w_uv"].reshape(m.kv_lora, H, m.d_v)
    return jnp.einsum("bshq,qhv->bshv", lat, w_uv)           # [B,Sq,H,d_v]


def mla_output(p, o, cfg: ArchConfig):
    B, S, H, Dv = o.shape
    return jnp.einsum("bsh,hd->bsd", o.reshape(B, S, H * Dv), p["wo"])


# ---------------------------------------------------------------------------
# MLP + MoE
# ---------------------------------------------------------------------------
def mlp_shapes(cfg: ArchConfig, d_ff: int | None = None) -> dict:
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    return {
        "w_gate": Spec((D, F), ("embed", "mlp")),
        "w_up": Spec((D, F), ("embed", "mlp")),
        "w_down": Spec((F, D), ("mlp", "embed")),
    }


def mlp_apply(p, x, plan=None):
    g = jnp.einsum("...d,df->...f", x, p["w_gate"])
    u = jnp.einsum("...d,df->...f", x, p["w_up"])
    h = jax.nn.silu(g) * u
    if plan is not None and h.ndim == 3:  # megatron TP: hidden over "model"
        h = plan.constraint(h, "batch", None, "mlp")
    return jnp.einsum("...f,fd->...d", h, p["w_down"])


def moe_shapes(cfg: ArchConfig) -> dict:
    mo = cfg.moe
    D = cfg.d_model
    s = {
        "router": Spec((D, mo.n_experts), ("embed", None)),
        "we_gate": Spec((mo.n_experts, D, mo.d_ff_expert),
                        ("experts", "embed", "mlp")),
        "we_up": Spec((mo.n_experts, D, mo.d_ff_expert),
                      ("experts", "embed", "mlp")),
        "we_down": Spec((mo.n_experts, mo.d_ff_expert, D),
                        ("experts", "mlp", "embed")),
    }
    if mo.n_shared:
        s["shared"] = mlp_shapes(cfg, d_ff=mo.n_shared * mo.d_ff_expert)
    return s


def moe_apply_local_dispatch(p, x, cfg: ArchConfig,
                             expert_perm: jax.Array | None, plan):
    """Hillclimb D1 (EXPERIMENTS.md §Perf): shard-local MoE dispatch.

    The global sort+scatter dispatch hands XLA a scatter whose indices span
    the whole token axis, so the SPMD partitioner all-gathers the [E,cap,D]
    buffers across the mesh (collective-bound deepseek baseline). Here the
    top-k/sort/scatter runs *inside* shard_map over the DP axes — indices
    are rank-local, zero collectives — producing xe with the capacity dim
    sharded over DP. One constrained einsum then re-shards to (experts->EP,
    cap->DP) for the expert GEMMs; the combine gather is again rank-local.
    """
    from jax.sharding import PartitionSpec as P
    mo = cfg.moe
    B, S, D = x.shape
    E, K = mo.n_experts, mo.top_k
    dp = plan.rules["batch"]
    ndp = 1
    for a in ((dp,) if isinstance(dp, str) else dp):
        ndp *= plan.mesh.shape[a]
    G = B * S
    cap_local = max(8, int(math.ceil(G * K / E * mo.capacity_factor / ndp)))

    router = p["router"]

    def local(xb, router_w):
        b, s, d = xb.shape
        g = b * s
        xf = xb.reshape(g, d)
        logits = jnp.einsum("gd,de->ge", xf, router_w,
                            preferred_element_type=F32)
        gates = jax.nn.softmax(logits, axis=-1)
        topv, topi = jax.lax.top_k(gates, K)
        topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
        if expert_perm is not None:
            topi = expert_perm[topi]
        density = jnp.mean(jax.nn.one_hot(topi[:, 0], E, dtype=F32), axis=0)
        aux = (jnp.sum(density * jnp.mean(gates, axis=0)) * E
               * mo.router_aux_coef)
        aux = jax.lax.pmean(aux, tuple(plan.mesh.axis_names))
        flat_e = topi.reshape(g * K)
        flat_w = topv.reshape(g * K).astype(xb.dtype)
        tok = jnp.repeat(jnp.arange(g, dtype=jnp.int32), K)
        se, payload = jax.lax.sort(
            [flat_e, jnp.arange(g * K, dtype=jnp.int32)], num_keys=1,
            is_stable=True)
        stok = tok[payload]
        seg_start = jnp.concatenate([jnp.ones((1,), bool),
                                     se[1:] != se[:-1]])
        idx = jnp.arange(g * K, dtype=jnp.int32)
        start = jax.lax.associative_scan(
            jnp.maximum, jnp.where(seg_start, idx, 0))
        pos = idx - start
        keep = pos < cap_local
        xe = jnp.zeros((E, cap_local, d), xb.dtype)
        xe = xe.at[jnp.where(keep, se, E),
                   jnp.where(keep, pos, 0)].add(xf[stok], mode="drop")
        meta = dict(se=se, pos=pos, keep=keep, stok=stok,
                    w=flat_w[payload])
        return xe, aux, meta

    def combine(ye, meta, b, s, d):
        g = b * s
        contrib = ye[jnp.where(meta["keep"], meta["se"], 0),
                     jnp.where(meta["keep"], meta["pos"], 0)]
        contrib = jnp.where(meta["keep"][:, None], contrib, 0.0)
        out = jnp.zeros((g, d), ye.dtype).at[meta["stok"]].add(
            contrib * meta["w"][:, None])
        return out.reshape(b, s, d)

    assert B % ndp == 0, "local dispatch requires DP-divisible batch"
    b_loc = B // ndp
    xe, aux, meta = shard_map(
        local, mesh=plan.mesh, in_specs=(P(dp), P()),
        out_specs=(P(None, dp), P(), P(dp)), check=False)(x, router)
    # re-shard once for the expert GEMMs: experts -> EP, capacity -> DP
    xe = plan.constraint(xe, "experts", "batch", None)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["we_gate"])) * \
        jnp.einsum("ecd,edf->ecf", xe, p["we_up"])
    h = plan.constraint(h, "experts", "batch", "mlp")
    ye = jnp.einsum("ecf,efd->ecd", h, p["we_down"])
    ye = plan.constraint(ye, "experts", "batch", None)

    out = shard_map(
        lambda yb, mb: combine(yb, mb, b_loc, S, D),
        mesh=plan.mesh, in_specs=(P(None, dp), P(dp)),
        out_specs=P(dp), check=False)(ye.astype(x.dtype), meta)
    if mo.n_shared:
        out = out + mlp_apply(p["shared"], x, plan)
    return out, aux


def moe_apply(p, x, cfg: ArchConfig, expert_perm: jax.Array | None = None,
              plan=None):
    """Sort-based capacity dispatch (GShard-style, no [G,E,C] one-hot):
    tokens sort by chosen expert, scatter into per-expert capacity slots,
    batched expert GEMMs, gather+combine. `expert_perm` (from the hypergraph
    placement planner) permutes the expert axis so co-activated experts land
    on the same EP shard. Returns (out, aux_loss)."""
    mo = cfg.moe
    B, S, D = x.shape
    if plan is not None and plan.rules.get("moe_local_dispatch"):
        dp = plan.rules["batch"]
        ndp = 1
        for a in ((dp,) if isinstance(dp, str) else dp):
            ndp *= plan.mesh.shape[a]
        if B % ndp == 0 and B > 1:
            return moe_apply_local_dispatch(p, x, cfg, expert_perm, plan)
    E, K = mo.n_experts, mo.top_k
    G = B * S
    xf = x.reshape(G, D)

    logits = jnp.einsum("gd,de->ge", xf, p["router"],
                        preferred_element_type=F32)
    gates = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(gates, K)                      # [G,K]
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    if expert_perm is not None:
        topi = expert_perm[topi]

    # aux load-balance loss (Switch-style)
    density = jnp.mean(jax.nn.one_hot(topi[:, 0], E, dtype=F32), axis=0)
    density_proxy = jnp.mean(gates, axis=0)
    aux = jnp.sum(density * density_proxy) * E * mo.router_aux_coef

    cap = max(8, int(math.ceil(G * K / E * mo.capacity_factor)))
    flat_e = topi.reshape(G * K)
    flat_w = topv.reshape(G * K).astype(x.dtype)
    tok = jnp.repeat(jnp.arange(G, dtype=jnp.int32), K)

    se, payload = jax.lax.sort([flat_e, jnp.arange(G * K, dtype=jnp.int32)],
                               num_keys=1, is_stable=True)
    stok = tok[payload]
    # position within expert group
    seg_start = jnp.concatenate([jnp.ones((1,), bool), se[1:] != se[:-1]])
    idx = jnp.arange(G * K, dtype=jnp.int32)
    start_pos = jnp.where(seg_start, idx, 0)
    start_of_seg = jax.lax.associative_scan(jnp.maximum, start_pos)
    pos = idx - start_of_seg
    keep = pos < cap

    xe = jnp.zeros((E, cap, D), x.dtype)
    xe = xe.at[jnp.where(keep, se, E), jnp.where(keep, pos, 0)].add(
        xf[stok], mode="drop")
    if plan is not None:  # EP over experts, capacity over the DP axis
        xe = plan.constraint(xe, "experts", "batch", None)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["we_gate"])) * \
        jnp.einsum("ecd,edf->ecf", xe, p["we_up"])
    if plan is not None:
        h = plan.constraint(h, "experts", "batch", "mlp")
    ye = jnp.einsum("ecf,efd->ecd", h, p["we_down"])          # [E,cap,D]
    if plan is not None:
        ye = plan.constraint(ye, "experts", "batch", None)

    contrib = ye[jnp.where(keep, se, 0), jnp.where(keep, pos, 0)]
    contrib = jnp.where(keep[:, None], contrib, 0.0)
    out = jnp.zeros((G, D), x.dtype).at[stok].add(
        contrib * flat_w[payload][:, None])
    out = out.reshape(B, S, D)
    if mo.n_shared:
        out = out + mlp_apply(p["shared"], x, plan)
    return out, aux


# ---------------------------------------------------------------------------
# Embeddings / head
# ---------------------------------------------------------------------------
def embed_shapes(cfg: ArchConfig) -> dict:
    s = {"tok": Spec((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                     init="embed", scale=1.0)}
    if cfg.pos == "learned":
        s["pos"] = Spec((cfg.max_seq, cfg.d_model), (None, "embed"),
                        init="embed", scale=0.02)
    if not cfg.tie_embeddings:
        s["unembed"] = Spec((cfg.d_model, cfg.vocab), ("embed", "vocab"))
    if cfg.vision_dim:
        s["vis_proj"] = Spec((cfg.vision_dim, cfg.d_model), (None, "embed"))
    return s


def embed_apply(p, tokens, cfg: ArchConfig, positions=None):
    x = p["tok"][tokens]
    if cfg.pos == "learned":
        assert positions is not None
        x = x + p["pos"][positions]
    return x


def unembed_apply(p, x, cfg: ArchConfig):
    w = p["tok"].T if cfg.tie_embeddings else p["unembed"]
    return jnp.einsum("bsd,dv->bsv", x, w)
