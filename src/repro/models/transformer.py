"""Model assembly: decoder-only LM (+ encoder-decoder and VLM variants)
built from the declared pattern of (mixer, ffn) layer specs.

Homogeneous superblocks scan over a stacked parameter axis ("layers"
logical axis) with remat — one compiled layer body regardless of depth, the
key to tractable dry-run compiles at 60-layer scale. Heterogeneous patterns
(jamba 7-mamba:1-attn, xlstm mlstm/slstm interleave) stack per *pattern
slot*, so each slot's params are homogeneous across superblocks.

Three entry modes share the block code:
  train   — full causal, no caches, chunked CE loss
  prefill — causal, returns per-layer caches
  decode  — one token against caches (seq-sharded KV via flash-decoding)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerSpec
from repro.models import layers as L
from repro.models import ssm
from repro.models.common import (Spec, apply_rope, rms_norm, layer_norm,
                                 shard_map, stack_specs,
                                 softmax_cross_entropy)

F32 = jnp.float32


# ---------------------------------------------------------------------------
# shapes
# ---------------------------------------------------------------------------
def _mixer_shapes(spec: LayerSpec, cfg: ArchConfig) -> dict:
    return {"attn": L.attn_shapes, "mla": L.mla_shapes,
            "mamba": ssm.mamba_shapes, "mlstm": ssm.mlstm_shapes,
            "slstm": ssm.slstm_shapes}[spec.mixer](cfg)


def _ffn_shapes(spec: LayerSpec, cfg: ArchConfig) -> dict | None:
    if spec.ffn == "mlp":
        return L.mlp_shapes(cfg)
    if spec.ffn == "moe":
        return L.moe_shapes(cfg)
    return None


def _norm_shapes(cfg: ArchConfig) -> dict:
    if cfg.norm == "rms":
        return {"g": Spec((cfg.d_model,), (None,), init="ones")}
    return {"g": Spec((cfg.d_model,), (None,), init="ones"),
            "b": Spec((cfg.d_model,), (None,), init="zeros")}


def _layer_shapes(spec: LayerSpec, cfg: ArchConfig) -> dict:
    s = {"norm1": _norm_shapes(cfg), "mixer": _mixer_shapes(spec, cfg)}
    ffn = _ffn_shapes(spec, cfg)
    if ffn is not None:
        s["norm2"] = _norm_shapes(cfg)
        s["ffn"] = ffn
    return s


def _enc_layer_shapes(cfg: ArchConfig) -> dict:
    return {"norm1": _norm_shapes(cfg), "mixer": L.attn_shapes(cfg),
            "norm2": _norm_shapes(cfg), "ffn": L.mlp_shapes(cfg)}


def _cross_shapes(cfg: ArchConfig) -> dict:
    return {"normx": _norm_shapes(cfg), "cross": L.attn_shapes(cfg)}


def lm_shapes(cfg: ArchConfig) -> dict:
    s: dict[str, Any] = {"embed": L.embed_shapes(cfg),
                         "final_norm": _norm_shapes(cfg)}
    s["stack"] = {
        f"slot{i}": stack_specs(_layer_shapes(spec, cfg), cfg.n_superblocks)
        for i, spec in enumerate(cfg.pattern)
    }
    if cfg.encoder_layers:
        s["stack_cross"] = {
            f"slot{i}": stack_specs(_cross_shapes(cfg), cfg.n_superblocks)
            for i, _ in enumerate(cfg.pattern)
        }
        s["encoder"] = {
            "stack": stack_specs(_enc_layer_shapes(cfg), cfg.encoder_layers),
            "final_norm": _norm_shapes(cfg),
            "pos": Spec((cfg.max_source_positions, cfg.d_model),
                        (None, "embed"), init="embed", scale=0.02),
        }
    for k in range(cfg.first_k_dense):
        s[f"dense{k}"] = _layer_shapes(
            dataclasses.replace(cfg.pattern[0], ffn="mlp"), cfg)
    return s


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------
def _mixer_cache_spec(spec: LayerSpec, cfg: ArchConfig, batch: int,
                      cache_len: int, page_size: int = 0,
                      n_pages: int = 0) -> dict:
    B, S = batch, cache_len
    if spec.mixer == "attn":
        if page_size:  # paged: [n_pages, page_size, ...] + per-slot tables
            return {"k": Spec((n_pages, page_size, cfg.n_kv, cfg.d_head),
                              ("kv_pages", None, "kv_heads", None),
                              init="zeros"),
                    "v": Spec((n_pages, page_size, cfg.n_kv, cfg.d_head),
                              ("kv_pages", None, "kv_heads", None),
                              init="zeros")}
        return {"k": Spec((B, S, cfg.n_kv, cfg.d_head),
                          ("batch", "kv_seq", "kv_heads", None), init="zeros"),
                "v": Spec((B, S, cfg.n_kv, cfg.d_head),
                          ("batch", "kv_seq", "kv_heads", None), init="zeros")}
    if spec.mixer == "mla":
        m = cfg.mla
        if page_size:
            return {"c_kv": Spec((n_pages, page_size, m.kv_lora),
                                 ("kv_pages", None, None), init="zeros"),
                    "k_rope": Spec((n_pages, page_size, m.d_rope),
                                   ("kv_pages", None, None), init="zeros")}
        return {"c_kv": Spec((B, S, m.kv_lora), ("batch", "kv_seq", None),
                             init="zeros"),
                "k_rope": Spec((B, S, m.d_rope), ("batch", "kv_seq", None),
                               init="zeros")}
    if spec.mixer == "mamba":
        mb = cfg.mamba
        di = mb.expand * cfg.d_model
        return {"tail": Spec((B, mb.d_conv - 1, di), ("batch", None, "mlp"),
                             init="zeros"),
                "h": Spec((B, di, mb.d_state), ("batch", "mlp", None),
                          init="zeros")}
    if spec.mixer == "mlstm":
        H, DH = cfg.n_heads, cfg.d_model // cfg.n_heads
        return {"C": Spec((B, H, DH, DH), ("batch", "heads", None, None),
                          init="zeros"),
                "n": Spec((B, H, DH), ("batch", "heads", None), init="zeros")}
    if spec.mixer == "slstm":
        D = cfg.d_model
        z = lambda: Spec((B, D), ("batch", None), init="zeros")
        return {"c": z(), "n": z(), "h": z(), "m": z()}
    raise ValueError(spec.mixer)


def cache_shapes(cfg: ArchConfig, batch: int, cache_len: int, *,
                 page_size: int = 0, n_pages: int = 0) -> dict:
    """Cache spec tree. With `page_size`/`n_pages` the per-token mixer caches
    (attn KV, MLA compressed KV) switch to the paged [n_pages, page_size, ...]
    layout ("kv_pages" leading axis); per-slot constant-size state (SSM/conv
    tails, recurrent states, enc_out) keeps its [batch, ...] slot layout."""
    c: dict[str, Any] = {"stack": {
        f"slot{i}": stack_specs(_mixer_cache_spec(spec, cfg, batch, cache_len,
                                                  page_size, n_pages),
                                cfg.n_superblocks)
        for i, spec in enumerate(cfg.pattern)}}
    for k in range(cfg.first_k_dense):
        c[f"dense{k}"] = _mixer_cache_spec(cfg.pattern[0], cfg, batch,
                                           cache_len, page_size, n_pages)
    if cfg.encoder_layers:
        enc_len = min(cfg.max_source_positions, cache_len)
        c["enc_out"] = Spec((batch, enc_len, cfg.d_model),
                            ("batch", None, "embed"), init="zeros")
    return c


# ---------------------------------------------------------------------------
# mixers
# ---------------------------------------------------------------------------
def _attn_out(p, o):
    B, S, H, DH = o.shape
    return jnp.einsum("bsh,hd->bsd", o.reshape(B, S, H * DH), p["wo"])


def _apply_attn(p, x, cfg, plan, mode, positions, cache, pos, pages=None):
    q, k, v = L.qkv_project(p, x, x, cfg)
    if cfg.pos == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if plan is not None:  # megatron TP: heads over "model"
        q = plan.constraint(q, "batch", None, "heads", None)
        k = plan.constraint(k, "batch", None, "kv_heads", None)
        v = plan.constraint(v, "batch", None, "kv_heads", None)
    sp, pbf16 = False, False
    if plan is not None:
        msz = plan.mesh.shape.get("model", 1)
        sp = (plan.rules.get("attn_seq") is not None
              and cfg.n_heads % msz != 0
              and (x.shape[1] // math.gcd(cfg.q_chunk, x.shape[1])) % msz == 0)
        pbf16 = bool(plan.rules.get("attn_p_bf16"))
    if mode == "train":
        o = L.flash_attention(q, k, v, causal=True, q_chunk=cfg.q_chunk,
                              k_chunk=cfg.k_chunk, plan=plan,
                              seq_parallel=sp, p_bf16=pbf16)
        return _attn_out(p, o), None
    if mode == "prefill":
        o = L.flash_attention(q, k, v, causal=True, q_chunk=cfg.q_chunk,
                              k_chunk=cfg.k_chunk, plan=plan,
                              seq_parallel=sp, p_bf16=pbf16)
        S, Sc = x.shape[1], cache["k"].shape[1]
        pad = [(0, 0), (0, Sc - S), (0, 0), (0, 0)]
        new = {"k": jnp.pad(k, pad).astype(cache["k"].dtype),
               "v": jnp.pad(v, pad).astype(cache["v"].dtype)}
        return _attn_out(p, o), new
    # decode: update + flash-decode over (paged / possibly seq-sharded) cache
    if pages is not None:
        table, psize = pages
        posv = _pos_vec(pos, q.shape[0])
        kc = _paged_update(cache["k"], k.astype(cache["k"].dtype), posv,
                           table, psize)
        vc = _paged_update(cache["v"], v.astype(cache["v"].dtype), posv,
                           table, psize)
        o = L.decode_attention(q, _paged_gather(kc, table),
                               _paged_gather(vc, table), length=posv + 1)
        return _attn_out(p, o), {"k": kc, "v": vc}
    o, kc, vc = _decode_attn_update(plan, q, k.astype(cache["k"].dtype),
                                    v.astype(cache["v"].dtype),
                                    cache["k"], cache["v"], pos)
    return _attn_out(p, o), {"k": kc, "v": vc}


def _dp_or_none(plan, batch: int):
    """DP axes for shard_map in_specs, None when batch doesn't divide
    (long_500k global_batch=1)."""
    dp = plan.rules["batch"]
    axes = (dp,) if isinstance(dp, str) else tuple(dp)
    n = 1
    for a in axes:
        n *= plan.mesh.shape[a]
    return dp if batch % n == 0 else None


def _pos_vec(pos, batch: int):
    """Normalize a decode position (scalar or per-slot [B] vector) to [B]."""
    return jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (batch,))


def _paged_update(cache, new, posv, table, psize: int):
    """Per-row write into a paged cache. cache [NP, psize, ...], new
    [B, 1, ...], posv [B], table [B, P]. Unallocated table entries are an
    out-of-range sentinel (>= NP), so their writes drop — inactive slots
    never touch physical pages."""
    B = new.shape[0]
    page = table[jnp.arange(B), posv // psize]
    return cache.at[page, posv % psize].set(new[:, 0], mode="drop")


def _paged_gather(cache, table):
    """Materialize each slot's logical [P*psize, ...] view of its pages.
    Sentinel entries clamp to an arbitrary physical page; callers mask by
    per-slot length so the clamped rows never contribute."""
    B, P = table.shape
    g = cache[jnp.clip(table, 0, cache.shape[0] - 1)]
    return g.reshape(B, P * cache.shape[1], *cache.shape[2:])


def _decode_attn_update(plan, q, k_new, v_new, kcache, vcache, pos):
    """Write (k_new, v_new) at per-row `pos` and attend. `pos` may be a
    scalar (synchronized static batch) or a [B] vector (continuous batching:
    every slot sits at its own position). When the cache sequence dim is
    sharded over "model", both the per-row scatter and the flash-decode
    partial softmax run rank-local inside shard_map (paper-free
    beyond-baseline: this is flash-decoding adapted to SPMD TPU)."""
    from jax.sharding import PartitionSpec as P
    posv = _pos_vec(pos, q.shape[0])
    seq_sharded = (plan is not None and "model" in plan.mesh.axis_names
                   and plan.rules.get("kv_seq") is not None
                   and kcache.shape[1] % plan.mesh.shape["model"] == 0)
    if not seq_sharded:
        rows = jnp.arange(q.shape[0])
        kc = kcache.at[rows, posv].set(k_new[:, 0])
        vc = vcache.at[rows, posv].set(v_new[:, 0])
        o = L.decode_attention(q, kc, vc, length=posv + 1)
        return o, kc, vc

    mesh = plan.mesh
    dp = _dp_or_none(plan, q.shape[0])

    def local(qb, knb, vnb, kb, vb, posb):
        B, _, H, Dh = qb.shape
        _, Sl, KV, _ = kb.shape
        g = H // KV
        r = jax.lax.axis_index("model")
        lpos = posb - r * Sl                                   # [B]
        in_rng = (lpos >= 0) & (lpos < Sl)
        safe = jnp.where(in_rng, lpos, Sl)  # off-rank rows drop
        rows = jnp.arange(B)
        kb = kb.at[rows, safe].set(knb[:, 0], mode="drop")
        vb = vb.at[rows, safe].set(vnb[:, 0], mode="drop")
        gpos = r * Sl + jnp.arange(Sl)
        valid = gpos[None, :] <= posb[:, None]                 # [B, Sl]
        qr = qb.reshape(B, KV, g, Dh)
        s = jnp.einsum("bkgd,bckd->bkgc", qr, kb,
                       preferred_element_type=F32) / math.sqrt(Dh)
        s = jnp.where(valid[:, None, None], s, -jnp.inf)
        m = jax.lax.pmax(jnp.max(s, axis=-1), "model")
        p_ = jnp.exp(s - m[..., None])
        l = jax.lax.psum(jnp.sum(p_, axis=-1), "model")
        o = jnp.einsum("bkgc,bckd->bkgd", p_.astype(qb.dtype), vb,
                       preferred_element_type=F32)
        o = jax.lax.psum(o, "model") / jnp.maximum(l, 1e-30)[..., None]
        return o.reshape(B, 1, H, Dh).astype(qb.dtype), kb, vb

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(dp), P(dp), P(dp), P(dp, "model"), P(dp, "model"),
                  P(dp)),
        out_specs=(P(dp), P(dp, "model"), P(dp, "model")),
        check=False)(q, k_new, v_new, kcache, vcache, posv)


def _apply_mla(p, x, cfg, plan, mode, positions, cache, pos, pages=None):
    q_nope, q_rope = L.mla_project_q(p, x, cfg, positions)
    c_new, kr_new = L.mla_compress_kv(p, x, cfg, positions)
    if plan is not None:  # TP: query heads over "model"
        q_nope = plan.constraint(q_nope, "batch", None, "heads", None)
        q_rope = plan.constraint(q_rope, "batch", None, "heads", None)
    use_flash = plan is not None and plan.rules.get("mla_flash")
    mla_fn = (lambda *a, **kw: L.mla_attention_flash(*a, plan=plan, **kw)) \
        if use_flash else L.mla_attention
    if mode == "train":
        o = mla_fn(p, q_nope, q_rope, c_new, kr_new, cfg, causal=True)
        return L.mla_output(p, o, cfg), None
    if mode == "prefill":
        o = mla_fn(p, q_nope, q_rope, c_new, kr_new, cfg, causal=True)
        S, Sc = x.shape[1], cache["c_kv"].shape[1]
        new = {"c_kv": jnp.pad(c_new, [(0, 0), (0, Sc - S), (0, 0)]
                               ).astype(cache["c_kv"].dtype),
               "k_rope": jnp.pad(kr_new, [(0, 0), (0, Sc - S), (0, 0)]
                                 ).astype(cache["k_rope"].dtype)}
        return L.mla_output(p, o, cfg), new
    o, cc, krc = _mla_decode_update(plan, p, q_nope, q_rope,
                                    c_new.astype(cache["c_kv"].dtype),
                                    kr_new.astype(cache["k_rope"].dtype),
                                    cache["c_kv"], cache["k_rope"],
                                    pos, cfg, pages)
    return L.mla_output(p, o, cfg), {"c_kv": cc, "k_rope": krc}


def _mla_decode_update(plan, p, q_nope, q_rope, c_new, kr_new, c_cache,
                       kr_cache, pos, cfg, pages=None):
    """Absorbed-matrix MLA flash-decode over the (paged / seq-sharded)
    compressed cache: scores q_eff.c + q_rope.k_rope, values combine in
    latent space. `pos` is scalar or per-slot [B]."""
    from jax.sharding import PartitionSpec as P
    m = cfg.mla
    H = cfg.n_heads
    B = q_nope.shape[0]
    posv = _pos_vec(pos, B)
    w_uk = p["w_uk"].reshape(m.kv_lora, H, m.d_nope)
    q_eff = jnp.einsum("bshn,qhn->bshq", q_nope, w_uk)[:, 0]   # [B,H,lora]
    qr = q_rope[:, 0]                                          # [B,H,rope]
    scale = 1.0 / math.sqrt(m.d_nope + m.d_rope)

    seq_sharded = (pages is None and plan is not None
                   and "model" in plan.mesh.axis_names
                   and plan.rules.get("kv_seq") is not None
                   and c_cache.shape[1] % plan.mesh.shape["model"] == 0)

    def attend(qe, qrope, cc, krc, posb, axis=None, rank0=0):
        Sl = cc.shape[1]
        gpos = rank0 + jnp.arange(Sl)
        s = (jnp.einsum("bhq,btq->bht", qe, cc, preferred_element_type=F32)
             + jnp.einsum("bhr,btr->bht", qrope, krc,
                          preferred_element_type=F32)) * scale
        s = jnp.where(gpos[None, None, :] <= posb[:, None, None], s,
                      -jnp.inf)
        m_loc = jnp.max(s, axis=-1)
        if axis:
            m_g = jax.lax.pmax(m_loc, axis)
        else:
            m_g = m_loc
        pw = jnp.exp(s - m_g[..., None])
        l = jnp.sum(pw, axis=-1)
        lat = jnp.einsum("bht,btq->bhq", pw.astype(cc.dtype), cc,
                         preferred_element_type=F32)
        if axis:
            l = jax.lax.psum(l, axis)
            lat = jax.lax.psum(lat, axis)
        return (lat / jnp.maximum(l, 1e-30)[..., None])

    if pages is not None:
        table, psize = pages
        cc = _paged_update(c_cache, c_new, posv, table, psize)
        krc = _paged_update(kr_cache, kr_new, posv, table, psize)
        lat = attend(q_eff, qr, _paged_gather(cc, table),
                     _paged_gather(krc, table), posv)
    elif not seq_sharded:
        rows = jnp.arange(B)
        cc = c_cache.at[rows, posv].set(c_new[:, 0])
        krc = kr_cache.at[rows, posv].set(kr_new[:, 0])
        lat = attend(q_eff, qr, cc, krc, posv)
    else:
        mesh = plan.mesh
        dp = _dp_or_none(plan, q_nope.shape[0])

        def local(qe, qrope, cnb, krnb, cb, krb, posb):
            Sl = cb.shape[1]
            r = jax.lax.axis_index("model")
            lpos = posb - r * Sl                               # [B]
            in_rng = (lpos >= 0) & (lpos < Sl)
            safe = jnp.where(in_rng, lpos, Sl)  # off-rank rows drop
            rows = jnp.arange(cb.shape[0])
            cb = cb.at[rows, safe].set(cnb[:, 0], mode="drop")
            krb = krb.at[rows, safe].set(krnb[:, 0], mode="drop")
            lat = attend(qe, qrope, cb, krb, posb, axis="model",
                         rank0=r * Sl)
            return lat, cb, krb

        lat, cc, krc = shard_map(
            local, mesh=mesh,
            in_specs=(P(dp), P(dp), P(dp), P(dp), P(dp, "model"),
                      P(dp, "model"), P(dp)),
            out_specs=(P(dp), P(dp, "model"), P(dp, "model")),
            check=False)(q_eff, qr, c_new, kr_new, c_cache, kr_cache, posv)

    w_uv = p["w_uv"].reshape(m.kv_lora, H, m.d_v)
    o = jnp.einsum("bhq,qhv->bhv", lat.astype(q_nope.dtype), w_uv)
    return o[:, None], cc, krc


def _apply_mixer(spec: LayerSpec, p, x, cfg, plan, mode, positions, cache,
                 pos, pages=None):
    if spec.mixer == "attn":
        return _apply_attn(p, x, cfg, plan, mode, positions, cache, pos,
                           pages)
    if spec.mixer == "mla":
        return _apply_mla(p, x, cfg, plan, mode, positions, cache, pos,
                          pages)
    def _cast(new):
        if new is None or cache is None:
            return new
        return {k: v.astype(cache[k].dtype) for k, v in new.items()}

    if spec.mixer == "mamba":
        state = None if mode in ("train", "prefill") else \
            (cache["tail"], cache["h"])
        out, (tail, h) = ssm.mamba_apply(p, x, cfg, state=state, plan=plan)
        new = {"tail": tail, "h": h} if mode != "train" else None
        return out, _cast(new)
    if spec.mixer == "mlstm":
        state = None if mode in ("train", "prefill") else \
            (cache["C"], cache["n"])
        out, (C, n) = ssm.mlstm_apply(p, x, cfg, state=state)
        new = {"C": C, "n": n} if mode != "train" else None
        return out, _cast(new)
    if spec.mixer == "slstm":
        state = None if mode in ("train", "prefill") else \
            (cache["c"], cache["n"], cache["h"], cache["m"])
        out, (c, n, h, m_) = ssm.slstm_apply(p, x, cfg, state=state)
        new = {"c": c, "n": n, "h": h, "m": m_} if mode != "train" else None
        return out, _cast(new)
    raise ValueError(spec.mixer)


def _apply_layer(spec: LayerSpec, p, x, cfg, plan, mode, positions, cache,
                 pos, cross_p=None, enc_out=None, expert_perm=None,
                 pages=None):
    aux = jnp.float32(0.0)
    h = _norm(p["norm1"], x, cfg)
    mix, new_cache = _apply_mixer(spec, p["mixer"], h, cfg, plan, mode,
                                  positions, cache, pos, pages)
    x = x + mix
    if cross_p is not None and enc_out is not None:
        hx = _norm(cross_p["normx"], x, cfg)
        q, k, v = L.qkv_project(cross_p["cross"], hx, enc_out, cfg)
        o = L.flash_attention(q, k, v, causal=False,
                              q_chunk=cfg.q_chunk, k_chunk=cfg.k_chunk)
        x = x + _attn_out(cross_p["cross"], o)
    if "ffn" in p:
        h = _norm(p["norm2"], x, cfg)
        if spec.ffn == "moe":
            out, a = L.moe_apply(p["ffn"], h, cfg, expert_perm, plan)
            aux = aux + a
        else:
            out = L.mlp_apply(p["ffn"], h, plan)
        x = x + out
    if plan is not None:
        x = plan.constraint(x, "batch", None, None)
    return x, new_cache, aux


def _norm(p, x, cfg):
    return rms_norm(x, p["g"]) if cfg.norm == "rms" else \
        layer_norm(x, p["g"], p["b"])


# ---------------------------------------------------------------------------
# full forward
# ---------------------------------------------------------------------------
def _encoder_forward(params, frames, cfg, plan):
    """Whisper-style encoder over stub frame embeddings [B, T, D]."""
    T = frames.shape[1]
    x = frames + params["encoder"]["pos"][:T]

    def body_nc(x, sp):
        h = _norm(sp["norm1"], x, cfg)
        q, k, v = L.qkv_project(sp["mixer"], h, h, cfg)
        o = L.flash_attention(q, k, v, causal=False, q_chunk=cfg.q_chunk,
                              k_chunk=cfg.k_chunk)
        x = x + _attn_out(sp["mixer"], o)
        h = _norm(sp["norm2"], x, cfg)
        return x + L.mlp_apply(sp["ffn"], h, plan), None

    x, _ = jax.lax.scan(body_nc, x, params["encoder"]["stack"])
    return _norm(params["encoder"]["final_norm"], x, cfg)


def _positions(pos, S: int):
    """Sequence positions for the current chunk: [S] when `pos` is None or
    scalar, [B, S] when `pos` is a per-slot [B] vector (continuous decode:
    each batch row sits at its own position)."""
    if pos is None:
        return jnp.arange(S)
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        return pos + jnp.arange(S)
    return pos[:, None] + jnp.arange(S)[None, :]


def forward(params, tokens, cfg: ArchConfig, plan=None, *, mode="train",
            cache=None, pos=None, vision=None, enc_frames=None,
            expert_perm=None, remat=True, page_table=None,
            page_size: int = 0):
    """Returns (hidden [B,S,D], new_cache, aux_loss). `pos` may be a scalar
    (synchronized decode) or a [B] vector of per-slot positions; with
    `page_table` [B, P] (+ static `page_size`) the decode-mode KV updates go
    through the paged block-table layout instead of the dense [B, S] one."""
    B, S = tokens.shape
    pages = (page_table, page_size) if page_table is not None else None
    x = L.embed_apply(params["embed"], tokens, cfg,
                      positions=_positions(pos, S)
                      if cfg.pos == "learned" else None)
    if vision is not None and cfg.vision_dim:
        vx = jnp.einsum("bpv,vd->bpd", vision, params["embed"]["vis_proj"])
        x = jnp.concatenate([vx, x], axis=1)
        S = x.shape[1]
    if plan is not None:
        x = plan.constraint(x, "batch", None, None)

    positions = _positions(pos, S)
    enc_out = None
    if cfg.encoder_layers:
        if mode == "decode":
            enc_out = cache["enc_out"]
        else:
            assert enc_frames is not None
            enc_out = _encoder_forward(params, enc_frames, cfg, plan)

    aux = jnp.float32(0.0)
    # unscanned leading dense layers (deepseek first_k_dense)
    for k in range(cfg.first_k_dense):
        c = cache[f"dense{k}"] if cache is not None else None
        x, nc, a = _apply_layer(
            dataclasses.replace(cfg.pattern[0], ffn="mlp"),
            params[f"dense{k}"], x, cfg, plan, mode, positions, c, pos,
            pages=pages)
        aux += a
        if cache is not None and nc is not None:
            cache = dict(cache)
            cache[f"dense{k}"] = nc

    cross_stack = params.get("stack_cross")

    def body(carry, xs):
        x, aux = carry
        slot_params, slot_caches, slot_cross = xs
        new_caches = {}
        for i, spec in enumerate(cfg.pattern):
            key = f"slot{i}"
            c = slot_caches[key] if slot_caches is not None else None
            xp = slot_cross[key] if slot_cross is not None else None
            x, nc, a = _apply_layer(spec, slot_params[key], x, cfg, plan,
                                    mode, positions, c, pos, xp, enc_out,
                                    expert_perm, pages)
            aux = aux + a
            new_caches[key] = nc
        return (x, aux), new_caches

    body_fn = jax.checkpoint(body) if (mode == "train" and remat) else body
    cache_stack = cache["stack"] if cache is not None else None
    (x, aux), new_stack = jax.lax.scan(
        body_fn, (x, aux), (params["stack"], cache_stack, cross_stack))

    x = _norm(params["final_norm"], x, cfg)
    new_cache = None
    if cache is not None:
        new_cache = dict(cache)
        new_cache["stack"] = new_stack
        if cfg.encoder_layers and mode != "decode":
            new_cache["enc_out"] = enc_out.astype(cache["enc_out"].dtype)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------
def chunked_ce_loss(x, params, labels, cfg: ArchConfig, chunk: int = 512,
                    z_loss: float = 1e-4):
    """CE over sequence chunks — never materializes [B, S, V] logits."""
    B, S, D = x.shape
    chunk = math.gcd(min(chunk, S), S)
    nc = S // chunk
    xr = jnp.moveaxis(x.reshape(B, nc, chunk, D), 1, 0)
    lr = jnp.moveaxis(labels.reshape(B, nc, chunk), 1, 0)

    def body(acc, xs):
        xc, lc = xs
        logits = L.unembed_apply(params["embed"], xc, cfg)
        mask = (lc >= 0).sum()
        loss = softmax_cross_entropy(logits, lc, z_loss) * jnp.maximum(mask, 1)
        return (acc[0] + loss, acc[1] + mask), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.int32(0)),
                                 (xr, lr))
    return tot / jnp.maximum(cnt, 1)


def loss_fn(params, batch, cfg: ArchConfig, plan=None, expert_perm=None,
            remat=True):
    """batch: dict(tokens [B,S], labels [B,S], + optional vision/enc_frames)."""
    x, _, aux = forward(params, batch["tokens"], cfg, plan, mode="train",
                        vision=batch.get("vision"),
                        enc_frames=batch.get("enc_frames"),
                        expert_perm=expert_perm, remat=remat)
    lbl = batch["labels"]
    if batch.get("vision") is not None and cfg.vision_dim:
        pad = jnp.full((lbl.shape[0], x.shape[1] - lbl.shape[1]), -1,
                       lbl.dtype)
        lbl = jnp.concatenate([pad, lbl], axis=1)
    ce = chunked_ce_loss(x, params, lbl, cfg)
    return ce + aux, {"ce": ce, "aux": aux}


def prefill(params, tokens, cache, cfg: ArchConfig, plan=None, *,
            vision=None, enc_frames=None, expert_perm=None):
    """Fills `cache` (zeros, cache_len >= S); returns (last_logits, cache)."""
    x, new_cache, _ = forward(params, tokens, cfg, plan, mode="prefill",
                              cache=cache, vision=vision,
                              enc_frames=enc_frames, expert_perm=expert_perm)
    logits = L.unembed_apply(params["embed"], x[:, -1:], cfg)
    return logits[:, 0], new_cache


def decode_step(params, token, pos, cache, cfg: ArchConfig, plan=None,
                expert_perm=None, page_table=None, page_size: int = 0):
    """token [B,1] int32, pos scalar int32 OR per-slot [B] int32 vector
    (continuous batching). With `page_table` [B, P] + static `page_size` the
    KV caches are paged (see `cache_shapes(page_size=..., n_pages=...)`).
    Returns (logits [B,V], cache)."""
    x, new_cache, _ = forward(params, token, cfg, plan, mode="decode",
                              cache=cache, pos=pos, expert_perm=expert_perm,
                              page_table=page_table, page_size=page_size)
    logits = L.unembed_apply(params["embed"], x, cfg)
    return logits[:, 0], new_cache
