"""Parameter-spec system with logical sharding axes (t5x-style, from scratch).

Every parameter is declared as a `Spec(shape, axes)` where `axes` names each
dimension with a *logical* axis ("embed", "mlp", "heads", "experts", ...).
A parallelism plan maps logical axes to mesh axes; `shardings()` resolves
them to NamedShardings with automatic divisibility fallback (a dim that
does not divide its mesh axes is replicated — e.g. 8 KV heads on a 16-way
"model" axis). Specs materialize to real arrays (smoke tests / training) or
jax.ShapeDtypeStruct stand-ins (multi-pod dry-run: no allocation).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec


@dataclasses.dataclass(frozen=True)
class Spec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"     # normal | zeros | ones | embed
    scale: float | None = None  # default: 1/sqrt(fan_in)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, Spec)


def tree_map_specs(fn: Callable[[Spec], Any], tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_spec)


def materialize(tree, rng: jax.Array, dtype=jnp.float32):
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=is_spec)
    rngs = jax.random.split(rng, max(len(leaves), 1))
    out = []
    for spec, r in zip(leaves, rngs):
        if spec.init == "zeros":
            out.append(jnp.zeros(spec.shape, dtype))
        elif spec.init == "ones":
            out.append(jnp.ones(spec.shape, dtype))
        else:
            fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
            scale = spec.scale if spec.scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
            if spec.init == "embed":
                scale = spec.scale if spec.scale is not None else 1.0
            out.append(scale * jax.random.normal(r, spec.shape, dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def abstracts(tree, dtype=jnp.bfloat16):
    return tree_map_specs(lambda s: jax.ShapeDtypeStruct(s.shape, dtype), tree)


def _resolve_pspec(spec: Spec, rules: dict[str, Any], mesh: Mesh) -> PartitionSpec:
    entries = []
    used: set[str] = set()  # a mesh axis may shard at most one dim;
    # earlier dims win (axes tuples are declared most-important-first,
    # e.g. ("experts", "embed", "mlp") keeps EP and drops the TP dim).
    for dim, name in zip(spec.shape, spec.axes):
        mapped = rules.get(name) if name else None
        if mapped is None:
            entries.append(None)
            continue
        mesh_axes = (mapped,) if isinstance(mapped, str) else tuple(mapped)
        if any(ax in used for ax in mesh_axes):
            entries.append(None)
            continue
        total = 1
        for ax in mesh_axes:
            total *= mesh.shape[ax]
        if dim % total != 0:
            entries.append(None)  # divisibility fallback: replicate
        else:
            used.update(mesh_axes)
            entries.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
    while entries and entries[-1] is None:
        entries.pop()
    return PartitionSpec(*entries)


def pspecs(tree, rules: dict[str, Any], mesh: Mesh):
    return tree_map_specs(lambda s: _resolve_pspec(s, rules, mesh), tree)


def shardings(tree, rules: dict[str, Any], mesh: Mesh):
    return tree_map_specs(
        lambda s: NamedSharding(mesh, _resolve_pspec(s, rules, mesh)), tree)


def shard_map(f, *, mesh, in_specs, out_specs, check=False):
    """Version-portable shard_map: `jax.shard_map` (new API, check_vma)
    when present, else `jax.experimental.shard_map` (check_rep)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check)


def param_count(tree) -> int:
    return sum(int(np.prod(s.shape))
               for s in jax.tree_util.tree_leaves(tree, is_leaf=is_spec))


def stack_specs(tree, n: int, axis_name: str = "layers"):
    """Prepend a stacked leading dim (scan-over-layers axis) to every spec."""
    return tree_map_specs(
        lambda s: Spec((n,) + s.shape, (axis_name,) + s.axes, s.init, s.scale),
        tree)


# ---------------------------------------------------------------------------
# numeric primitives
# ---------------------------------------------------------------------------
def rms_norm(x, gamma, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * gamma


def layer_norm(x, gamma, beta, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return (((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma
            + beta)


def rope_freqs(head_dim: int, theta: float = 1e4):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float = 1e4):
    """x: [..., S, H, D] (D even), positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # [D/2]
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # [...,S,1,D/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


def softmax_cross_entropy(logits, labels, z_loss: float = 0.0):
    """Mean token CE; logits [..., V] f32-upcast; labels int32 (-1 = pad).

    The label log-prob uses a one-hot mask-and-reduce rather than
    take_along_axis: under a vocab-sharded logits layout the reduction
    lowers to a cheap all-reduce instead of an all-gather of the logits.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    v = logits.shape[-1]
    onehot = labels[..., None].clip(0) == jnp.arange(v, dtype=labels.dtype)
    ll = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    mask = labels >= 0
    return jnp.sum(loss * mask) / jnp.maximum(jnp.sum(mask), 1)
