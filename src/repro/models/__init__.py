from repro.models import common, layers, ssm, transformer  # noqa: F401
