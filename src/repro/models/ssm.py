"""Recurrent mixers: Mamba (selective SSM, chunked parallel scan), and the
xLSTM blocks (mLSTM: matrix memory, chunkwise-parallel linear-attention
form; sLSTM: scalar memory, sequential scan). These are the sub-quadratic
mixers that make the `long_500k` decode cells O(1) per token.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import Spec

F32 = jnp.float32


# ---------------------------------------------------------------------------
# Mamba (selective state space)
# ---------------------------------------------------------------------------
def mamba_shapes(cfg: ArchConfig) -> dict:
    mb = cfg.mamba
    D = cfg.d_model
    di = mb.expand * D
    dt_rank = mb.dt_rank or max(1, math.ceil(D / 16))
    return {
        "w_in": Spec((D, 2 * di), ("embed", "mlp")),
        "conv_w": Spec((mb.d_conv, di), (None, "mlp"), scale=0.5),
        "conv_b": Spec((di,), ("mlp",), init="zeros"),
        "w_x": Spec((di, dt_rank + 2 * mb.d_state), ("mlp", None)),
        "w_dt": Spec((dt_rank, di), (None, "mlp")),
        "b_dt": Spec((di,), ("mlp",), init="ones", scale=1.0),
        "a_log": Spec((di, mb.d_state), ("mlp", None), init="ones"),
        "d_skip": Spec((di,), ("mlp",), init="ones"),
        "w_out": Spec((di, D), ("mlp", "embed")),
    }


def _mamba_scan_chunked(dA, dBx, h0, chunk: int):
    """h_t = dA_t * h_{t-1} + dBx_t over axis 1 (S), chunked to bound the
    associative-scan working set. dA/dBx: [B,S,di,ds]."""
    B, S, di, ds = dA.shape
    chunk = min(chunk, S)
    chunk = math.gcd(chunk, S)
    nc = S // chunk
    dA_c = jnp.moveaxis(dA.reshape(B, nc, chunk, di, ds), 1, 0)
    dBx_c = jnp.moveaxis(dBx.reshape(B, nc, chunk, di, ds), 1, 0)

    def combine(a, b):
        (a1, b1), (a2, b2) = a, b
        return a2 * a1, a2 * b1 + b2

    def body(h, ab):
        a, b = ab
        acum, bcum = jax.lax.associative_scan(combine, (a, b), axis=1)
        h_inner = bcum + acum * h[:, None]
        return h_inner[:, -1], h_inner

    h_last, hs = jax.lax.scan(body, h0, (dA_c, dBx_c))
    return h_last, jnp.moveaxis(hs, 0, 1).reshape(B, S, di, ds)


def mamba_apply(p, x, cfg: ArchConfig, state=None, chunk: int = 256,
                plan=None):
    """x [B,S,D]. state (decode): (conv_tail [B,d_conv-1,di], h [B,di,ds]).
    Returns (out, new_state)."""
    mb = cfg.mamba
    B, S, D = x.shape
    di = mb.expand * D
    dt_rank = mb.dt_rank or max(1, math.ceil(D / 16))

    xz = jnp.einsum("bsd,de->bse", x, p["w_in"])
    if plan is not None:  # TP: d_inner over "model"
        xz = plan.constraint(xz, "batch", None, "mlp")
    xin, z = jnp.split(xz, 2, axis=-1)

    # causal depthwise conv over S
    if state is None:
        tail = jnp.zeros((B, mb.d_conv - 1, di), x.dtype)
    else:
        tail = state[0]
    xpad = jnp.concatenate([tail, xin], axis=1)
    idx = jnp.arange(S)
    conv = sum(xpad[:, idx + j, :] * p["conv_w"][j]
               for j in range(mb.d_conv)) + p["conv_b"]
    new_tail = xpad[:, S:, :] if xpad.shape[1] - S == mb.d_conv - 1 else \
        xpad[:, -(mb.d_conv - 1):, :]
    xc = jax.nn.silu(conv)

    dbc = jnp.einsum("bse,ef->bsf", xc, p["w_x"])
    dt_raw = dbc[..., :dt_rank]
    Bmat = dbc[..., dt_rank: dt_rank + mb.d_state]
    Cmat = dbc[..., dt_rank + mb.d_state:]
    dt = jax.nn.softplus(jnp.einsum("bsr,re->bse", dt_raw, p["w_dt"])
                         + p["b_dt"])                            # [B,S,di]
    A = -jnp.exp(p["a_log"].astype(F32))                        # [di,ds]
    dA = jnp.exp(dt[..., None].astype(F32) * A)                 # [B,S,di,ds]
    dBx = (dt * xc)[..., None].astype(F32) * Bmat[:, :, None, :].astype(F32)

    h0 = jnp.zeros((B, di, mb.d_state), F32) if state is None else \
        state[1].astype(F32)
    h_last, hs = _mamba_scan_chunked(dA, dBx, h0, chunk)
    y = jnp.einsum("bsen,bsn->bse", hs, Cmat.astype(F32)).astype(x.dtype)
    y = y + xc * p["d_skip"]
    out = jnp.einsum("bse,ed->bsd", y * jax.nn.silu(z), p["w_out"])
    return out, (new_tail, h_last)


# ---------------------------------------------------------------------------
# xLSTM: mLSTM (matrix memory, chunkwise linear attention with decay)
# ---------------------------------------------------------------------------
def mlstm_shapes(cfg: ArchConfig) -> dict:
    D = cfg.d_model
    H, DH = cfg.n_heads, cfg.d_model // cfg.n_heads
    return {
        "wq": Spec((D, D), ("embed", "heads")),
        "wk": Spec((D, D), ("embed", "heads")),
        "wv": Spec((D, D), ("embed", "heads")),
        "w_i": Spec((D, H), ("embed", None), scale=0.02),
        "w_f": Spec((D, H), ("embed", None), scale=0.02),
        "b_f": Spec((H,), (None,), init="ones", scale=3.0),
        "w_og": Spec((D, D), ("embed", "heads")),
        "wo": Spec((D, D), ("heads", "embed")),
    }


def mlstm_apply(p, x, cfg: ArchConfig, state=None, chunk: int = 128):
    """Chunkwise mLSTM: C_t = f_t C_{t-1} + i_t v_t k_t^T, y_t = C_t q_t /
    max(|n_t q_t|, 1). state: (C [B,H,DH,DH], n [B,H,DH])."""
    B, S, D = x.shape
    H = cfg.n_heads
    DH = D // H
    q = jnp.einsum("bsd,de->bse", x, p["wq"]).reshape(B, S, H, DH)
    k = jnp.einsum("bsd,de->bse", x, p["wk"]).reshape(B, S, H, DH) / math.sqrt(DH)
    v = jnp.einsum("bsd,de->bse", x, p["wv"]).reshape(B, S, H, DH)
    logf = jax.nn.log_sigmoid(
        jnp.einsum("bsd,dh->bsh", x, p["w_f"]).astype(F32) + p["b_f"])
    logi = jnp.einsum("bsd,dh->bsh", x, p["w_i"]).astype(F32)

    chunk = math.gcd(min(chunk, S), S)
    nc = S // chunk
    rs = lambda a: jnp.moveaxis(a.reshape(B, nc, chunk, *a.shape[2:]), 1, 0)
    qc, kc, vc, fc, ic = map(rs, (q, k, v, logf, logi))

    if state is None:
        C0 = jnp.zeros((B, H, DH, DH), F32)
        n0 = jnp.zeros((B, H, DH), F32)
    else:
        C0, n0 = (state[0].astype(F32), state[1].astype(F32))

    def body(carry, xs):
        C, n = carry
        qb, kb, vb, fb, ib = xs
        fcum = jnp.cumsum(fb, axis=1)                   # [B,c,H]
        # intra-chunk (quadratic within chunk)
        dmat = (fcum[:, :, None] - fcum[:, None, :]) + ib[:, None, :]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        dmat = jnp.where(tri[None, :, :, None], dmat, -jnp.inf)  # [B,c,c,H]
        w = jnp.exp(dmat)
        s = jnp.einsum("bihd,bjhd->bijh", qb, kb, preferred_element_type=F32)
        y_intra = jnp.einsum("bijh,bijh,bjhe->bihe", s, w,
                             vb.astype(F32))
        # inter-chunk from carried state
        decay_q = jnp.exp(fcum)                          # [B,c,H]
        y_inter = jnp.einsum("bihd,bhde,bih->bihe",
                             qb.astype(F32), C, decay_q)
        n_dot = jnp.einsum("bihd,bhd,bih->bih", qb.astype(F32), n, decay_q)
        n_intra = jnp.einsum("bijh,bjhd,bihd->bih", w, kb.astype(F32),
                             qb.astype(F32))
        denom = jnp.maximum(jnp.abs(n_dot + n_intra), 1.0)
        y = (y_inter + y_intra) / denom[..., None]
        # state update to end of chunk
        ftot = fcum[:, -1]                               # [B,H]
        dk = jnp.exp(ftot[:, None] - fcum + ib)          # [B,c,H]
        C = C * jnp.exp(ftot)[..., None, None] + jnp.einsum(
            "bjh,bjhd,bjhe->bhde", dk, kb.astype(F32), vb.astype(F32))
        n = n * jnp.exp(ftot)[..., None] + jnp.einsum(
            "bjh,bjhd->bhd", dk, kb.astype(F32))
        return (C, n), y.astype(x.dtype)

    (C, n), ys = jax.lax.scan(body, (C0, n0), (qc, kc, vc, fc, ic))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, D)
    og = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", x, p["w_og"]))
    out = jnp.einsum("bse,ed->bsd", y * og, p["wo"])
    return out, (C, n)


# ---------------------------------------------------------------------------
# xLSTM: sLSTM (scalar memory, sequential)
# ---------------------------------------------------------------------------
def slstm_shapes(cfg: ArchConfig) -> dict:
    D = cfg.d_model
    return {
        "w_gates": Spec((D, 4 * D), ("embed", "mlp")),
        "r_gates": Spec((D, 4 * D), ("embed", "mlp"), scale=0.02),
        "b_gates": Spec((4 * D,), ("mlp",), init="zeros"),
        "wo": Spec((D, D), ("embed", "embed")),
    }


def slstm_apply(p, x, cfg: ArchConfig, state=None):
    """Sequential sLSTM with exponential gating + stabilizer state.
    state: (c, n, h, m) each [B, D]."""
    B, S, D = x.shape
    zx = jnp.einsum("bsd,de->bse", x, p["w_gates"]) + p["b_gates"]
    if state is None:
        zero = jnp.zeros((B, D), F32)
        state = (zero, zero + 1.0, zero.astype(x.dtype), zero)
    else:
        c_, n_, h_, m_ = state
        state = (c_.astype(F32), n_.astype(F32), h_.astype(x.dtype),
                 m_.astype(F32))

    def step(carry, zxt):
        c, n, h, m = carry
        z = zxt + jnp.einsum("bd,de->be", h, p["r_gates"])
        zi, zf, zz, zo = jnp.split(z.astype(F32), 4, axis=-1)
        m_new = jnp.maximum(zf + m, zi)
        i = jnp.exp(zi - m_new)
        f = jnp.exp(zf + m - m_new)
        c = f * c + i * jnp.tanh(zz)
        n = f * n + i
        h_new = (jnp.tanh(c / jnp.maximum(n, 1.0))
                 * jax.nn.sigmoid(zo)).astype(x.dtype)
        return (c, n, h_new, m_new), h_new

    (c, n, h, m), hs = jax.lax.scan(step, state, jnp.moveaxis(zx, 1, 0))
    y = jnp.moveaxis(hs, 0, 1)
    out = jnp.einsum("bsd,de->bse", y, p["wo"])
    return out, (c, n, h, m)
