from repro.serve.engine import ServeEngine  # noqa: F401
from repro.serve.paging import (  # noqa: F401
    OutOfPagesError,
    PageManager,
    PagingSpec,
)
