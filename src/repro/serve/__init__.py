from repro.serve.engine import ServeEngine  # noqa: F401
from repro.serve.paging import (  # noqa: F401
    OutOfPagesError,
    PageManager,
    PagingSpec,
)
from repro.serve.partition_service import (  # noqa: F401
    PartitionService,
    ServiceResult,
    stack_device_batch,
)
