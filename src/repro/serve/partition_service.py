"""Multi-tenant partition service: slot-scheduled batched partition solves.

The paper's workload shape at production scale is not one giant hypergraph —
it is a flood of small-to-medium partition requests (placement queries,
circuit blocks, MoE cells), each carrying its own (Omega, Delta)
constraints. `PartitionService` schedules that flood the same way
`ServeEngine` schedules decode requests: `submit()` queues a request,
`step()` admits queued work and runs one device solve, `drain()` loops to
completion and delivers `{rid: ServiceResult}`.

Scheduling policy (three lanes):

* **Capacity buckets** — small/medium graphs are padded into a geometric
  ladder of static `Caps` buckets (the PR-5 capacity machinery gives the
  static shapes; `check_expansion_caps` audits placement, and a
  `CapacityError` *bumps the request to the next bucket*). Requests sharing
  a bucket are stacked and solved as ONE vmapped device batch
  (`core.partitioner.partition_batch_device`) — per-request Omega/Delta are
  traced vectors, so every batch a bucket ever sees shares a single jit
  cache entry keyed on the bucket signature.
* **Routed V-cycle** — graphs above `route_threshold` nodes (or too big for
  any bucket) route to the existing host-driven multilevel solve
  (`core.partitioner.partition`), mesh-sharded when the service holds a
  `Plan` (`plan=`, `shard_graph=True` — the PR-5 memory-sharded storage).
* **Warm repartition** — a `submit(..., key=...)` request caches its
  solution; `resubmit(key, deltas=...)` applies the incremental
  `GraphDelta` batch to the cached graph immediately (so watchdog requeues
  never double-apply) and queues a refine-only warm solve
  (`core.partitioner.repartition`) from the previous parts, with the
  drift / audit cold fallbacks handled inside the solver. The lane shares
  the FIFO order pick and the full supervision machinery, and records the
  ``repartition.*`` counter/histogram series.
* **Supervision** — every blocking device solve is armed with
  `dist.ft.StepWatchdog` (`with wd.watch(step):`). A solve that raises, is
  killed by fault injection, or stalls past the deadline is *requeued* with
  a per-request restart budget (`max_restarts`), so no submitted rid is
  ever lost; the budget exhausting re-raises, mirroring `TrainSupervisor`.

Results are delivered as `ServiceResult` (compacted parts + the same
host-side `metrics.audit` the offline driver reports), so a bucket-solved
request is indistinguishable from a solo `partition()` call to the caller.

Telemetry: every service records into a `repro.obs.metrics.Registry`
(private per instance by default so concurrent services stay isolated; the
CLI passes the process-global one so a single ``--metrics-json`` dump
carries service + span + watchdog series). The metric catalogue lives in
docs/observability.md; the legacy ``stats`` counter dict survives as a
read-only property view over the registry.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics
from repro.core.hypergraph import (Caps, CapacityError, DeviceHypergraph,
                                   HostHypergraph, check_expansion_caps,
                                   host_pair_count, packed_host_arrays)
from repro.core.partitioner import (WarmCache, _batch_solver, partition,
                                    partition_batch_device, repartition)
from repro.dist.ft import StepWatchdog
from repro.obs import metrics as obs_metrics
from repro.obs import trace as otrace


def stack_device_batch(hgs: list[HostHypergraph], caps: Caps
                       ) -> DeviceHypergraph:
    """Stack capacity-padded staging arrays of ``hgs`` into one device batch
    (every `DeviceHypergraph` leaf gains a leading batch axis) — the input
    shape `partition_batch_device` vmaps over."""
    packed = [packed_host_arrays(hg, caps) for hg in hgs]
    stacked = {k: jnp.asarray(np.stack([p[k] for p in packed]))
               for k in packed[0]}
    return DeviceHypergraph(**stacked)


@dataclasses.dataclass(frozen=True)
class Bucket:
    """Static solve signature: caps + partition-axis capacity + unrolled
    level bound. One jit cache entry per distinct Bucket."""
    caps: Caps
    kcap: int
    max_levels: int


@dataclasses.dataclass
class ServiceResult:
    rid: int
    parts: np.ndarray          # [n_nodes] compacted partition ids
    n_parts: int
    n_levels: int
    connectivity: float
    cut_net: float
    audit: dict
    route: str                 # "bucket" | "vcycle" | "vcycle-sharded"
                               # | "warm" (keyed resubmit, repartition lane)
    bucket: Bucket | None      # the solving bucket (bucket route only)
    restarts: int              # failed/stalled solves this request survived
    bumps: int                 # capacity bumps to a bigger bucket
    queue_wait_s: float = 0.0  # total time queued, INCLUDING re-queue time
                               # after a failed/stalled/bumped attempt
    solve_s: float = 0.0       # total device-solve time across attempts


@dataclasses.dataclass
class _Request:
    rid: int
    hg: HostHypergraph
    omega: int
    delta: int
    caps_exact: Caps | None    # None on the routed lane
    bucket_i: int | None       # ladder index; None -> routed V-cycle
    order: int                 # FIFO tie-break across lanes
    restarts: int = 0
    bumps: int = 0
    submitted_at: float = 0.0  # time.monotonic() at submit()
    enqueued_at: float = 0.0   # reset by every (re-)enqueue
    queue_wait_s: float = 0.0  # accumulated across attempts
    solve_s: float = 0.0       # accumulated across attempts
    warm_key: object = None    # set -> request is keyed (resumable)
    warm_key_cold: bool = False  # keyed, but this solve is the cold seed


@dataclasses.dataclass
class _WarmState:
    """Per-key cached solution: the live host graph, its constraints, the
    last delivered partition vector, and the device-storage `WarmCache`
    that lets a resubmit skip the host->device re-upload. Deltas apply to
    ``hg`` at `resubmit()` time — before the request enters the queue — so
    a watchdog requeue can never double-apply them."""
    hg: HostHypergraph
    omega: int
    delta: int
    parts: np.ndarray
    cache: WarmCache


class PartitionService:
    """See module docstring. Construction is cheap; device work happens in
    `step()`/`drain()`.

    Parameters
    ----------
    theta, n_cands, chain_rounds : solver params shared by every request
        (they are part of the static bucket signature).
    batch_slots : device-batch width per bucket solve; short batches pad by
        repeating lane 0 (discarded), so B is static per bucket.
    bucket_base : node capacity of the smallest bucket; ladder doubles up to
        `route_threshold`.
    route_threshold : graphs with more nodes (or that fit no bucket) take
        the host-driven V-cycle, mesh-sharded when `plan` is set.
    plan, shard_graph, race : forwarded to the routed `partition()` call.
    deadline_s : `StepWatchdog` deadline per device solve.
    max_restarts : per-request budget of failed/stalled solves before the
        failure re-raises.
    requeue_on_stall : a stalled-but-completed solve is discarded and
        requeued while budget remains (the completed result may come from a
        flaky device); with the budget spent the late result is accepted.
    fault_hook : test-only injection point, called as ``hook(route, reqs)``
        immediately before each device solve; a raise is treated exactly
        like a solve failure.
    registry : `repro.obs.metrics.Registry` to record service metrics into;
        None (default) creates a private one so concurrent services do not
        mix series. Pass `repro.obs.metrics.REGISTRY` to join the
        process-global dump (the CLI does).
    collect_stats : forward per-level quality `LevelStats` collection to the
        routed `partition()` lane.
    """

    def __init__(self, theta: int = 16, n_cands: int = 4,
                 chain_rounds: int = 16, batch_slots: int = 4,
                 bucket_base: int = 64, route_threshold: int = 2048,
                 plan=None, shard_graph: bool = True, race: bool = True,
                 deadline_s: float = 300.0, max_restarts: int = 3,
                 requeue_on_stall: bool = True, fault_hook=None,
                 registry: obs_metrics.Registry | None = None,
                 collect_stats: bool = False):
        if batch_slots < 1:
            raise ValueError(f"batch_slots must be >= 1, got {batch_slots}")
        if bucket_base < 2:
            raise ValueError(f"bucket_base must be >= 2, got {bucket_base}")
        self.theta = theta
        self.n_cands = n_cands
        self.chain_rounds = chain_rounds
        self.batch_slots = batch_slots
        self.bucket_base = 1 << max(1, math.ceil(math.log2(bucket_base)))
        self.route_threshold = route_threshold
        self.plan = plan
        self.shard_graph = shard_graph
        self.race = race
        self.deadline_s = deadline_s
        self.max_restarts = max_restarts
        self.requeue_on_stall = requeue_on_stall
        self.fault_hook = fault_hook
        self.registry = registry if registry is not None \
            else obs_metrics.Registry()
        self.collect_stats = collect_stats
        # ladder indices 0..n_buckets-1; smallest bucket >= route_threshold
        # closes the ladder (a graph may need its caps even with few nodes)
        self.n_buckets = 1
        while (self.bucket_base << (self.n_buckets - 1)) < route_threshold:
            self.n_buckets += 1
        self._backlogs: dict[int, collections.deque] = {}
        self._routed: collections.deque = collections.deque()
        self._warm: collections.deque = collections.deque()
        self._warm_state: dict = {}
        self.drift_threshold = 0.25
        self._results: dict[int, ServiceResult] = {}
        self._next_rid = 0
        self._next_order = 0
        self._solve_no = 0
        self._wd: StepWatchdog | None = None
        self.stall_log: list[int] = []
        # pre-register the zero-valued counter series so a dump taken
        # before the first event still carries the full catalogue
        r = self.registry
        for route in ("bucket", self._routed_route(), "warm"):
            r.counter("service.submitted", 0, route=route)
            r.counter("service.solves", 0, route=route)
            r.counter("service.requeues", 0, route=route)
            r.counter("service.stalls", 0, route=route)
        r.counter("service.bumps", 0)
        r.gauge("service.pending", 0)
        # streaming-repartition lane catalogue (the schema test validates a
        # dump taken before any warm solve, so the histogram pre-registers
        # too — `Registry.histogram` is the empty-series analogue of
        # `counter(name, 0)`)
        r.counter("repartition.submitted", 0)
        for mode in ("warm", "fallback-drift", "fallback-audit"):
            r.counter("repartition.solves", 0, mode=mode)
        r.histogram("repartition.solve_latency.s")

    def _routed_route(self) -> str:
        return "vcycle" if self.plan is None else "vcycle-sharded"

    @property
    def stats(self) -> dict:
        """Legacy counter-dict view (read-only) over the registry — the
        telemetry counters are the source of truth now."""
        r = self.registry
        return dict(
            batch_solves=int(r.value("service.solves", route="bucket")),
            routed_solves=int(r.value("service.solves",
                                      route=self._routed_route())),
            restarts=int(r.total("service.requeues")),
            stalls=int(r.total("service.stalls")),
            bumps=int(r.total("service.bumps")))

    # ------------------------------------------------------------- buckets
    def bucket(self, i: int) -> Bucket:
        """Ladder bucket i: node cap `bucket_base << i`, companion caps by
        fixed multipliers (pairs 16x nodes — dense graphs overflow this and
        bump up the ladder via the placement audit). The multipliers are
        deliberately tight: every level of the device scan computes at full
        bucket caps, so padding slack is paid `max_levels` times over and a
        ladder bump is cheaper than a fat bucket. The kernel tile fields
        (d_max/h0/l0/u0) are zeroed: `vcycle_device` never dispatches the
        Pallas kernels, and zeroing keeps the signature request-independent."""
        n = self.bucket_base << i
        caps = Caps(n=n, e=n, p=4 * n, pairs=16 * n, nbrs=16 * n)
        return Bucket(caps=caps, kcap=n, max_levels=int(math.log2(n)) + 1)

    def _place(self, hg: HostHypergraph, caps_exact: Caps,
               min_bucket: int = 0) -> int | None:
        """Smallest ladder bucket that fits, or None -> routed V-cycle.
        `check_expansion_caps` is the placement audit: a `CapacityError`
        (pair expansion over the bucket's cap) bumps to the next bucket."""
        if hg.n_nodes > self.route_threshold:
            return None
        pair_need = host_pair_count(hg)
        for i in range(min_bucket, self.n_buckets):
            c = self.bucket(i).caps
            if caps_exact.n > c.n or caps_exact.e > c.e or caps_exact.p > c.p:
                continue
            try:
                check_expansion_caps(c, pair_need)
            except CapacityError:
                continue  # audit failure: bump to the next bucket
            return i
        return None

    # ----------------------------------------------------- slot scheduler
    def submit(self, hg: HostHypergraph, omega: int, delta: int,
               key=None) -> int:
        """Queue one partition request; returns a request id whose
        `ServiceResult` `step()`/`drain()` eventually deliver. A non-None
        ``key`` makes the request *resumable*: once solved, the service
        caches the solution under the key and `resubmit(key, deltas=...)`
        routes follow-up solves through the warm repartition lane."""
        if hg.n_nodes < 1:
            raise ValueError("empty hypergraph")
        rid = self._next_rid
        self._next_rid += 1
        routed = hg.n_nodes > self.route_threshold
        caps_exact = None if routed else Caps.for_host(hg)
        bucket_i = None if routed else self._place(hg, caps_exact)
        req = _Request(rid=rid, hg=hg, omega=int(omega), delta=int(delta),
                       caps_exact=caps_exact, bucket_i=bucket_i,
                       order=self._next_order,
                       submitted_at=time.monotonic())
        if key is not None:
            req.warm_key = key
            req.warm_key_cold = True  # first solve is cold; cached after
        self._next_order += 1
        self.registry.counter(
            "service.submitted",
            route="bucket" if bucket_i is not None else self._routed_route())
        self._enqueue(req)
        return rid

    def resubmit(self, key, deltas=None) -> int:
        """Queue a warm re-solve of the cached solution under ``key``:
        apply ``deltas`` (a `GraphDelta` or a sequence) to the cached graph
        NOW — before the request enters the queue, so a watchdog requeue
        never double-applies — then enqueue a repartition request that
        refines from the previous partition vector (cold fallback on drift
        or audit failure happens inside `core.partitioner.repartition`).
        Raises ``KeyError`` for an unknown key."""
        from repro.core.hypergraph import GraphDelta, apply_delta, \
            check_fits_caps
        st = self._warm_state[key]  # KeyError -> unknown key
        if isinstance(deltas, GraphDelta):
            deltas = [deltas]
        for dl in (deltas or []):
            apply_delta(st.hg, dl)
            if st.cache.caps is not None:
                st.cache.d = None  # host arrays changed; rebuild lazily
                try:
                    check_fits_caps(st.hg, st.cache.caps)
                except CapacityError:
                    st.cache.invalidate()
        rid = self._next_rid
        self._next_rid += 1
        req = _Request(rid=rid, hg=st.hg, omega=st.omega, delta=st.delta,
                       caps_exact=None, bucket_i=None,
                       order=self._next_order,
                       submitted_at=time.monotonic(), warm_key=key)
        self._next_order += 1
        self.registry.counter("repartition.submitted")
        self._enqueue(req)
        return rid

    def _enqueue(self, req: _Request) -> None:
        # every (re-)enqueue restarts the wait clock: a requeued request's
        # queue_wait_s therefore includes its re-queue time (the first
        # attempt's wait was folded in when that attempt started)
        req.enqueued_at = time.monotonic()
        if req.warm_key is not None and not req.warm_key_cold:
            self._warm.append(req)
        elif req.bucket_i is None:
            self._routed.append(req)
        else:
            self._backlogs.setdefault(req.bucket_i, collections.deque()
                                      ).append(req)
        self.registry.gauge("service.pending", self.pending)

    @property
    def pending(self) -> int:
        return (len(self._routed) + len(self._warm)
                + sum(map(len, self._backlogs.values())))

    def step(self) -> list[int]:
        """Run one device solve for the oldest pending work: a stacked
        bucket batch (up to `batch_slots` requests sharing one bucket), one
        routed V-cycle, or one warm repartition. Returns the rids finished
        this step."""
        lanes: list[tuple[int, object]] = [
            (dq[0].order, i) for i, dq in self._backlogs.items() if dq]
        if self._routed:
            lanes.append((self._routed[0].order, None))
        if self._warm:
            lanes.append((self._warm[0].order, "warm"))
        if not lanes:
            return []
        _, pick = min(lanes, key=lambda t: t[0])
        if pick == "warm":
            req = self._warm.popleft()
            self.registry.gauge("service.pending", self.pending)
            return self._solve_warm(req)
        if pick is None:
            req = self._routed.popleft()
            self.registry.gauge("service.pending", self.pending)
            return self._solve_routed(req)
        dq = self._backlogs[pick]
        reqs = [dq.popleft() for _ in range(min(self.batch_slots, len(dq)))]
        self.registry.gauge("service.pending", self.pending)
        return self._solve_bucket(pick, reqs)

    def drain(self) -> dict[int, ServiceResult]:
        """`step()` until no work is pending; returns and clears
        {rid: ServiceResult}."""
        while self.pending:
            self.step()
        out, self._results = self._results, {}
        return out

    def close(self) -> None:
        if self._wd is not None:
            self._wd.stop()
            self._wd = None

    # ------------------------------------------------------------- solves
    def _watchdog(self) -> StepWatchdog:
        if self._wd is None:
            self._wd = StepWatchdog(self.deadline_s,
                                    self.stall_log.append,
                                    registry=self.registry)
        return self._wd

    def _attempt(self, route: str, reqs: list[_Request], solve):
        """Shared supervision wrapper: fault hook, watchdog arm, requeue on
        failure/stall with the per-request restart budget. Returns the solve
        output or None when the batch was requeued.

        Queue-wait accounting happens here, at solve start: each request's
        wait clock (restarted by `_enqueue`) is folded into its cumulative
        `queue_wait_s`, so a requeued request's total includes its re-queue
        time. Solve wall-time (failed attempts included) accumulates into
        `solve_s` and the per-attempt latency histogram."""
        wd = self._watchdog()
        step_no = self._solve_no
        self._solve_no += 1
        now = time.monotonic()
        for r in reqs:
            r.queue_wait_s += now - r.enqueued_at
        t0 = time.monotonic()
        try:
            with wd.watch(step_no):
                if self.fault_hook is not None:
                    self.fault_hook(route, reqs)
                out = solve()
                jax.block_until_ready(out)
        except Exception as e:  # noqa: BLE001 — any solve failure restarts
            self._account_solve(route, reqs, time.monotonic() - t0)
            self._requeue_or_raise(route, reqs, e)
            return None
        self._account_solve(route, reqs, time.monotonic() - t0)
        if step_no in wd.fired_steps:
            self.registry.counter("service.stalls", route=route)
            if (self.requeue_on_stall
                    and all(r.restarts < self.max_restarts for r in reqs)):
                # late result may come from a flaky device: discard + retry
                self._requeue_or_raise(route, reqs)
                return None
        return out

    def _account_solve(self, route: str, reqs: list[_Request],
                       elapsed: float) -> None:
        for r in reqs:
            r.solve_s += elapsed
        self.registry.observe("service.solve_latency.s", elapsed,
                              route=route)

    def _requeue_or_raise(self, route: str, reqs: list[_Request],
                          exc: Exception | None = None) -> None:
        """Requeue every request with budget left, then re-raise if any
        exhausted its budget (requeue-first so a budget-spent lane does not
        drop its batchmates' rids)."""
        exhausted = [r.rid for r in reqs if r.restarts >= self.max_restarts]
        for r in reqs:
            if r.restarts >= self.max_restarts:
                continue
            r.restarts += 1
            self.registry.counter("service.requeues", route=route)
            self._enqueue(r)
        if exhausted:
            if exc is not None:
                raise exc
            raise RuntimeError(
                f"restart budget exhausted for rids {exhausted}")

    def _solve_bucket(self, i: int, reqs: list[_Request]) -> list[int]:
        bucket = self.bucket(i)
        r = self.registry
        # occupancy = live lanes / batch width; padding waste = fraction of
        # the node-capacity volume the batch pads away (empty repeat lanes
        # AND within-lane caps slack)
        r.gauge("service.bucket_occupancy", len(reqs) / self.batch_slots,
                bucket=i)
        used = sum(q.hg.n_nodes for q in reqs)
        r.gauge("service.padding_waste",
                1.0 - used / (bucket.caps.n * self.batch_slots), bucket=i)
        with otrace.span("service.stack", bucket=i) as sp:
            lanes = reqs + [reqs[0]] * (self.batch_slots - len(reqs))
            batch = sp.sync(
                stack_device_batch([q.hg for q in lanes], bucket.caps))
            omega = np.asarray([q.omega for q in lanes], np.int32)
            delta = np.asarray([q.delta for q in lanes], np.int32)
        misses0 = _batch_solver.cache_info().misses
        with otrace.span("service.solve", route="bucket", bucket=i,
                         lanes=len(reqs)):
            out = self._attempt("bucket", reqs,
                                lambda: partition_batch_device(
                                    batch, omega, delta, bucket.caps,
                                    bucket.kcap, n_cands=self.n_cands,
                                    theta=self.theta,
                                    max_levels=bucket.max_levels,
                                    chain_rounds=self.chain_rounds))
        missed = _batch_solver.cache_info().misses > misses0
        r.counter("service.jit_cache", bucket=i,
                  result="miss" if missed else "hit")
        if out is None:
            return []
        r.counter("service.solves", route="bucket")
        finished = []
        with otrace.span("service.audit", bucket=i):
            host = {k: np.asarray(v) for k, v in out.items()}
            for lane, req in enumerate(reqs):
                try:
                    # defense-in-depth recheck of the placement audit (the
                    # level-0 host audit + pair monotonicity already bound
                    # these)
                    check_expansion_caps(bucket.caps,
                                         host["pairs_live_max"][lane],
                                         host["nbr_entries_max"][lane])
                except CapacityError:
                    req.bumps += 1
                    r.counter("service.bumps")
                    req.bucket_i = self._place(req.hg, req.caps_exact,
                                               min_bucket=i + 1)
                    self._enqueue(req)
                    continue
                parts = host["parts"][lane][: req.hg.n_nodes] \
                    .astype(np.int64)
                uniq, parts = np.unique(parts, return_inverse=True)
                aud = metrics.audit(req.hg, parts, omega=req.omega,
                                    delta=req.delta)
                r.observe("service.queue_wait.s", req.queue_wait_s,
                          route="bucket")
                if req.warm_key is not None:
                    self._seed_warm(req, parts)
                self._results[req.rid] = ServiceResult(
                    rid=req.rid, parts=parts, n_parts=len(uniq),
                    n_levels=int(host["n_levels"][lane]),
                    connectivity=aud["connectivity"], cut_net=aud["cut_net"],
                    audit=aud, route="bucket", bucket=bucket,
                    restarts=req.restarts, bumps=req.bumps,
                    queue_wait_s=req.queue_wait_s, solve_s=req.solve_s)
                finished.append(req.rid)
        return finished

    def _solve_routed(self, req: _Request) -> list[int]:
        route = self._routed_route()
        kwargs = dict(theta=self.theta, n_cands=self.n_cands,
                      chain_rounds=self.chain_rounds,
                      collect_stats=self.collect_stats)
        if self.plan is not None:
            kwargs.update(plan=self.plan, shard_graph=self.shard_graph,
                          race=self.race)
        with otrace.span("service.solve", route=route):
            res = self._attempt(route, [req], lambda: partition(
                req.hg, omega=req.omega, delta=req.delta, **kwargs))
        if res is None:
            return []
        self.registry.counter("service.solves", route=route)
        self.registry.observe("service.queue_wait.s", req.queue_wait_s,
                              route=route)
        if req.warm_key is not None:
            self._seed_warm(req, res.parts)
        self._results[req.rid] = ServiceResult(
            rid=req.rid, parts=res.parts, n_parts=res.n_parts,
            n_levels=res.n_levels, connectivity=res.connectivity,
            cut_net=res.cut_net, audit=res.audit, route=route, bucket=None,
            restarts=req.restarts, bumps=req.bumps,
            queue_wait_s=req.queue_wait_s, solve_s=req.solve_s)
        return [req.rid]

    # ------------------------------------------------ warm repartition lane
    def _seed_warm(self, req: _Request, parts: np.ndarray) -> None:
        """Cache the just-delivered solution of a keyed request so
        `resubmit` can warm-start from it."""
        self._warm_state[req.warm_key] = _WarmState(
            hg=req.hg, omega=req.omega, delta=req.delta,
            parts=np.asarray(parts, np.int64).copy(), cache=WarmCache())

    def _solve_warm(self, req: _Request) -> list[int]:
        """One warm repartition solve: refine-only from the cached parts
        (deltas were already applied at `resubmit` time), with
        `core.partitioner.repartition` handling the drift / audit cold
        fallbacks internally. Same watchdog + requeue supervision as the
        other lanes."""
        st = self._warm_state[req.warm_key]
        kwargs = dict(theta=self.theta, n_cands=self.n_cands,
                      chain_rounds=self.chain_rounds,
                      collect_stats=self.collect_stats,
                      drift_threshold=self.drift_threshold)
        if self.plan is not None:
            kwargs.update(plan=self.plan, shard_graph=self.shard_graph,
                          race=self.race)
        t0 = time.monotonic()
        with otrace.span("service.solve", route="warm"):
            res = self._attempt("warm", [req], lambda: repartition(
                st.hg, st.parts, st.omega, st.delta, deltas=None,
                cache=st.cache, **kwargs))
        if res is None:
            return []
        r = self.registry
        r.counter("service.solves", route="warm")
        r.counter("repartition.solves", mode=res.mode)
        r.observe("repartition.solve_latency.s", time.monotonic() - t0)
        r.observe("service.queue_wait.s", req.queue_wait_s, route="warm")
        st.parts = np.asarray(res.parts, np.int64).copy()
        self._results[req.rid] = ServiceResult(
            rid=req.rid, parts=res.parts, n_parts=res.n_parts,
            n_levels=res.n_levels, connectivity=res.connectivity,
            cut_net=res.cut_net, audit=res.audit, route="warm", bucket=None,
            restarts=req.restarts, bumps=req.bumps,
            queue_wait_s=req.queue_wait_s, solve_s=req.solve_s)
        return [req.rid]
