"""Multi-tenant partition service: slot-scheduled batched partition solves.

The paper's workload shape at production scale is not one giant hypergraph —
it is a flood of small-to-medium partition requests (placement queries,
circuit blocks, MoE cells), each carrying its own (Omega, Delta)
constraints. `PartitionService` schedules that flood the same way
`ServeEngine` schedules decode requests: `submit()` queues a request,
`step()` admits queued work and runs one device solve, `drain()` loops to
completion and delivers `{rid: ServiceResult}`.

Scheduling policy (three lanes):

* **Capacity buckets** — small/medium graphs are padded into a geometric
  ladder of static `Caps` buckets (the PR-5 capacity machinery gives the
  static shapes; `check_expansion_caps` audits placement, and a
  `CapacityError` *bumps the request to the next bucket*). Requests sharing
  a bucket are stacked and solved as ONE vmapped device batch
  (`core.partitioner.partition_batch_device`) — per-request Omega/Delta are
  traced vectors, so every batch a bucket ever sees shares a single jit
  cache entry keyed on the bucket signature.
* **Routed V-cycle** — graphs above `route_threshold` nodes (or too big for
  any bucket) route to the existing host-driven multilevel solve
  (`core.partitioner.partition`), mesh-sharded when the service holds a
  `Plan` (`plan=`, `shard_graph=True` — the PR-5 memory-sharded storage).
* **Supervision** — every blocking device solve is armed with
  `dist.ft.StepWatchdog` (`with wd.watch(step):`). A solve that raises, is
  killed by fault injection, or stalls past the deadline is *requeued* with
  a per-request restart budget (`max_restarts`), so no submitted rid is
  ever lost; the budget exhausting re-raises, mirroring `TrainSupervisor`.

Results are delivered as `ServiceResult` (compacted parts + the same
host-side `metrics.audit` the offline driver reports), so a bucket-solved
request is indistinguishable from a solo `partition()` call to the caller.
"""
from __future__ import annotations

import collections
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics
from repro.core.hypergraph import (Caps, CapacityError, DeviceHypergraph,
                                   HostHypergraph, check_expansion_caps,
                                   host_pair_count, packed_host_arrays)
from repro.core.partitioner import partition, partition_batch_device
from repro.dist.ft import StepWatchdog


def stack_device_batch(hgs: list[HostHypergraph], caps: Caps
                       ) -> DeviceHypergraph:
    """Stack capacity-padded staging arrays of ``hgs`` into one device batch
    (every `DeviceHypergraph` leaf gains a leading batch axis) — the input
    shape `partition_batch_device` vmaps over."""
    packed = [packed_host_arrays(hg, caps) for hg in hgs]
    stacked = {k: jnp.asarray(np.stack([p[k] for p in packed]))
               for k in packed[0]}
    return DeviceHypergraph(**stacked)


@dataclasses.dataclass(frozen=True)
class Bucket:
    """Static solve signature: caps + partition-axis capacity + unrolled
    level bound. One jit cache entry per distinct Bucket."""
    caps: Caps
    kcap: int
    max_levels: int


@dataclasses.dataclass
class ServiceResult:
    rid: int
    parts: np.ndarray          # [n_nodes] compacted partition ids
    n_parts: int
    n_levels: int
    connectivity: float
    cut_net: float
    audit: dict
    route: str                 # "bucket" | "vcycle" | "vcycle-sharded"
    bucket: Bucket | None      # the solving bucket (bucket route only)
    restarts: int              # failed/stalled solves this request survived
    bumps: int                 # capacity bumps to a bigger bucket


@dataclasses.dataclass
class _Request:
    rid: int
    hg: HostHypergraph
    omega: int
    delta: int
    caps_exact: Caps | None    # None on the routed lane
    bucket_i: int | None       # ladder index; None -> routed V-cycle
    order: int                 # FIFO tie-break across lanes
    restarts: int = 0
    bumps: int = 0


class PartitionService:
    """See module docstring. Construction is cheap; device work happens in
    `step()`/`drain()`.

    Parameters
    ----------
    theta, n_cands, chain_rounds : solver params shared by every request
        (they are part of the static bucket signature).
    batch_slots : device-batch width per bucket solve; short batches pad by
        repeating lane 0 (discarded), so B is static per bucket.
    bucket_base : node capacity of the smallest bucket; ladder doubles up to
        `route_threshold`.
    route_threshold : graphs with more nodes (or that fit no bucket) take
        the host-driven V-cycle, mesh-sharded when `plan` is set.
    plan, shard_graph, race : forwarded to the routed `partition()` call.
    deadline_s : `StepWatchdog` deadline per device solve.
    max_restarts : per-request budget of failed/stalled solves before the
        failure re-raises.
    requeue_on_stall : a stalled-but-completed solve is discarded and
        requeued while budget remains (the completed result may come from a
        flaky device); with the budget spent the late result is accepted.
    fault_hook : test-only injection point, called as ``hook(route, reqs)``
        immediately before each device solve; a raise is treated exactly
        like a solve failure.
    """

    def __init__(self, theta: int = 16, n_cands: int = 4,
                 chain_rounds: int = 16, batch_slots: int = 4,
                 bucket_base: int = 64, route_threshold: int = 2048,
                 plan=None, shard_graph: bool = True, race: bool = True,
                 deadline_s: float = 300.0, max_restarts: int = 3,
                 requeue_on_stall: bool = True, fault_hook=None):
        if batch_slots < 1:
            raise ValueError(f"batch_slots must be >= 1, got {batch_slots}")
        if bucket_base < 2:
            raise ValueError(f"bucket_base must be >= 2, got {bucket_base}")
        self.theta = theta
        self.n_cands = n_cands
        self.chain_rounds = chain_rounds
        self.batch_slots = batch_slots
        self.bucket_base = 1 << max(1, math.ceil(math.log2(bucket_base)))
        self.route_threshold = route_threshold
        self.plan = plan
        self.shard_graph = shard_graph
        self.race = race
        self.deadline_s = deadline_s
        self.max_restarts = max_restarts
        self.requeue_on_stall = requeue_on_stall
        self.fault_hook = fault_hook
        # ladder indices 0..n_buckets-1; smallest bucket >= route_threshold
        # closes the ladder (a graph may need its caps even with few nodes)
        self.n_buckets = 1
        while (self.bucket_base << (self.n_buckets - 1)) < route_threshold:
            self.n_buckets += 1
        self._backlogs: dict[int, collections.deque] = {}
        self._routed: collections.deque = collections.deque()
        self._results: dict[int, ServiceResult] = {}
        self._next_rid = 0
        self._next_order = 0
        self._solve_no = 0
        self._wd: StepWatchdog | None = None
        self.stall_log: list[int] = []
        self.stats = dict(batch_solves=0, routed_solves=0, restarts=0,
                          stalls=0, bumps=0)

    # ------------------------------------------------------------- buckets
    def bucket(self, i: int) -> Bucket:
        """Ladder bucket i: node cap `bucket_base << i`, companion caps by
        fixed multipliers (pairs 16x nodes — dense graphs overflow this and
        bump up the ladder via the placement audit). The multipliers are
        deliberately tight: every level of the device scan computes at full
        bucket caps, so padding slack is paid `max_levels` times over and a
        ladder bump is cheaper than a fat bucket. The kernel tile fields
        (d_max/h0/l0/u0) are zeroed: `vcycle_device` never dispatches the
        Pallas kernels, and zeroing keeps the signature request-independent."""
        n = self.bucket_base << i
        caps = Caps(n=n, e=n, p=4 * n, pairs=16 * n, nbrs=16 * n)
        return Bucket(caps=caps, kcap=n, max_levels=int(math.log2(n)) + 1)

    def _place(self, hg: HostHypergraph, caps_exact: Caps,
               min_bucket: int = 0) -> int | None:
        """Smallest ladder bucket that fits, or None -> routed V-cycle.
        `check_expansion_caps` is the placement audit: a `CapacityError`
        (pair expansion over the bucket's cap) bumps to the next bucket."""
        if hg.n_nodes > self.route_threshold:
            return None
        pair_need = host_pair_count(hg)
        for i in range(min_bucket, self.n_buckets):
            c = self.bucket(i).caps
            if caps_exact.n > c.n or caps_exact.e > c.e or caps_exact.p > c.p:
                continue
            try:
                check_expansion_caps(c, pair_need)
            except CapacityError:
                continue  # audit failure: bump to the next bucket
            return i
        return None

    # ----------------------------------------------------- slot scheduler
    def submit(self, hg: HostHypergraph, omega: int, delta: int) -> int:
        """Queue one partition request; returns a request id whose
        `ServiceResult` `step()`/`drain()` eventually deliver."""
        if hg.n_nodes < 1:
            raise ValueError("empty hypergraph")
        rid = self._next_rid
        self._next_rid += 1
        routed = hg.n_nodes > self.route_threshold
        caps_exact = None if routed else Caps.for_host(hg)
        bucket_i = None if routed else self._place(hg, caps_exact)
        req = _Request(rid=rid, hg=hg, omega=int(omega), delta=int(delta),
                       caps_exact=caps_exact, bucket_i=bucket_i,
                       order=self._next_order)
        self._next_order += 1
        self._enqueue(req)
        return rid

    def _enqueue(self, req: _Request) -> None:
        if req.bucket_i is None:
            self._routed.append(req)
        else:
            self._backlogs.setdefault(req.bucket_i, collections.deque()
                                      ).append(req)

    @property
    def pending(self) -> int:
        return len(self._routed) + sum(map(len, self._backlogs.values()))

    def step(self) -> list[int]:
        """Run one device solve for the oldest pending work: a stacked
        bucket batch (up to `batch_slots` requests sharing one bucket) or
        one routed V-cycle. Returns the rids finished this step."""
        lanes: list[tuple[int, object]] = [
            (dq[0].order, i) for i, dq in self._backlogs.items() if dq]
        if self._routed:
            lanes.append((self._routed[0].order, None))
        if not lanes:
            return []
        _, pick = min(lanes)
        if pick is None:
            return self._solve_routed(self._routed.popleft())
        dq = self._backlogs[pick]
        reqs = [dq.popleft() for _ in range(min(self.batch_slots, len(dq)))]
        return self._solve_bucket(pick, reqs)

    def drain(self) -> dict[int, ServiceResult]:
        """`step()` until no work is pending; returns and clears
        {rid: ServiceResult}."""
        while self.pending:
            self.step()
        out, self._results = self._results, {}
        return out

    def close(self) -> None:
        if self._wd is not None:
            self._wd.stop()
            self._wd = None

    # ------------------------------------------------------------- solves
    def _watchdog(self) -> StepWatchdog:
        if self._wd is None:
            self._wd = StepWatchdog(self.deadline_s,
                                    self.stall_log.append)
        return self._wd

    def _attempt(self, route: str, reqs: list[_Request], solve):
        """Shared supervision wrapper: fault hook, watchdog arm, requeue on
        failure/stall with the per-request restart budget. Returns the solve
        output or None when the batch was requeued."""
        wd = self._watchdog()
        step_no = self._solve_no
        self._solve_no += 1
        try:
            with wd.watch(step_no):
                if self.fault_hook is not None:
                    self.fault_hook(route, reqs)
                out = solve()
                jax.block_until_ready(out)
        except Exception as e:  # noqa: BLE001 — any solve failure restarts
            self._requeue_or_raise(reqs, e)
            return None
        if step_no in wd.fired_steps:
            self.stats["stalls"] += 1
            if (self.requeue_on_stall
                    and all(r.restarts < self.max_restarts for r in reqs)):
                # late result may come from a flaky device: discard + retry
                self._requeue_or_raise(reqs)
                return None
        return out

    def _requeue_or_raise(self, reqs: list[_Request],
                          exc: Exception | None = None) -> None:
        """Requeue every request with budget left, then re-raise if any
        exhausted its budget (requeue-first so a budget-spent lane does not
        drop its batchmates' rids)."""
        exhausted = [r.rid for r in reqs if r.restarts >= self.max_restarts]
        for r in reqs:
            if r.restarts >= self.max_restarts:
                continue
            r.restarts += 1
            self.stats["restarts"] += 1
            self._enqueue(r)
        if exhausted:
            if exc is not None:
                raise exc
            raise RuntimeError(
                f"restart budget exhausted for rids {exhausted}")

    def _solve_bucket(self, i: int, reqs: list[_Request]) -> list[int]:
        bucket = self.bucket(i)
        lanes = reqs + [reqs[0]] * (self.batch_slots - len(reqs))
        batch = stack_device_batch([r.hg for r in lanes], bucket.caps)
        omega = np.asarray([r.omega for r in lanes], np.int32)
        delta = np.asarray([r.delta for r in lanes], np.int32)
        out = self._attempt("bucket", reqs, lambda: partition_batch_device(
            batch, omega, delta, bucket.caps, bucket.kcap,
            n_cands=self.n_cands, theta=self.theta,
            max_levels=bucket.max_levels, chain_rounds=self.chain_rounds))
        if out is None:
            return []
        self.stats["batch_solves"] += 1
        host = {k: np.asarray(v) for k, v in out.items()}
        finished = []
        for lane, req in enumerate(reqs):
            try:
                # defense-in-depth recheck of the placement audit (the
                # level-0 host audit + pair monotonicity already bound these)
                check_expansion_caps(bucket.caps,
                                     host["pairs_live_max"][lane],
                                     host["nbr_entries_max"][lane])
            except CapacityError:
                req.bumps += 1
                self.stats["bumps"] += 1
                req.bucket_i = self._place(req.hg, req.caps_exact,
                                           min_bucket=i + 1)
                self._enqueue(req)
                continue
            parts = host["parts"][lane][: req.hg.n_nodes].astype(np.int64)
            uniq, parts = np.unique(parts, return_inverse=True)
            aud = metrics.audit(req.hg, parts, omega=req.omega,
                                delta=req.delta)
            self._results[req.rid] = ServiceResult(
                rid=req.rid, parts=parts, n_parts=len(uniq),
                n_levels=int(host["n_levels"][lane]),
                connectivity=aud["connectivity"], cut_net=aud["cut_net"],
                audit=aud, route="bucket", bucket=bucket,
                restarts=req.restarts, bumps=req.bumps)
            finished.append(req.rid)
        return finished

    def _solve_routed(self, req: _Request) -> list[int]:
        route = "vcycle" if self.plan is None else "vcycle-sharded"
        kwargs = dict(theta=self.theta, n_cands=self.n_cands,
                      chain_rounds=self.chain_rounds)
        if self.plan is not None:
            kwargs.update(plan=self.plan, shard_graph=self.shard_graph,
                          race=self.race)
        res = self._attempt(route, [req], lambda: partition(
            req.hg, omega=req.omega, delta=req.delta, **kwargs))
        if res is None:
            return []
        self.stats["routed_solves"] += 1
        self._results[req.rid] = ServiceResult(
            rid=req.rid, parts=res.parts, n_parts=res.n_parts,
            n_levels=res.n_levels, connectivity=res.connectivity,
            cut_net=res.cut_net, audit=res.audit, route=route, bucket=None,
            restarts=req.restarts, bumps=req.bumps)
        return [req.rid]
