"""Batched serving engine: prefill + synchronized batched decode.

Static batching: a batch of requests is padded to a common prompt length,
prefilled once, then decoded lock-step with temperature/greedy sampling and
per-sequence EOS masking. (Per-slot positions / continuous batching would
need per-row cache scatter — noted as future work in DESIGN.md; the
synchronized scheme is what the dry-run decode cells lower.)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import common
from repro.models import transformer as T


@dataclasses.dataclass
class ServeEngine:
    cfg: ArchConfig
    params: object
    cache_len: int
    plan: object | None = None
    temperature: float = 0.0
    eos_id: int = 1
    seed: int = 0

    def __post_init__(self):
        if self.plan is not None:
            # place params per the plan so callers can hand in host arrays;
            # the decode path then runs sharded (seq-sharded KV flash-decode
            # when the plan enables kv_seq)
            self.params = jax.device_put(
                self.params, self.plan.param_shardings(T.lm_shapes(self.cfg)))
        self._prefill = jax.jit(
            lambda p, t, c: T.prefill(p, t, c, self.cfg, self.plan))
        self._decode = jax.jit(
            lambda p, t, pos, c: T.decode_step(p, t, pos, c, self.cfg,
                                               self.plan))

    def generate(self, prompts: np.ndarray, max_new: int = 32,
                 extras: dict | None = None) -> np.ndarray:
        """prompts: [B, S0] int32 (left-aligned, pad with 0 to equal S0).
        Returns generated tokens [B, max_new]."""
        B, S0 = prompts.shape
        assert S0 + max_new <= self.cache_len, "cache too small"
        cspecs = T.cache_shapes(self.cfg, B, self.cache_len)
        zeros = lambda: common.tree_map_specs(
            lambda s: jnp.zeros(s.shape, jnp.float32), cspecs)
        if self.plan is not None:
            # allocate sharded from the start: a replicated-then-reshard
            # cache would peak at full unsharded size per device, exactly
            # what kv_seq sharding exists to avoid
            cache = jax.jit(
                zeros,
                out_shardings=self.plan.param_shardings(cspecs))()
        else:
            cache = zeros()
        kw = {}
        if self.cfg.vision_dim:
            kw["vision"] = jnp.zeros((B, self.cfg.vision_tokens,
                                      self.cfg.vision_dim), jnp.float32)
        if self.cfg.encoder_layers:
            kw["enc_frames"] = jnp.zeros(
                (B, min(self.cfg.max_source_positions, self.cache_len),
                 self.cfg.d_model), jnp.float32)
        if kw:
            logits, cache = jax.jit(
                lambda p, t, c, **k: T.prefill(p, t, c, self.cfg, self.plan,
                                               **k))(self.params,
                                                     jnp.asarray(prompts),
                                                     cache, **kw)
        else:
            logits, cache = self._prefill(self.params, jnp.asarray(prompts),
                                          cache)

        rng = jax.random.PRNGKey(self.seed)
        out = np.zeros((B, max_new), np.int32)
        done = np.zeros((B,), bool)
        pos_off = self.cfg.vision_tokens if self.cfg.vision_dim else 0
        tok = self._sample(logits, rng)
        for i in range(max_new):
            out[:, i] = np.where(done, self.eos_id, np.asarray(tok))
            done |= np.asarray(tok) == self.eos_id
            if done.all():
                break
            rng, sub = jax.random.split(rng)
            logits, cache = self._decode(self.params, tok[:, None],
                                         jnp.int32(S0 + pos_off + i), cache)
            tok = self._sample(logits, sub)
        return out

    def _sample(self, logits, rng):
        if self.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            rng, logits / self.temperature, axis=-1).astype(jnp.int32)
