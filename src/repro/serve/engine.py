"""Continuous-batching serving engine over a paged KV cache.

The engine runs a slot scheduler (`submit()` / `step()` / `drain()`): each
request is prefilled alone (batch-1) and inserted into a free decode slot,
all active slots decode together with *per-slot positions* (a `[n_slots]`
positions vector — no synchronized scalar `pos`), and a slot that finishes
(EOS or its own `max_new`) is freed mid-decode and immediately refilled from
the queue. A straggler therefore never holds other slots hostage, which is
what the static scheme this module used to implement did (one long sequence
pinned the whole batch until `done.all()`).

Per-token KV state lives in a block-table paged pool (`serve/paging.py`):
fixed-size pages + per-slot page tables, so a request reserves pages for its
own `prompt + vision offset + max_new` tokens instead of `cache_len` per
slot, and returns them at EOS. Per-slot constant-size state (SSM conv tails,
recurrent states, encoder output) stays in `[n_slots, ...]` rows. Cache
allocation is plan-aware either way: with a `Plan`, the paged pool stripes
its physical pages over the TP axis ("kv_pages") exactly like the dense
layout seq-shards ("kv_seq"), allocated sharded from the start.

`generate()` is a thin wrapper over the scheduler and keeps the old batched
API; `policy="static"` keeps the synchronized static batch (used as the
benchmark baseline in `benchmarks/serve_engine.py`). Under greedy sampling,
a prompt decoded inside a mixed-length continuous batch is bit-identical to
the same prompt decoded solo (slots are row-independent; MoE capacity
dispatch is the documented exception — its token-drop threshold is batch
global).
"""
from __future__ import annotations

import collections
import dataclasses
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import common
from repro.models import transformer as T
from repro.obs import metrics as obs_metrics
from repro.serve import paging


@dataclasses.dataclass
class _Request:
    rid: int
    prompt: np.ndarray          # [S0] int32
    max_new: int
    tokens: list


@dataclasses.dataclass
class ServeEngine:
    cfg: ArchConfig
    params: object
    cache_len: int
    plan: object | None = None
    temperature: float = 0.0
    eos_id: int = 1
    seed: int = 0
    n_slots: int = 0            # 0 -> sized from the first generate() batch
    page_size: int = 16
    n_pages: int = 0            # 0 -> n_slots * ceil(cache_len / page_size)
    policy: str = "continuous"  # "continuous" | "static"
    admit_lookahead: int = 4    # page-starved queue heads step() may skip
    record_keys: bool = False   # keep (tag, key) of every sample for tests
    registry: obs_metrics.Registry | None = None  # None -> global REGISTRY

    def __post_init__(self):
        if self.policy not in ("continuous", "static"):
            raise ValueError(f"unknown policy {self.policy!r}")
        if self.registry is None:
            self.registry = obs_metrics.REGISTRY
        if self.plan is not None:
            # place params per the plan so callers can hand in host arrays;
            # the decode path then runs sharded (seq-sharded KV flash-decode
            # when the plan enables kv_seq; TP-striped page pool when paged)
            self.params = jax.device_put(
                self.params, self.plan.param_shardings(T.lm_shapes(self.cfg)))
        self._prefill = jax.jit(
            lambda p, t, c, **kw: T.prefill(p, t, c, self.cfg, self.plan,
                                            **kw))
        self._decode = jax.jit(
            lambda p, t, pos, c: T.decode_step(p, t, pos, c, self.cfg,
                                               self.plan),
            donate_argnums=(3,))
        self._decode_paged = jax.jit(
            lambda p, t, pos, c, tbl: T.decode_step(
                p, t, pos, c, self.cfg, self.plan, page_table=tbl,
                page_size=self.page_size),
            donate_argnums=(3,))
        self._rng = jax.random.PRNGKey(self.seed)
        self._keys_used: list = []
        self._queue: collections.deque = collections.deque()
        self._active: dict[int, _Request] = {}
        self._results: dict[int, np.ndarray] = {}
        self._next_rid = 0
        self._cache = None

    # ------------------------------------------------------------ plumbing
    @property
    def _pos_off(self) -> int:
        return self.cfg.vision_tokens if self.cfg.vision_dim else 0

    def _validate(self, prompt_len: int, max_new: int) -> None:
        if max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {max_new}")
        need = prompt_len + self._pos_off + max_new
        if need > self.cache_len:
            raise ValueError(
                f"cache too small: prompt {prompt_len} + vision offset "
                f"{self._pos_off} + max_new {max_new} = {need} > "
                f"cache_len {self.cache_len}")

    def _next_key(self, tag: str):
        self._rng, sub = jax.random.split(self._rng)
        if self.record_keys:
            self._keys_used.append((tag, np.asarray(sub)))
        return sub

    def _sample(self, logits, rng):
        if self.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            rng, logits / self.temperature, axis=-1).astype(jnp.int32)

    def _alloc_cache(self, cspecs):
        zeros = lambda: common.tree_map_specs(
            lambda s: jnp.zeros(s.shape, jnp.float32), cspecs)
        if self.plan is not None:
            # allocate sharded from the start: a replicated-then-reshard
            # cache would peak at full unsharded size per device, exactly
            # what kv_seq / kv_pages sharding exists to avoid
            return jax.jit(
                zeros, out_shardings=self.plan.param_shardings(cspecs))()
        return zeros()

    def _prefill_kwargs(self, batch: int) -> dict:
        kw = {}
        if self.cfg.vision_dim:
            kw["vision"] = jnp.zeros((batch, self.cfg.vision_tokens,
                                      self.cfg.vision_dim), jnp.float32)
        if self.cfg.encoder_layers:
            kw["enc_frames"] = jnp.zeros(
                (batch, min(self.cfg.max_source_positions, self.cache_len),
                 self.cfg.d_model), jnp.float32)
        return kw

    def _ensure(self, n_slots_hint: int = 1) -> None:
        if self._cache is not None:
            return
        if self.n_slots <= 0:
            self.n_slots = max(n_slots_hint, 1)
        pages_per_slot = int(math.ceil(self.cache_len / self.page_size))
        if self.n_pages <= 0:
            self.n_pages = self.n_slots * pages_per_slot
        self._pm = paging.PageManager(
            self.n_slots, pages_per_slot,
            paging.PagingSpec(self.page_size, self.n_pages))
        cspecs = T.cache_shapes(self.cfg, self.n_slots, self.cache_len,
                                page_size=self.page_size,
                                n_pages=self.n_pages)
        self._cache = self._alloc_cache(cspecs)
        self._insert = jax.jit(paging.make_insert(cspecs, self.page_size),
                               donate_argnums=(0,))
        dense1 = T.cache_shapes(self.cfg, 1, self.cache_len)
        self._dense_zeros = jax.jit(lambda: common.tree_map_specs(
            lambda s: jnp.zeros(s.shape, jnp.float32), dense1))
        self._slot_pos = np.zeros((self.n_slots,), np.int32)
        self._slot_tok = np.zeros((self.n_slots,), np.int32)
        self._free_slots = list(range(self.n_slots - 1, -1, -1))

    # ----------------------------------------------------- slot scheduler
    def submit(self, prompt, max_new: int = 32) -> int:
        """Queue one request. prompt: [S0] int32. Returns a request id whose
        tokens `step()`/`drain()` eventually deliver."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        self._validate(len(prompt), max_new)
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(_Request(rid, prompt, int(max_new), []))
        self.registry.counter("engine.submitted")
        return rid

    def _commit(self, slot: int, req: _Request, tok: int,
                finished: list) -> None:
        req.tokens.append(tok)
        if tok == self.eos_id or len(req.tokens) >= req.max_new:
            del self._active[slot]
            self._pm.release(slot)
            self._free_slots = sorted(set(self._free_slots) | {slot},
                                      reverse=True)
            self._results[req.rid] = np.asarray(req.tokens, np.int32)
            finished.append(req.rid)
            self.registry.counter("engine.evicted")
            self.registry.counter(
                "engine.finished",
                reason="eos" if tok == self.eos_id else "max_new")

    def step(self) -> list:
        """Admit queued requests into free slots (prefill + insert), then one
        decode step for every active slot. Returns rids finished this step."""
        self._ensure()
        t0 = time.monotonic()
        finished: list = []
        # admission: prefill-insert into freed slots (MaxText idiom). A
        # page-starved head no longer blocks the whole queue: up to
        # `admit_lookahead` starved heads are skipped so a smaller request
        # behind them can take the free slot (skipped heads keep their
        # queue positions, so admission order stays FIFO among fitters)
        skipped: list = []
        admitted = 0
        while self._queue and self._free_slots:
            req = self._queue.popleft()
            need_tok = len(req.prompt) + self._pos_off + req.max_new
            if not self._pm.can_alloc(need_tok):
                skipped.append(req)
                if len(skipped) > self.admit_lookahead:
                    break  # bounded lookahead: wait for the next EOS
                continue
            admitted += 1
            slot = self._free_slots.pop()
            self._pm.alloc(slot, need_tok)
            dense = self._dense_zeros()
            logits, dense = self._prefill(
                self.params, jnp.asarray(req.prompt[None]), dense,
                **self._prefill_kwargs(1))
            self._cache = self._insert(
                self._cache, dense, jnp.int32(slot),
                jnp.asarray(self._pm.table[slot]))
            tok = int(np.asarray(
                self._sample(logits, self._next_key("prefill")))[0])
            self._slot_pos[slot] = len(req.prompt) + self._pos_off
            self._slot_tok[slot] = tok
            self._active[slot] = req
            self._commit(slot, req, tok, finished)
        for req in reversed(skipped):
            self._queue.appendleft(req)
        if skipped and not self._active and not admitted:
            need_tok = len(skipped[0].prompt) + self._pos_off \
                + skipped[0].max_new
            raise paging.OutOfPagesError(
                f"request needs {self._pm.spec.pages_for(need_tok)} "
                f"pages but the idle pool has {self._pm.free_pages} "
                f"of {self.n_pages}")
        # decode: per-slot positions, paged KV scatter; freed slots' table
        # rows are sentinels, so their lanes are inert
        emitted = admitted
        if self._active:
            emitted += len(self._active)
            logits, self._cache = self._decode_paged(
                self.params, jnp.asarray(self._slot_tok[:, None]),
                jnp.asarray(self._slot_pos), self._cache,
                self._pm.device_table())
            toks = np.asarray(self._sample(logits, self._next_key("decode")))
            for slot, req in list(self._active.items()):
                self._slot_pos[slot] += 1
                tok = int(toks[slot])
                self._slot_tok[slot] = tok
                self._commit(slot, req, tok, finished)
        # step telemetry: _commit/_sample already synced to host above, so
        # the wall-time here is the true step cost, not a dispatch tail
        r = self.registry
        dt = time.monotonic() - t0
        if admitted:
            r.counter("engine.admitted", admitted)
        if emitted:
            r.counter("engine.tokens", emitted)
        r.observe("engine.step.s", dt)
        r.gauge("engine.tokens_per_s", emitted / dt if dt > 0 else 0.0)
        r.gauge("engine.slot_occupancy", len(self._active) / self.n_slots)
        return finished

    def drain(self) -> dict:
        """Run `step()` until queue and slots are empty; returns
        {rid: np.ndarray of generated tokens (EOS included when emitted)}."""
        while self._queue or self._active:
            self.step()
        out, self._results = self._results, {}
        return out

    # ------------------------------------------------------- batched API
    def generate(self, prompts: np.ndarray, max_new: int = 32,
                 extras: dict | None = None,
                 lengths: np.ndarray | None = None) -> np.ndarray:
        """prompts: [B, S0] int32 (left-aligned, pad with 0 to equal S0).
        Returns generated tokens [B, max_new]; positions after a sequence's
        EOS are filled with `eos_id` (never pad-0).

        `lengths` ([B] true prompt lengths) overrides the default pad
        inference (row length = last nonzero + 1) — pass it when pad-0 is a
        legitimate trailing prompt token. On the continuous policy each row
        is submitted at its TRUE length, so a short row pays short-prompt
        positions, prefill, and page budget (the ragged-batch win). The
        static policy still decodes the full padded [B, S0] block — pad-0
        columns count as prompt there — so for ragged batches the two
        policies see different prompts and their outputs are NOT expected to
        match token-for-token; compare policies on equal-length batches.

        generate() reseeds the engine RNG for per-call reproducibility, so
        it refuses to run while streaming `submit()`/`step()` requests are
        in flight (the reseed would silently clobber their sampling
        streams); drain() first. Results of already-finished streaming
        requests are preserved across the call."""
        prompts = np.asarray(prompts, np.int32)
        B, S0 = prompts.shape
        if lengths is None:
            nonpad = prompts != 0
            lengths = np.where(nonpad.any(axis=1),
                               S0 - np.argmax(nonpad[:, ::-1], axis=1), 1)
        lengths = np.asarray(lengths, np.int64).reshape(-1)
        if lengths.shape[0] != B or (B and (lengths.min() < 1
                                            or lengths.max() > S0)):
            raise ValueError(
                f"lengths must be [B={B}] in [1, {S0}], got {lengths}")
        if self._active or self._queue:
            raise RuntimeError(
                f"generate() would reseed the RNG stream of "
                f"{len(self._active)} active + {len(self._queue)} queued "
                f"streaming request(s); drain() them first")
        self._validate(int(lengths.max()) if B else S0, max_new)
        self._rng = jax.random.PRNGKey(self.seed)  # per-call reproducibility
        if self.policy == "static":
            return self._generate_static(prompts, max_new)
        self._ensure(B)
        rids = [self.submit(prompts[i, :lengths[i]], max_new)
                for i in range(B)]
        res = self.drain()
        out = np.full((B, max_new), self.eos_id, np.int32)
        for i, rid in enumerate(rids):
            t = res.pop(rid)
            out[i, :len(t)] = t
        self._results.update(res)  # uncollected streaming results survive
        return out

    def _generate_static(self, prompts: np.ndarray, max_new: int):
        """Synchronized static batch (benchmark baseline): one dense cache
        row per request, lock-step decode until every row is done — a long
        straggler holds all B rows."""
        B, S0 = prompts.shape
        cache = self._alloc_cache(T.cache_shapes(self.cfg, B, self.cache_len))
        kw = self._prefill_kwargs(B)
        logits, cache = self._prefill(self.params, jnp.asarray(prompts),
                                      cache, **kw)
        out = np.zeros((B, max_new), np.int32)
        done = np.zeros((B,), bool)
        pos_off = self._pos_off
        tok = self._sample(logits, self._next_key("prefill"))
        for i in range(max_new):
            out[:, i] = np.where(done, self.eos_id, np.asarray(tok))
            done |= np.asarray(tok) == self.eos_id
            if done.all():
                out[:, i + 1:] = self.eos_id  # consistent post-EOS padding
                break
            logits, cache = self._decode(
                self.params, tok[:, None],
                jnp.full((B,), S0 + pos_off + i, jnp.int32), cache)
            tok = self._sample(logits, self._next_key("decode"))
        return out
