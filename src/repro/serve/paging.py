"""Block-table paged KV layout for the serving engine.

Physical per-token cache storage is a pool of fixed-size pages
([n_pages, page_size, ...] per layer, see `transformer.cache_shapes(
page_size=..., n_pages=...)`); each slot owns a row of a page table mapping
logical page -> physical page (the MaxText `page_manager` / flashinfer
block-table idiom). Heterogeneous sequence lengths then reserve pages
proportional to their own request (prompt + vision offset + max_new) instead
of `cache_len` per slot, and a finished request's pages return to the pool
immediately at EOS.

Host side, `PageManager` is a free-list allocator over the physical pool.
Device side, the sentinel convention makes inactive slots inert without
masking: unallocated table entries hold `n_pages` (one past the pool), so
decode writes through them drop (`mode="drop"` scatter) and gathers clamp to
an arbitrary page whose rows the per-slot length mask then discards.

`make_insert` builds the prefill-insert step (the MaxText
prefill-insert/decode-loop split): a batch-1 *dense* prefill cache is
scattered into the slot's pages (per-token leaves) / slot row (per-slot SSM
and encoder state), driven entirely by the cache `Spec` axes — "kv_pages"
leaves page-scatter, "batch" leaves slot-insert.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import is_spec


class OutOfPagesError(RuntimeError):
    """Raised when a request cannot be admitted even on an idle engine."""


@dataclasses.dataclass(frozen=True)
class PagingSpec:
    page_size: int
    n_pages: int

    def __post_init__(self):
        if self.page_size <= 0 or self.n_pages <= 0:
            raise ValueError(f"invalid paging spec {self}")

    def pages_for(self, n_tokens: int) -> int:
        return int(math.ceil(n_tokens / self.page_size))


class PageManager:
    """Free-list page allocator with per-slot page tables.

    `table` is [n_slots, pages_per_slot] int32; unallocated entries hold the
    sentinel `n_pages`. Allocation is all-at-admission: a request's full
    page budget (prompt + offset + max_new tokens) is claimed up front, so
    decode never needs a mid-flight extend, and `release` returns the whole
    row to the free list (lowest-numbered pages are handed out first, so
    physical reuse is deterministic given the request order)."""

    def __init__(self, n_slots: int, pages_per_slot: int, spec: PagingSpec):
        self.spec = spec
        self.n_slots = n_slots
        self.table = np.full((n_slots, pages_per_slot), spec.n_pages,
                             np.int32)
        self.lengths = np.zeros((n_slots,), np.int32)
        self._free = list(range(spec.n_pages - 1, -1, -1))

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def can_alloc(self, n_tokens: int) -> bool:
        need = self.spec.pages_for(n_tokens)
        return need <= len(self._free) and need <= self.table.shape[1]

    def alloc(self, slot: int, n_tokens: int) -> None:
        need = self.spec.pages_for(n_tokens)
        if need > self.table.shape[1]:
            raise OutOfPagesError(
                f"request needs {need} pages > pages_per_slot "
                f"{self.table.shape[1]} (n_tokens={n_tokens}, "
                f"page_size={self.spec.page_size})")
        if need > len(self._free):
            raise OutOfPagesError(
                f"request needs {need} pages, only {len(self._free)} free "
                f"of {self.spec.n_pages}")
        assert (self.table[slot] == self.spec.n_pages).all(), \
            f"slot {slot} still holds pages"
        for i in range(need):
            self.table[slot, i] = self._free.pop()
        self.lengths[slot] = n_tokens

    def release(self, slot: int) -> None:
        row = self.table[slot]
        freed = sorted(int(p) for p in row if p < self.spec.n_pages)
        # keep the free list sorted descending so .pop() hands out the
        # lowest page first — deterministic physical placement
        self._free = sorted(set(self._free) | set(freed), reverse=True)
        self.table[slot] = self.spec.n_pages
        self.lengths[slot] = 0

    def device_table(self) -> jax.Array:
        return jnp.asarray(self.table)


def _leaf_kind(spec) -> tuple[str, int]:
    """('paged', axis of n_pages) or ('slot', axis of batch) for one cache
    Spec (stacked leaves carry a leading 'layers' axis)."""
    if "kv_pages" in spec.axes:
        return "paged", spec.axes.index("kv_pages")
    if "batch" in spec.axes:
        return "slot", spec.axes.index("batch")
    raise ValueError(f"cache spec with neither kv_pages nor batch: {spec}")


def make_insert(paged_specs, page_size: int):
    """Build `insert(paged_cache, dense_cache, slot, table_row)`: scatter a
    batch-1 dense prefill cache into `slot`'s pages / slot row. jit-able;
    `slot` is a traced scalar, `table_row` a traced [pages_per_slot] row."""
    spec_leaves, treedef = jax.tree_util.tree_flatten(paged_specs,
                                                      is_leaf=is_spec)
    kinds = [_leaf_kind(s) for s in spec_leaves]

    def insert(paged_cache, dense_cache, slot, table_row):
        big_leaves = treedef.flatten_up_to(paged_cache)
        small_leaves = treedef.flatten_up_to(dense_cache)
        out = []
        for big, small, (kind, ax) in zip(big_leaves, small_leaves, kinds):
            if kind == "paged":
                # big [..., NP, ps, tail], small [..., 1, CL, tail]; write
                # logical row p to physical (table[p // ps], p % ps) —
                # rows past the slot's allocated pages hit the sentinel
                # and drop
                cl = small.shape[ax + 1]
                rows = jnp.squeeze(small, axis=ax).astype(big.dtype)
                p = jnp.arange(cl)
                page = table_row[p // page_size]
                idx = (slice(None),) * ax + (page, p % page_size)
                out.append(big.at[idx].set(rows, mode="drop"))
            else:
                start = (0,) * ax + (slot,) + (0,) * (big.ndim - ax - 1)
                out.append(jax.lax.dynamic_update_slice(
                    big, small.astype(big.dtype), start))
        return jax.tree_util.tree_unflatten(treedef, out)

    return insert
