"""Deterministic synthetic LM data pipeline.

Design constraints for 1000+ node fleets:
  * step-indexed determinism — batch(step) is a pure function of
    (seed, step), so a restart at any step replays identical data with no
    state to checkpoint beyond the step counter;
  * shardable — each data-parallel rank can materialize only its slice
    (host-sharded feed) or the full batch (single-controller jit feed);
  * double-buffered prefetch thread for CPU-bound hosts.

The token stream is a order-2 Markov-ish mix over a synthetic vocabulary so
the LM loss actually decreases (pure uniform noise would pin loss at
log V) — enough structure for the end-to-end training example to show
learning without external datasets.
"""
from __future__ import annotations

import dataclasses
import queue
import threading

import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_shards: int = 1
    shard: int = 0

    def batch_at(self, step: int) -> dict:
        """Pure function of (seed, step): tokens/labels [B_shard, S]."""
        b = self.global_batch // self.n_shards
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard]))
        # structured stream: per-sequence bigram tables over a small state
        k = min(257, self.vocab)
        base = rng.integers(0, self.vocab, size=(b, 1), dtype=np.int64)
        steps = rng.integers(1, 7, size=(b, self.seq_len), dtype=np.int64)
        noise = rng.integers(0, self.vocab, size=(b, self.seq_len))
        is_noise = rng.random((b, self.seq_len)) < 0.1
        walk = (base + np.cumsum(steps, axis=1)) % k
        toks = np.where(is_noise, noise, walk).astype(np.int32)
        labels = np.concatenate(
            [toks[:, 1:], np.full((b, 1), -1, np.int32)], axis=1)
        return {"tokens": toks, "labels": labels}


class Prefetcher:
    """Double-buffered background producer of batches."""

    def __init__(self, ds: SyntheticLM, start_step: int = 0, depth: int = 2):
        self.ds = ds
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.step = start_step
        self._stop = threading.Event()
        self.t = threading.Thread(target=self._run, daemon=True)
        self.t.start()

    def _run(self):
        s = self.step
        while not self._stop.is_set():
            try:
                self.q.put((s, self.ds.batch_at(s)), timeout=0.2)
                s += 1
            except queue.Full:
                continue

    def next(self) -> tuple[int, dict]:
        return self.q.get()

    def stop(self):
        self._stop.set()


def make_pipeline(cfg, global_batch: int, seq_len: int, seed: int = 0,
                  n_shards: int = 1, shard: int = 0,
                  start_step: int = 0) -> Prefetcher:
    ds = SyntheticLM(vocab=cfg.vocab, seq_len=seq_len,
                     global_batch=global_batch, seed=seed,
                     n_shards=n_shards, shard=shard)
    return Prefetcher(ds, start_step=start_step)
