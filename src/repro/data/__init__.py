from repro.data.pipeline import SyntheticLM, make_pipeline  # noqa: F401
