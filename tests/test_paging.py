"""Paged KV block-table layout: PageManager allocator, paged scatter/gather
vs the dense cache oracle, and the prefill-insert split."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import common
from repro.models import transformer as T
from repro.serve.paging import (OutOfPagesError, PageManager, PagingSpec,
                                make_insert)


# ------------------------------------------------------------- PageManager
def test_pages_for_rounds_up():
    spec = PagingSpec(page_size=16, n_pages=8)
    assert spec.pages_for(1) == 1
    assert spec.pages_for(16) == 1
    assert spec.pages_for(17) == 2


def test_alloc_release_reuse_is_deterministic():
    pm = PageManager(n_slots=2, pages_per_slot=3, spec=PagingSpec(16, 6))
    pm.alloc(0, 33)  # 3 pages
    assert list(pm.table[0]) == [0, 1, 2]
    assert (pm.table[1] == 6).all()  # sentinel
    pm.alloc(1, 17)  # 2 pages
    assert list(pm.table[1][:2]) == [3, 4]
    assert pm.free_pages == 1
    pm.release(0)
    assert (pm.table[0] == 6).all() and pm.lengths[0] == 0
    assert pm.free_pages == 4
    # lowest pages are handed out first after a release
    pm.alloc(0, 16)
    assert pm.table[0][0] == 0


def test_alloc_raises_out_of_pages():
    pm = PageManager(n_slots=2, pages_per_slot=2, spec=PagingSpec(16, 3))
    pm.alloc(0, 32)
    assert not pm.can_alloc(32)
    with pytest.raises(OutOfPagesError, match="free"):
        pm.alloc(1, 32)
    with pytest.raises(OutOfPagesError, match="pages_per_slot"):
        pm.alloc(1, 48)  # 3 pages > pages_per_slot 2


def test_alloc_into_held_slot_asserts():
    pm = PageManager(n_slots=1, pages_per_slot=4, spec=PagingSpec(16, 4))
    pm.alloc(0, 16)
    with pytest.raises(AssertionError):
        pm.alloc(0, 16)


# -------------------------------------------------- paged update vs dense
def test_paged_update_gather_matches_dense():
    """Per-row paged scatter + page-table gather reproduces the dense
    [B, S, ...] cache in logical order, whatever the physical placement."""
    ps, n_pages, B, P = 4, 9, 3, 3
    cache_len = P * ps
    rng = np.random.default_rng(0)
    # shuffled, non-contiguous physical placement
    perm = rng.permutation(n_pages)[: B * P].reshape(B, P).astype(np.int32)
    table = jnp.asarray(perm)
    paged = jnp.zeros((n_pages + 0, ps, 2, 5))  # no sentinel rows used here
    dense = jnp.zeros((B, cache_len, 2, 5))
    for pos in range(cache_len):
        new = jnp.asarray(rng.normal(size=(B, 1, 2, 5)).astype(np.float32))
        posv = jnp.full((B,), pos, jnp.int32)
        paged = T._paged_update(paged, new, posv, table, ps)
        dense = dense.at[jnp.arange(B), posv].set(new[:, 0])
    np.testing.assert_array_equal(np.asarray(T._paged_gather(paged, table)),
                                  np.asarray(dense))


def test_paged_update_sentinel_drops():
    """Writes routed through sentinel table entries (freed slot) must leave
    the pool untouched."""
    ps, n_pages = 4, 2
    table = jnp.full((1, 2), n_pages, jnp.int32)  # all sentinel
    paged = jnp.ones((n_pages, ps, 3))
    new = jnp.full((1, 1, 3), 7.0)
    out = T._paged_update(paged, new, jnp.zeros((1,), jnp.int32), table, ps)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(paged))


def test_ragged_positions_update_rows_independently():
    ps, n_pages, B = 4, 6, 2
    table = jnp.asarray(np.arange(B * 3, dtype=np.int32).reshape(B, 3))
    paged = jnp.zeros((n_pages, ps, 1))
    posv = jnp.asarray([1, 9], jnp.int32)  # row 0 page 0, row 1 page 2
    new = jnp.asarray([[[1.0]], [[2.0]]])
    out = T._paged_update(paged, new, posv, table, ps)
    g = np.asarray(T._paged_gather(out, table))  # [B, 12, 1]
    assert g[0, 1, 0] == 1.0 and g[1, 9, 0] == 2.0
    assert np.count_nonzero(g) == 2


# ------------------------------------------------------------ make_insert
@pytest.mark.parametrize("name", ["qwen2-1.5b", "xlstm-350m"])
def test_insert_then_gather_matches_dense_prefill(name):
    """Scattering a batch-1 dense prefill cache into a slot's pages must
    reproduce that cache under a page-table gather; per-slot leaves must
    land in the slot row."""
    cfg = get_config(name).smoke()
    cache_len, ps, n_slots = 24, 8, 2
    pps = cache_len // ps
    pspecs = T.cache_shapes(cfg, n_slots, cache_len, page_size=ps,
                            n_pages=n_slots * pps)
    dspecs = T.cache_shapes(cfg, 1, cache_len)
    rng = np.random.default_rng(1)
    rand = lambda tree: jax.tree_util.tree_map(
        lambda s: jnp.asarray(rng.normal(size=s.shape).astype(np.float32)),
        tree, is_leaf=common.is_spec)
    dense = rand(dspecs)  # cache specs init to zeros; want real content
    paged = jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, jnp.float32),
        pspecs, is_leaf=common.is_spec)
    pm = PageManager(n_slots, pps, PagingSpec(ps, n_slots * pps))
    slot = 1
    pm.alloc(slot, cache_len)
    insert = jax.jit(make_insert(pspecs, ps))
    paged = insert(paged, dense, jnp.int32(slot),
                   jnp.asarray(pm.table[slot]))
    flat_p, _ = jax.tree_util.tree_flatten(paged)
    flat_d, _ = jax.tree_util.tree_flatten(dense)
    flat_s, _ = jax.tree_util.tree_flatten(pspecs, is_leaf=common.is_spec)
    checked_paged = checked_slot = 0
    for big, small, spec in zip(flat_p, flat_d, flat_s):
        big, small = np.asarray(big), np.asarray(small)
        if "kv_pages" in spec.axes:
            # paged leaf: [.., n_pages, page_size, ..] at ax replaces the
            # dense [.., 1, cache_len, ..]; check every logical row
            ax = spec.axes.index("kv_pages")
            for p in range(cache_len):
                phys = int(pm.table[slot, p // ps])
                got = np.take(np.take(big, phys, axis=ax), p % ps, axis=ax)
                want = np.take(np.take(small, 0, axis=ax), p, axis=ax)
                np.testing.assert_array_equal(got, want)
            checked_paged += 1
        else:
            ax = spec.axes.index("batch")
            np.testing.assert_array_equal(np.take(big, slot, axis=ax),
                                          np.take(small, 0, axis=ax))
            checked_slot += 1
    if name == "qwen2-1.5b":
        assert checked_paged > 0  # attention KV leaves page-scatter
    else:
        assert checked_slot > 0  # SSM state leaves slot-insert
