"""Sharding plan resolution + an actual multi-device sharded train step
(subprocess with 8 forced host devices so the main test session keeps its
single-device view)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "src")


def test_pspec_resolution_rules():
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.dist.sharding import Plan
    from repro.models.common import Spec, _resolve_pspec
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    plan = Plan.make(mesh)
    r = plan.rules
    # duplicate mesh axis -> later dim replicated
    s = Spec((16, 64, 32), ("experts", "embed", "mlp"))
    ps = _resolve_pspec(s, r, mesh)
    assert ps[0] == "model" and ps[1] in ("data", ("data",))
    assert len(ps) == 2 or ps[2] is None
    # non-divisible dim replicates (needs a >1 axis): fake with rules
    mesh2 = jax.make_mesh((1, 1), ("data", "model"))
    s2 = Spec((7,), ("heads",))
    ps2 = _resolve_pspec(s2, r, mesh2)  # 7 % 1 == 0 -> sharded trivially
    assert ps2 == P("model")


_MULTIDEV = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.dist.sharding import Plan
    from repro.launch.steps import make_train_step, batch_specs
    from repro.models import common, transformer as T
    from repro.train import optimizer as opt

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    plan = Plan.make(mesh)
    cfg = get_config("qwen2-1.5b").smoke()
    pspecs = T.lm_shapes(cfg)
    params = common.materialize(pspecs, jax.random.PRNGKey(0))
    state = opt.init_state(params)
    sspec = opt.state_shapes(pspecs)
    state_sh = opt.TrainState(
        params=plan.param_shardings(sspec.params),
        master=plan.param_shardings(sspec.master),
        mu=plan.param_shardings(sspec.mu),
        nu=plan.param_shardings(sspec.nu),
        step=plan.sharding())
    state = jax.device_put(state, state_sh)
    batch = {"tokens": jnp.ones((8, 32), jnp.int32),
             "labels": jnp.ones((8, 32), jnp.int32)}
    batch = jax.device_put(batch, {k: plan.sharding("batch", None)
                                   for k in batch})
    step = jax.jit(make_train_step(cfg, plan), donate_argnums=(0,))
    # sharded result must equal the single-device result
    state2, m = step(state, batch)
    params1 = common.materialize(pspecs, jax.random.PRNGKey(0))
    s1 = opt.init_state(params1)
    _, m1 = jax.jit(make_train_step(cfg, None))(s1, batch)
    a, b = float(m["loss"]), float(m1["loss"])
    assert abs(a - b) < 5e-3, (a, b)
    print("SHARDED_OK", a, b)
""")


@pytest.mark.slow
def test_sharded_train_step_matches_single_device(tmp_path):
    script = tmp_path / "multidev.py"
    script.write_text(_MULTIDEV)
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, str(script)], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "SHARDED_OK" in r.stdout
