"""Hypothesis property tests on system invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed (declared in [test] extras; "
           "pip install hypothesis)")
from hypothesis import HealthCheck, given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import generate, metrics
from repro.core import hypergraph as H
from repro.core import refine as R
from repro.core.coarsen import CoarsenParams, coarsen_step
from repro.core.contract import contract
from repro.core.matching import match_pseudoforest
from repro.utils import segops

from test_matching import brute_force, matched_value, proposal_graph

SET = settings(max_examples=12, deadline=None,
               suppress_health_check=[HealthCheck.too_slow])

IMAX = 2**31 - 1


@given(n=st.integers(8, 40), e=st.integers(5, 40), k=st.integers(2, 6),
       seed=st.integers(0, 1000))
@SET
def test_pair_expansion_complete_and_exact(n, e, k, seed):
    hg = generate.random_kuniform(n, e, min(k, n), seed=seed)
    caps = H.Caps.for_host(hg)
    d = H.device_from_host(hg, caps)
    pairs = H.build_pairs(d, caps)
    got = set()
    pe, pn, pm, pv = map(np.asarray, (pairs.edge, pairs.n, pairs.m,
                                      pairs.valid))
    for i in range(len(pv)):
        if pv[i]:
            got.add((int(pe[i]), int(pn[i]), int(pm[i])))
    exp = set()
    for ei in range(hg.n_edges):
        pins = hg.edge(ei)
        for a in pins:
            for b in pins:
                if a != b:
                    exp.add((ei, int(a), int(b)))
    assert got == exp


@given(n=st.integers(12, 60), fanout=st.integers(3, 8),
       omega=st.integers(2, 12), seed=st.integers(0, 100))
@SET
def test_one_coarsen_level_always_valid(n, fanout, omega, seed):
    hg = generate.snn_smallworld(n_nodes=n, fanout=fanout, seed=seed)
    # feasibility precondition (paper Sec. II-B assumes a valid solution
    # exists): Delta must cover the largest single-node inbound set
    _, _, _, node_nin = hg.incidence()
    delta = max(2 * fanout, 8, int(node_nin.max(initial=0)))
    caps = H.Caps.for_host(hg)
    d = H.device_from_host(hg, caps)
    match, n_pairs, _ = coarsen_step(
        d, caps, CoarsenParams(omega=omega, delta=delta, n_cands=2))
    m = np.asarray(match)[:n]
    # matching is an involution on matched nodes
    for a in range(n):
        if m[a] >= 0:
            assert m[m[a]] == a and m[a] != a
    d2, gamma = contract(d, match, caps)
    g = np.asarray(gamma)[:n]
    sizes, inbound = metrics.partition_loads(hg, g)
    assert (sizes <= omega).all()
    assert (inbound <= delta).all()
    # gamma is a surjection onto [0, n_new)
    assert set(g.tolist()) == set(range(int(d2.n_nodes)))


@given(vals=st.lists(st.floats(-100, 100, width=32), min_size=2,
                     max_size=50),
       seed=st.integers(0, 100))
@SET
def test_segmented_scan_property(vals, seed):
    rng = np.random.default_rng(seed)
    v = np.asarray(vals, np.float32)
    starts = rng.random(len(v)) < 0.3
    starts[0] = True
    out = np.asarray(segops.segmented_scan(jnp.asarray(v),
                                           jnp.asarray(starts)))
    i0 = 0
    for i in range(len(v)):
        if starts[i]:
            i0 = i
        np.testing.assert_allclose(out[i], v[i0:i + 1].sum(), rtol=1e-4,
                                   atol=1e-4)


@given(n=st.integers(10, 40), e=st.integers(8, 50), k=st.integers(2, 4),
       kparts=st.integers(2, 6), seed=st.integers(0, 1000),
       rank_seed=st.integers(0, 3))
@SET
def test_build_sequence_properties(n, e, k, kparts, seed, rank_seed):
    """`build_sequence` invariants, for the identity and arbitrary tie-break
    permutations (replica racing uses the latter):
      * mover `seq` values form a contiguous permutation 0..n_movers-1
      * non-movers (and capacity padding) sit at IMAX
      * the post-cut `pred` relation is acyclic, and within a chain
        `seq[pred[x]] == seq[x] - 1`."""
    rng = np.random.default_rng(seed)
    hg = generate.random_kuniform(n, e, min(k, n), seed=seed, weighted=True)
    caps = H.Caps.for_host(hg)
    d = H.device_from_host(hg, caps)
    kcap = 8
    parts0 = rng.integers(0, kparts, size=hg.n_nodes).astype(np.int32)
    parts = jnp.asarray(np.pad(parts0, (0, caps.n - hg.n_nodes)))
    params = R.RefineParams(omega=max(3, n // 2), delta=4 * e)
    pins, _ = R.pins_matrix(d, parts, caps, kcap)
    move_to, gain_iso, _, _ = R.propose_moves(
        d, parts, pins, caps, kcap, params, jnp.asarray(False),
        jnp.int32(kparts))
    tie_rank = None
    if rank_seed > 0:
        tie_rank = jnp.asarray(np.random.default_rng(rank_seed)
                               .permutation(caps.n).astype(np.int32))
    seq, n_movers, aux = R.build_sequence(
        d, parts, move_to, gain_iso, caps, kcap, params,
        tie_rank=tie_rank, with_aux=True)
    mv = np.asarray(move_to)[: hg.n_nodes]
    sq = np.asarray(seq)
    nm = int(n_movers)
    assert sorted(sq[: hg.n_nodes][mv >= 0].tolist()) == list(range(nm))
    assert (sq[: hg.n_nodes][mv < 0] == IMAX).all()
    assert (sq[hg.n_nodes:] == IMAX).all()
    pred = np.asarray(aux["pred"])
    for x in range(caps.n):
        p, steps = x, 0
        while pred[p] >= 0:
            p = pred[p]
            steps += 1
            assert steps <= caps.n, "pred cycle survived cutting"
    for x in range(hg.n_nodes):
        if mv[x] >= 0 and pred[x] >= 0:
            assert sq[pred[x]] == sq[x] - 1


@given(n=st.integers(3, 9), seed=st.integers(0, 10_000))
@SET
def test_matching_total_equals_bruteforce_dp(n, seed):
    """On invariant-respecting round-1 proposal graphs (symmetric eta,
    larger-id tie-break), `match_pseudoforest`'s matched total equals the
    exact max-weight matching (brute-force over edge subsets), and the
    matching only uses proposed edges mutually."""
    rng = np.random.default_rng(seed)
    target, score = proposal_graph(rng, n)
    m = np.asarray(match_pseudoforest(
        jnp.asarray(target), jnp.asarray(score), jnp.ones(n, bool)))
    for a in range(n):
        if m[a] >= 0:
            assert m[m[a]] == a and m[a] != a
            assert target[a] == m[a] or target[m[a]] == a
    assert abs(matched_value(target, score, m)
               - brute_force(target, score)) < 1e-5


@given(n=st.integers(2, 40), seed=st.integers(0, 10_000),
       p_dead=st.floats(0.0, 0.6))
@SET
def test_matching_mutual_and_live_on_functional_graphs(n, seed, p_dead):
    """On arbitrary functional graphs (broken invariants, long cycles) the
    output is always a mutual involution and never pairs dead
    (`live=False`) nodes."""
    rng = np.random.default_rng(seed)
    target = rng.integers(-1, n, size=n).astype(np.int32)
    target[target == np.arange(n)] = -1
    score = (rng.random(n) * 10).astype(np.float32)
    live = rng.random(n) >= p_dead
    m = np.asarray(match_pseudoforest(
        jnp.asarray(target), jnp.asarray(score), jnp.asarray(live)))
    for a in range(n):
        if m[a] >= 0:
            assert m[m[a]] == a and m[a] != a
            assert live[a] and live[m[a]]
            assert target[a] == m[a] or target[m[a]] == a
    assert (m[~live] == -1).all()


@given(seed=st.integers(0, 50), k=st.integers(2, 5))
@SET
def test_connectivity_lower_bound_cutnet(seed, k):
    """Conn >= cut-net always; equal iff every cut edge spans 2 parts."""
    rng = np.random.default_rng(seed)
    hg = generate.random_kuniform(24, 30, 4, seed=seed, weighted=True)
    parts = rng.integers(0, k, size=hg.n_nodes)
    assert metrics.connectivity(hg, parts) >= metrics.cut_net(hg, parts) - 1e-6


@given(n_per=st.integers(4, 48), hi1=st.integers(1, 8), hi2=st.integers(1, 6),
       seed=st.integers(0, 1000))
@SET
def test_dist_sort_stable_and_matches_lexsort(n_per, hi1, hi2, seed):
    """`ShardCtx.sort_by` on duplicate-heavy random multi-key columns is
    the stable lexicographic sort: field-by-field equal to the numpy
    lexsort oracle, and equal keys preserve payload order (the threaded
    global-rank tie key). Runs on however many devices the session sees —
    1 locally (degenerate path), 8 in CI's forced-fan-out step (the real
    exchange)."""
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.models import common

    n_dev = len(jax.devices())
    n = n_per * n_dev
    rng = np.random.default_rng(seed)
    k1 = rng.integers(0, hi1, n).astype(np.int32)
    k2 = rng.integers(0, hi2, n).astype(np.int32)
    pay = np.arange(n, dtype=np.int32)

    mesh = jax.make_mesh((n_dev,), ("model",))
    ctx = segops.ShardCtx(axis="model", nshards=n_dev)

    def body(a, b, p):
        (s1, s2), (sp,) = ctx.sort_by([a, b], [p])
        return s1, s2, sp

    f = jax.jit(common.shard_map(body, mesh=mesh, in_specs=(P(), P(), P()),
                                 out_specs=(P(), P(), P())))
    s1, s2, sp = map(np.asarray, f(jnp.asarray(k1), jnp.asarray(k2),
                                   jnp.asarray(pay)))
    order = np.lexsort((pay, k2, k1))
    np.testing.assert_array_equal(s1, k1[order])
    np.testing.assert_array_equal(s2, k2[order])
    np.testing.assert_array_equal(sp, pay[order])  # stability: pay == rank
