"""Stripe-local Pallas kernel dispatch (`repro.kernels` + the sharded
hot-loop wrappers) — boundary behaviour, mutation sensitivity, and
kernel-vs-segment parity under `shard_map`.

Fast tests run everywhere; the 8-device variants mirror
tests/test_dist_partition.py: a subprocess forces 8 host devices, and the
in-process variant picks up CI's forced-fan-out step (XLA_FLAGS already set
before jax import)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "src")


def test_interpret_policy_env_override(monkeypatch):
    """`pallas_interpret` compiles on accelerators, interprets on host, and
    REPRO_PALLAS_INTERPRET=1 forces interpret everywhere; =0 stays a no-op
    on CPU (no compiled Pallas path exists there)."""
    import jax
    from repro.kernels import pallas_interpret

    on_host = jax.default_backend() not in ("tpu", "gpu", "cuda", "rocm")
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    assert pallas_interpret() is on_host
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert pallas_interpret() is True
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert pallas_interpret() is on_host  # CPU degrades to interpret


def _pair_setup(k_pins):
    """One k-uniform edge: every pin sees exactly k_pins - 1 unique
    neighbors and k_pins - 1 traversal entries — count == bound when
    k_pins - 1 == 128 with u0 = l0 = 1 (tile bounds round up to 128)."""
    import dataclasses
    from repro.core import generate
    from repro.core import hypergraph as H

    hg = generate.random_kuniform(200, 1, k_pins, seed=7, n_src=2,
                                  weighted=True)
    caps = dataclasses.replace(H.Caps.for_host(hg), u0=1, l0=1)
    d = H.device_from_host(hg, caps)
    pairs = H.build_pairs(d, caps)
    nbrs = H.build_neighbors(pairs, d, caps)
    return d, nbrs, pairs, caps


def test_fits_kernel_boundary_exact():
    """The dispatch flips exactly at the tile bound: 128 unique neighbors
    (== bound) routes to the kernel, 129 (== bound + 1) falls back — and
    the `lax.cond` output is bit-identical to the branch it claims to have
    taken in both cases."""
    import jax
    from repro.core.coarsen import score_slots
    from repro.kernels.pair_scores import ops as ps_ops

    def cond_dispatch(d, nbrs, pairs, caps):
        return jax.lax.cond(
            ps_ops.fits_kernel(d, nbrs, pairs, caps),
            lambda: ps_ops.score_slots_kernel(d, nbrs, pairs, caps),
            lambda: score_slots(d, nbrs, pairs, caps))

    # count == bound: kernel branch
    d, nbrs, pairs, caps = _pair_setup(129)
    assert ps_ops.tile_bounds(caps) == (128, 128)
    assert bool(ps_ops.fits_kernel(d, nbrs, pairs, caps))
    eta_c, inter_c = cond_dispatch(d, nbrs, pairs, caps)
    eta_k, inter_k = ps_ops.score_slots_kernel(d, nbrs, pairs, caps)
    np.testing.assert_array_equal(np.asarray(eta_c), np.asarray(eta_k))
    np.testing.assert_array_equal(np.asarray(inter_c), np.asarray(inter_k))

    # count == bound + 1: fallback branch, bit-identical to the segments
    d, nbrs, pairs, caps = _pair_setup(130)
    assert ps_ops.tile_bounds(caps) == (128, 128)
    assert not bool(ps_ops.fits_kernel(d, nbrs, pairs, caps))
    eta_c, inter_c = cond_dispatch(d, nbrs, pairs, caps)
    eta_s, inter_s = score_slots(d, nbrs, pairs, caps)
    np.testing.assert_array_equal(np.asarray(eta_c), np.asarray(eta_s))
    np.testing.assert_array_equal(np.asarray(inter_c), np.asarray(inter_s))


def test_stripe_tile_scatter_mutation_is_caught(monkeypatch):
    """Mutation check: corrupting the stripe-tile layout (an undersized row
    tile silently dropping the tail nodes' scatters) must be caught by the
    kernel-vs-segment oracle comparison — guards against a broken stripe
    scatter passing parity by accident."""
    from repro.core import generate
    from repro.core import hypergraph as H
    from repro.core.coarsen import score_slots
    from repro.kernels.pair_scores import ops as ps_ops

    hg = generate.random_kuniform(36, 50, 5, seed=4, n_src=2, weighted=True)
    caps = H.Caps.for_host(hg)
    d = H.device_from_host(hg, caps)
    pairs = H.build_pairs(d, caps)
    nbrs = H.build_neighbors(pairs, d, caps)
    eta_s, _ = score_slots(d, nbrs, pairs, caps)
    eta_ok, _ = ps_ops.score_slots_kernel(d, nbrs, pairs, caps)
    np.testing.assert_allclose(np.asarray(eta_ok), np.asarray(eta_s),
                               atol=1e-5)

    healthy = ps_ops.stripe_rows(caps, 1)
    assert healthy - 8 < caps.n  # the mutation really drops live rows
    monkeypatch.setattr(ps_ops, "stripe_rows", lambda c, s: healthy - 8)
    eta_bad, _ = ps_ops.score_slots_kernel(d, nbrs, pairs, caps)
    assert not np.allclose(np.asarray(eta_bad), np.asarray(eta_s),
                           atol=1e-5)


_SHARDED = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core import generate
    from repro.core import hypergraph as H
    from repro.core import refine as R
    from repro.core.coarsen import score_slots
    from repro.core.partitioner import partition
    from repro.dist.graph import graph_pspecs
    from repro.dist.sharding import Plan
    from repro.kernels.gains import ops as g_ops
    from repro.kernels.pair_scores import ops as ps_ops
    from repro.models import common
    from repro.utils import segops

    assert len(jax.devices()) == 8

    # --- stripe-local pair_scores under shard_map: bit-identical to the
    # single-device kernel, fp-close to the segment oracle ----------------
    hg = generate.random_kuniform(36, 50, 5, seed=4, n_src=2, weighted=True)
    caps = H.Caps.for_host(hg)
    d = H.device_from_host(hg, caps)
    pairs = H.build_pairs(d, caps)
    nbrs = H.build_neighbors(pairs, d, caps)
    assert bool(ps_ops.fits_kernel(d, nbrs, pairs, caps))
    eta0, inter0 = ps_ops.score_slots_kernel(d, nbrs, pairs, caps)
    eta_seg, inter_seg = score_slots(d, nbrs, pairs, caps)

    mesh = jax.make_mesh((8,), ("model",))
    ctx = segops.ShardCtx(axis="model", nshards=8)
    def ps_body(d_):
        pidx, pok = ctx.lanes(caps.pairs)
        prs = H.build_pairs(d_, caps, idx=pidx, idx_ok=pok, ctx=ctx)
        nb = H.build_neighbors(prs, d_, caps, ctx)
        fits = ps_ops.fits_kernel(d_, nb, prs, caps, ctx)
        eta, inter = ps_ops.score_slots_kernel(d_, nb, prs, caps, ctx)
        return fits, eta, inter
    ps_fn = jax.jit(common.shard_map(
        ps_body, mesh=mesh, in_specs=(graph_pspecs(False),),
        out_specs=(P(), P(), P())))
    fits8, eta8, inter8 = ps_fn(d)
    assert bool(fits8)
    assert np.array_equal(np.asarray(eta8), np.asarray(eta0))
    assert np.array_equal(np.asarray(inter8), np.asarray(inter0))
    np.testing.assert_allclose(np.asarray(eta8), np.asarray(eta_seg),
                               atol=1e-5)
    assert np.array_equal(np.asarray(inter8), np.asarray(inter_seg))
    print("PAIR_SCORES_SHARD_OK")

    # --- stripe-local gains under shard_map ------------------------------
    K, kcap = 5, 8
    rng = np.random.default_rng(3)
    parts = jnp.asarray(np.pad(
        rng.integers(0, K, hg.n_nodes).astype(np.int32),
        (0, caps.n - hg.n_nodes)))
    pins0, _ = R.pins_matrix(d, parts, caps, kcap)
    conn0 = g_ops.conn_weights(d, parts, pins0, caps, kcap)
    def g_body(d_, parts_):
        pins, _ = R.pins_matrix(d_, parts_, caps, kcap, ctx)
        return g_ops.conn_weights(d_, parts_, pins, caps, kcap, ctx)
    g_fn = jax.jit(common.shard_map(
        g_body, mesh=mesh, in_specs=(graph_pspecs(False), P()),
        out_specs=P()))
    conn8 = g_fn(d, parts)
    assert np.array_equal(np.asarray(conn8), np.asarray(conn0))
    # segment-path oracle (the _conn_segments computation, single device)
    t = jnp.arange(caps.p, dtype=jnp.int32)
    live = t < d.n_pins
    n_of = segops.rows_from_offsets(d.node_off, caps.p, caps.n)
    e = jnp.clip(d.node_edges, 0, caps.e - 1)
    w = jnp.where(live, d.edge_w[e], 0.0)
    contrib = w[:, None] * (pins0[:, e].T > 0)
    conn_seg = jax.ops.segment_sum(
        contrib, jnp.where(live, n_of, caps.n),
        num_segments=caps.n + 1)[: caps.n]
    np.testing.assert_allclose(np.asarray(conn8), np.asarray(conn_seg),
                               atol=1e-5)
    print("GAINS_SHARD_OK")

    # --- full V-cycle: kernels-on sharded vs kernels-on single device is
    # bit-exact on (2,4) and (1,8), kernels demonstrably fire on the
    # sharded path, and the per-level dispatch branch is mesh-independent
    hg2 = generate.snn_layered(n_layers=4, width=24, fanout=6, window=8,
                               seed=3)
    kw = dict(omega=16, delta=64, theta=4, use_kernels=True)
    r0 = partition(hg2, **kw)
    assert sum(r0.kernel_path["coarsen"]) > 0
    assert sum(r0.kernel_path["refine"]) > 0
    for shape in ((2, 4), (1, 8)):
        plan = Plan.make(jax.make_mesh(shape, ("data", "model")))
        r1 = partition(hg2, **kw, plan=plan, race=False)
        assert np.array_equal(r0.parts, r1.parts), shape
        assert r0.audit == r1.audit, shape
        assert r0.n_levels == r1.n_levels, shape
        # kernel_path_taken > 0 for the sharded levels, and the branch
        # taken per level matches the single-device run exactly
        assert r1.kernel_path == r0.kernel_path, shape
        assert sum(r1.kernel_path["coarsen"]) > 0, shape
        assert sum(r1.kernel_path["refine"]) > 0, shape
    # memory-sharded graph storage: same contract
    plan = Plan.make(jax.make_mesh((2, 4), ("data", "model")))
    rs = partition(hg2, **kw, plan=plan, race=False, shard_graph=True)
    assert np.array_equal(r0.parts, rs.parts)
    assert rs.kernel_path == r0.kernel_path
    print("KERNELS_DIST_PARITY_OK")
""")


@pytest.mark.slow
def test_kernels_dist_parity_8dev_subprocess(tmp_path):
    script = tmp_path / "kernels_dist_parity.py"
    script.write_text(_SHARDED)
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, str(script)], env=env,
                       capture_output=True, text=True, timeout=1800)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "KERNELS_DIST_PARITY_OK" in r.stdout


@pytest.mark.slow
def test_kernels_dist_parity_inprocess_8dev():
    """Runs only when the session itself was launched with 8 forced host
    devices (CI's forced-fan-out step): kernels-on full-V-cycle parity on
    (2, 4) + coverage assertion, without the subprocess."""
    import jax
    if len(jax.devices()) < 8:
        pytest.skip("needs XLA_FLAGS=--xla_force_host_platform_device_count=8")
    from repro.core import generate
    from repro.core.partitioner import partition
    from repro.dist.sharding import Plan

    hg = generate.snn_layered(n_layers=4, width=24, fanout=6, window=8,
                              seed=3)
    kw = dict(omega=16, delta=64, theta=4, use_kernels=True)
    r0 = partition(hg, **kw)
    plan = Plan.make(jax.make_mesh((2, 4), ("data", "model")))
    r1 = partition(hg, **kw, plan=plan, race=False)
    assert np.array_equal(r0.parts, r1.parts)
    assert r0.audit == r1.audit
    assert r1.kernel_path == r0.kernel_path
    assert sum(r1.kernel_path["coarsen"]) > 0
    assert sum(r1.kernel_path["refine"]) > 0
