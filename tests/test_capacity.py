"""Regression tests for the silent-corruption bug class around static
device capacities and the driver-loop host syncs.

Before these fixes: an undersized ``Caps.pairs``/``Caps.nbrs`` silently
truncated the pair expansion / neighborhood CSR (`mode="drop"` scatters)
and mis-partitioned with no error; a ``kcap_hint`` below the coarsest
partition count silently clipped partition ids; ``shrink_device`` paid a
blocking O(E) ``edge_off`` readback per bucketed level; and the phase
timers stopped before the async dispatch tail drained."""
import numpy as np
import pytest

from repro.core import generate
from repro.core import hypergraph as H
from repro.core.hypergraph import CapacityError
from repro.core.partitioner import partition


def _graph():
    return generate.snn_layered(n_layers=3, width=12, fanout=4, window=6,
                                seed=1)


# ---------------------------------------------------------------------------
# capacity-overflow audit
# ---------------------------------------------------------------------------
def test_undersized_pair_cap_raises():
    hg = _graph()
    with pytest.raises(CapacityError, match=r"pair-expansion overflow"):
        partition(hg, omega=8, delta=32, theta=2, pair_cap=4)


def test_undersized_nbr_cap_raises():
    hg = _graph()
    with pytest.raises(CapacityError, match=r"neighborhood overflow"):
        partition(hg, omega=8, delta=32, theta=2, nbr_cap=2)


def test_overflow_message_reports_live_vs_capacity():
    hg = _graph()
    exact = int(hg.stats()["pair_expansion"])
    with pytest.raises(CapacityError, match=rf"{exact}.*Caps\.pairs=4"):
        partition(hg, omega=8, delta=32, theta=2, pair_cap=4)


def test_exact_caps_do_not_raise():
    hg = _graph()
    caps = H.Caps.for_host(hg)  # exact bounds by default
    res = partition(hg, omega=8, delta=32, theta=2,
                    pair_cap=caps.pairs, nbr_cap=caps.nbrs)
    assert res.audit["size_ok"] and res.audit["inbound_ok"]


def test_check_expansion_caps_unit():
    caps = H.Caps(n=4, e=4, p=8, pairs=10, nbrs=5)
    H.check_expansion_caps(caps, 10, 5)  # at capacity: fine
    with pytest.raises(CapacityError, match="11"):
        H.check_expansion_caps(caps, 11, 0)
    with pytest.raises(CapacityError, match="6"):
        H.check_expansion_caps(caps, 10, 6)


# ---------------------------------------------------------------------------
# kcap_hint validation
# ---------------------------------------------------------------------------
def test_kcap_hint_below_k_raises():
    hg = _graph()
    with pytest.raises(ValueError, match=r"kcap_hint=1 is below"):
        partition(hg, omega=8, delta=32, theta=2, kcap_hint=1)


def test_kcap_hint_zero_raises_instead_of_silent_fallback():
    # `kcap_hint or default` used to treat 0 as "unset"; it is now an error
    hg = _graph()
    with pytest.raises(ValueError, match=r"kcap_hint=0"):
        partition(hg, omega=8, delta=32, theta=2, kcap_hint=0)


def test_valid_kcap_hint_matches_default():
    hg = _graph()
    r0 = partition(hg, omega=8, delta=32, theta=2)
    r1 = partition(hg, omega=8, delta=32, theta=2, kcap_hint=64)
    np.testing.assert_array_equal(r0.parts, r1.parts)


# ---------------------------------------------------------------------------
# shrink_device: device-side pair count + roundtrip parity
# ---------------------------------------------------------------------------
def test_device_pair_count_matches_host():
    hg = _graph()
    caps = H.Caps.for_host(hg)
    d = H.device_from_host(hg, caps)
    exact = int(hg.stats()["pair_expansion"])
    assert H.host_pair_count(hg) == exact
    assert int(H.device_pair_count(d.edge_off)) == exact


def test_host_pair_count_int64_exact_beyond_int32():
    # the upfront audit must not wrap where the int32 device count would:
    # one synthetic edge with 2**17 pins has ~2**34 ordered pairs
    c = 1 << 17
    hg = H.HostHypergraph(
        n_nodes=c, edge_off=np.array([0, c], np.int64),
        edge_pins=np.arange(c, dtype=np.int32),
        edge_nsrc=np.array([1], np.int32), edge_w=np.ones(1, np.float32))
    assert H.host_pair_count(hg) == c * (c - 1)  # > 2**31: no wrap
    caps = H.Caps(n=c, e=1, p=c, pairs=10, nbrs=10)
    with pytest.raises(CapacityError, match="pair-expansion overflow"):
        H.check_expansion_caps(caps, H.host_pair_count(hg))


def test_shrink_device_host_roundtrip():
    from repro.core.coarsen import CoarsenParams, coarsen_step
    from repro.core.contract import contract

    hg = _graph()
    caps = H.Caps.for_host(hg)
    d = H.device_from_host(hg, caps)
    match, n_pairs, _ = coarsen_step(d, caps, CoarsenParams(omega=8, delta=32))
    assert int(n_pairs) > 0
    d2, _ = contract(d, match, caps)
    d2s, caps2 = H.shrink_device(d2, caps)
    assert caps2.n <= caps.n and caps2.p <= caps.p
    assert caps2.pairs >= int(H.device_pair_count(d2.edge_off))
    h_full = H.host_from_device(d2)
    h_shr = H.host_from_device(d2s)
    assert h_full.n_nodes == h_shr.n_nodes
    np.testing.assert_array_equal(h_full.edge_off, h_shr.edge_off)
    np.testing.assert_array_equal(h_full.edge_pins, h_shr.edge_pins)
    np.testing.assert_array_equal(h_full.edge_nsrc, h_shr.edge_nsrc)
    np.testing.assert_array_equal(h_full.edge_w, h_shr.edge_w)


def test_bucketed_partition_parity():
    hg = _graph()
    r0 = partition(hg, omega=8, delta=32, theta=2)
    rb = partition(hg, omega=8, delta=32, theta=2, bucket=True)
    np.testing.assert_array_equal(r0.parts, rb.parts)


# ---------------------------------------------------------------------------
# shard_graph driver validation
# ---------------------------------------------------------------------------
def test_shard_graph_requires_plan():
    hg = _graph()
    with pytest.raises(ValueError, match="requires a Plan"):
        partition(hg, omega=8, delta=32, theta=2, shard_graph=True)
