"""Pallas flash-attention kernel: shape/dtype/causality sweeps vs oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn.kernel import flash_attention_pallas
from repro.kernels.flash_attn.ops import flash_attention_gqa
from repro.kernels.flash_attn.ref import flash_attention_ref


@pytest.mark.parametrize("bh,s,d,qc,kc", [(4, 128, 64, 64, 64),
                                          (2, 256, 32, 128, 64),
                                          (6, 64, 128, 64, 64)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_kernel_sweep(bh, s, d, qc, kc, causal, dtype, rng):
    q = jnp.asarray(rng.normal(size=(bh, s, d)), dtype)
    k = jnp.asarray(rng.normal(size=(bh, s, d)), dtype)
    v = jnp.asarray(rng.normal(size=(bh, s, d)), dtype)
    out = flash_attention_pallas(q, k, v, causal=causal, qc=qc, kc=kc)
    ref = flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)


def test_flash_gqa_matches_model_flash(rng):
    from repro.models import layers as L
    B, S, H, KV, Dh = 2, 64, 8, 2, 32
    q = jnp.asarray(rng.normal(size=(B, S, H, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, Dh)), jnp.float32)
    out = flash_attention_gqa(q, k, v, causal=True, qc=32, kc=32)
    ref = L.flash_attention(q, k, v, causal=True, q_chunk=16, k_chunk=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)
