"""Isolation oracle for `core.refine.events_validity`: synthetic move
sequences (arbitrary move_to / seq / gains, NOT pipeline-derived) are
brute-force simulated in numpy, asserting the chosen prefix is the
max-cumulative-gain prefix whose *end state* satisfies both the size (Omega)
and distinct-inbound (Delta) constraints — violations inside the prefix
permitted, exactly the paper's Sec. VI-D contract."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import generate
from repro.core import hypergraph as H
from repro.core import refine as R

IMAX = 2**31 - 1


def _distinct_inbound(hg, parts, kcap):
    """d[p] = #{e : some dst-pin of e lies in p}."""
    out = np.zeros(kcap, np.int64)
    for e in range(hg.n_edges):
        for p in np.unique(parts[hg.dst(e)]):
            out[p] += 1
    return out


def _brute_force(hg, parts0, mv, sq, gains, omega, delta, kcap):
    """Best valid prefix by step-by-step simulation from scratch."""
    order = [n for n in np.argsort(sq[: hg.n_nodes]) if mv[n] >= 0]
    p_cur = parts0.copy()
    best_t, best_gain, cum = None, -np.inf, 0.0
    for t, n in enumerate(order):
        p_cur[n] = mv[n]
        cum += gains[n]
        sizes = np.bincount(p_cur, minlength=kcap)
        valid = (sizes <= omega).all() and \
            (_distinct_inbound(hg, p_cur, kcap) <= delta).all()
        if valid and cum > best_gain:
            best_t, best_gain = t, cum
    if best_t is None or best_gain <= 0.0:
        return set(), 0.0
    return set(order[: best_t + 1]), best_gain


def _synthetic_moves(hg, parts0, K, seed, frac=0.6):
    """Random mover subset, random destinations != source, random seq
    permutation, continuous random gains (ties have measure zero)."""
    rng = np.random.default_rng(seed)
    n = hg.n_nodes
    movers = rng.random(n) < frac
    mv = np.full(n, -1, np.int32)
    dest = (parts0 + rng.integers(1, K, size=n)) % K
    mv[movers] = dest[movers]
    n_movers = int(movers.sum())
    sq = np.full(n, IMAX, np.int64)
    sq[movers] = rng.permutation(n_movers)
    gains = np.zeros(n, np.float32)
    gains[movers] = rng.normal(0.5, 1.5, size=n_movers).astype(np.float32)
    return mv, sq, gains


@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("omega,delta", [(6, 100), (100, 7), (6, 7)])
def test_events_validity_matches_numpy_oracle(seed, omega, delta):
    K, kcap = 4, 8
    rng = np.random.default_rng(seed)
    hg = generate.random_kuniform(n_nodes=14, n_edges=12, k=3, seed=seed,
                                  weighted=True)
    caps = H.Caps.for_host(hg)
    d = H.device_from_host(hg, caps)
    parts0 = rng.integers(0, K, size=hg.n_nodes).astype(np.int32)
    parts = jnp.asarray(np.pad(parts0, (0, caps.n - hg.n_nodes)))
    params = R.RefineParams(omega=omega, delta=delta)

    mv, sq, gains = _synthetic_moves(hg, parts0, K, seed)
    _, pins_in = R.pins_matrix(d, parts, caps, kcap)
    pad_n = caps.n - hg.n_nodes
    apply_mask, applied_gain = R.events_validity(
        d, parts, pins_in,
        jnp.asarray(np.pad(mv, (0, pad_n), constant_values=-1)),
        jnp.asarray(np.pad(sq.astype(np.int32), (0, pad_n),
                           constant_values=IMAX)),
        jnp.asarray(np.pad(gains, (0, pad_n))),
        caps, kcap, params)

    expect, expect_gain = _brute_force(hg, parts0, mv, sq, gains,
                                       omega, delta, kcap)
    got = set(np.where(np.asarray(apply_mask)[: hg.n_nodes])[0])
    assert got == expect, (seed, omega, delta)
    assert abs(float(applied_gain) - expect_gain) < 1e-4


def test_events_validity_initially_violating_state():
    """Start with every node in one partition (size violation everywhere):
    only prefixes that *repair* the violation may be applied."""
    K, kcap, omega, delta = 3, 8, 5, 100
    hg = generate.random_kuniform(n_nodes=12, n_edges=10, k=3, seed=9,
                                  weighted=True)
    caps = H.Caps.for_host(hg)
    d = H.device_from_host(hg, caps)
    parts0 = np.zeros(hg.n_nodes, np.int32)  # size 12 > omega=5
    parts = jnp.asarray(np.pad(parts0, (0, caps.n - hg.n_nodes)))
    params = R.RefineParams(omega=omega, delta=delta)

    # move nodes 0..7 round-robin to partitions 1,2 → end sizes (4,4,4)
    mv = np.full(hg.n_nodes, -1, np.int32)
    mv[:8] = [1, 2, 1, 2, 1, 2, 1, 2]
    sq = np.full(hg.n_nodes, IMAX, np.int64)
    sq[:8] = np.arange(8)
    gains = np.zeros(hg.n_nodes, np.float32)
    gains[:8] = 0.25

    _, pins_in = R.pins_matrix(d, parts, caps, kcap)
    pad_n = caps.n - hg.n_nodes
    apply_mask, applied_gain = R.events_validity(
        d, parts, pins_in,
        jnp.asarray(np.pad(mv, (0, pad_n), constant_values=-1)),
        jnp.asarray(np.pad(sq.astype(np.int32), (0, pad_n),
                           constant_values=IMAX)),
        jnp.asarray(np.pad(gains, (0, pad_n))),
        caps, kcap, params)

    expect, expect_gain = _brute_force(hg, parts0, mv, sq, gains,
                                       omega, delta, kcap)
    got = set(np.where(np.asarray(apply_mask)[: hg.n_nodes])[0])
    # the source partition only becomes feasible once >= 7 nodes left it;
    # gains are uniform-positive, so the best valid prefix is the full
    # 8-move sequence
    assert got == expect == set(range(8))
    assert abs(float(applied_gain) - expect_gain) < 1e-4
