"""Isolation oracles for the refinement pipeline, driven by *synthetic*
move sequences (arbitrary move_to / seq / gains, NOT pipeline-derived):

* `events_validity`: numpy brute-force simulation asserting the chosen
  prefix is the max-cumulative-gain prefix whose *end state* satisfies both
  the size (Omega) and distinct-inbound (Delta) constraints — violations
  inside the prefix permitted, exactly the paper's Sec. VI-D contract.
* `inseq_gains`: numpy sequential replay applying the sequence one move at
  a time, asserting each in-sequence gain equals the true connectivity
  delta at its position (so every prefix sum equals the true total).
* `build_sequence`: seeded invariants (contiguous seq permutation, IMAX
  non-movers, acyclic post-cut pred); hypothesis variants live in
  tests/test_property.py.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import generate, metrics
from repro.core import hypergraph as H
from repro.core import refine as R

IMAX = 2**31 - 1


def _distinct_inbound(hg, parts, kcap):
    """d[p] = #{e : some dst-pin of e lies in p}."""
    out = np.zeros(kcap, np.int64)
    for e in range(hg.n_edges):
        for p in np.unique(parts[hg.dst(e)]):
            out[p] += 1
    return out


def _brute_force(hg, parts0, mv, sq, gains, omega, delta, kcap):
    """Best valid prefix by step-by-step simulation from scratch."""
    order = [n for n in np.argsort(sq[: hg.n_nodes]) if mv[n] >= 0]
    p_cur = parts0.copy()
    best_t, best_gain, cum = None, -np.inf, 0.0
    for t, n in enumerate(order):
        p_cur[n] = mv[n]
        cum += gains[n]
        sizes = np.bincount(p_cur, minlength=kcap)
        valid = (sizes <= omega).all() and \
            (_distinct_inbound(hg, p_cur, kcap) <= delta).all()
        if valid and cum > best_gain:
            best_t, best_gain = t, cum
    if best_t is None or best_gain <= 0.0:
        return set(), 0.0
    return set(order[: best_t + 1]), best_gain


def _synthetic_moves(hg, parts0, K, seed, frac=0.6):
    """Random mover subset, random destinations != source, random seq
    permutation, continuous random gains (ties have measure zero)."""
    rng = np.random.default_rng(seed)
    n = hg.n_nodes
    movers = rng.random(n) < frac
    mv = np.full(n, -1, np.int32)
    dest = (parts0 + rng.integers(1, K, size=n)) % K
    mv[movers] = dest[movers]
    n_movers = int(movers.sum())
    sq = np.full(n, IMAX, np.int64)
    sq[movers] = rng.permutation(n_movers)
    gains = np.zeros(n, np.float32)
    gains[movers] = rng.normal(0.5, 1.5, size=n_movers).astype(np.float32)
    return mv, sq, gains


@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("omega,delta", [(6, 100), (100, 7), (6, 7)])
def test_events_validity_matches_numpy_oracle(seed, omega, delta):
    K, kcap = 4, 8
    rng = np.random.default_rng(seed)
    hg = generate.random_kuniform(n_nodes=14, n_edges=12, k=3, seed=seed,
                                  weighted=True)
    caps = H.Caps.for_host(hg)
    d = H.device_from_host(hg, caps)
    parts0 = rng.integers(0, K, size=hg.n_nodes).astype(np.int32)
    parts = jnp.asarray(np.pad(parts0, (0, caps.n - hg.n_nodes)))
    params = R.RefineParams(omega=omega, delta=delta)

    mv, sq, gains = _synthetic_moves(hg, parts0, K, seed)
    _, pins_in = R.pins_matrix(d, parts, caps, kcap)
    pad_n = caps.n - hg.n_nodes
    apply_mask, applied_gain = R.events_validity(
        d, parts, pins_in,
        jnp.asarray(np.pad(mv, (0, pad_n), constant_values=-1)),
        jnp.asarray(np.pad(sq.astype(np.int32), (0, pad_n),
                           constant_values=IMAX)),
        jnp.asarray(np.pad(gains, (0, pad_n))),
        caps, kcap, params)

    expect, expect_gain = _brute_force(hg, parts0, mv, sq, gains,
                                       omega, delta, kcap)
    got = set(np.where(np.asarray(apply_mask)[: hg.n_nodes])[0])
    assert got == expect, (seed, omega, delta)
    assert abs(float(applied_gain) - expect_gain) < 1e-4


@pytest.mark.parametrize("seed", range(5))
def test_inseq_gains_match_sequential_replay(seed):
    """Oracle for Eq. 14/15's exact before/after correction on *synthetic*
    sequences: replay the moves one at a time in numpy; the in-sequence
    gain of every move must equal the true connectivity delta at its
    position, hence the summed gains of any prefix equal the prefix's true
    connectivity improvement."""
    K, kcap = 4, 8
    rng = np.random.default_rng(seed)
    hg = generate.random_kuniform(n_nodes=14, n_edges=12, k=3, seed=seed,
                                  weighted=True)
    caps = H.Caps.for_host(hg)
    d = H.device_from_host(hg, caps)
    parts0 = rng.integers(0, K, size=hg.n_nodes).astype(np.int32)
    parts = jnp.asarray(np.pad(parts0, (0, caps.n - hg.n_nodes)))

    mv, sq, _ = _synthetic_moves(hg, parts0, K, seed)
    # exact isolation gains for the synthetic destinations (the Eq. 13
    # definition: connectivity delta of the move applied alone)
    conn0 = metrics.connectivity(hg, parts0)
    gi = np.zeros(hg.n_nodes, np.float32)
    for n in range(hg.n_nodes):
        if mv[n] >= 0:
            p2 = parts0.copy()
            p2[n] = mv[n]
            gi[n] = conn0 - metrics.connectivity(hg, p2)

    pins, _ = R.pins_matrix(d, parts, caps, kcap)
    pad_n = caps.n - hg.n_nodes
    gain_seq = R.inseq_gains(
        d, parts, pins,
        jnp.asarray(np.pad(mv, (0, pad_n), constant_values=-1)),
        jnp.asarray(np.pad(gi, (0, pad_n))),
        jnp.asarray(np.pad(sq.astype(np.int32), (0, pad_n),
                           constant_values=IMAX)),
        caps, kcap)
    gs = np.asarray(gain_seq)

    order = [n for n in np.argsort(sq[: hg.n_nodes]) if mv[n] >= 0]
    assert order, "synthetic sequence should have movers"
    p_cur = parts0.copy()
    conn_prev = conn0
    total = 0.0
    for n in order:
        p_cur[n] = mv[n]
        c = metrics.connectivity(hg, p_cur)
        assert abs((conn_prev - c) - gs[n]) < 1e-4, (seed, n)
        conn_prev = c
        total += gs[n]
    assert abs((conn0 - conn_prev) - total) < 1e-3


def test_events_validity_int32_sizes_beyond_float32():
    """Running size counts must accumulate in int32: with a 2**24-sized
    node, a float32 events scan rounds `2**24 + 1` back to `2**24`, judging
    an over-Omega prefix valid. The decisive event is the second of its
    segment, so *any* float32 summation order gets it wrong — the test
    fails if `events_validity` reverts to casting deltas to float32."""
    S = 2 ** 24
    hg = H.HostHypergraph(n_nodes=3, edge_off=np.array([0, 3]),
                          edge_pins=np.array([0, 1, 2]),
                          edge_nsrc=np.array([1]), edge_w=np.array([1.0]))
    caps = H.Caps.for_host(hg)
    d = H.device_from_host(hg, caps)
    d.node_size = jnp.asarray(np.array([S, 1, 1], np.int32))
    kcap = 4
    parts = jnp.zeros((caps.n,), jnp.int32)
    params = R.RefineParams(omega=S, delta=100)

    # all three nodes move 0 -> 1 in seq order; sizes after each move:
    # part1 = S, S+1, S+2 — only the first end-state is valid (<= Omega)
    mv = jnp.asarray(np.array([1, 1, 1], np.int32))
    sq = jnp.asarray(np.array([0, 1, 2], np.int32))
    gains = jnp.asarray(np.ones(3, np.float32))
    _, pins_in = R.pins_matrix(d, parts, caps, kcap)
    apply_mask, applied_gain = R.events_validity(
        d, parts, pins_in, mv, sq, gains, caps, kcap, params)
    got = set(np.where(np.asarray(apply_mask))[0])
    assert got == {0}, got
    assert abs(float(applied_gain) - 1.0) < 1e-6


def _walk_pred_acyclic(pred, n_nodes):
    """pred must terminate (-1) within n_nodes steps from every node."""
    for n in range(n_nodes):
        p, steps = n, 0
        while pred[p] >= 0:
            p = pred[p]
            steps += 1
            if steps > n_nodes:
                return False
    return True


@pytest.mark.parametrize("seed", range(4))
def test_build_sequence_invariants_seeded(seed):
    """Seeded (hypothesis-free) variant of the build_sequence properties:
    movers get a contiguous seq permutation 0..n_movers-1, non-movers IMAX,
    and the post-cut pred relation is acyclic with seq[pred] == seq - 1."""
    K, kcap = 5, 8
    rng = np.random.default_rng(seed)
    hg = generate.random_kuniform(n_nodes=30, n_edges=40, k=4, seed=seed,
                                  weighted=True)
    caps = H.Caps.for_host(hg)
    d = H.device_from_host(hg, caps)
    parts0 = rng.integers(0, K, size=hg.n_nodes).astype(np.int32)
    parts = jnp.asarray(np.pad(parts0, (0, caps.n - hg.n_nodes)))
    params = R.RefineParams(omega=9, delta=35)
    pins, _ = R.pins_matrix(d, parts, caps, kcap)
    move_to, gain_iso, _, _ = R.propose_moves(
        d, parts, pins, caps, kcap, params, jnp.asarray(False), jnp.int32(K))
    seq, n_movers, aux = R.build_sequence(
        d, parts, move_to, gain_iso, caps, kcap, params, with_aux=True)
    mv = np.asarray(move_to)[: hg.n_nodes]
    sq = np.asarray(seq)
    nm = int(n_movers)
    assert sorted(sq[: hg.n_nodes][mv >= 0].tolist()) == list(range(nm))
    assert (sq[: hg.n_nodes][mv < 0] == IMAX).all()
    assert (sq[hg.n_nodes:] == IMAX).all()
    pred = np.asarray(aux["pred"])
    assert _walk_pred_acyclic(pred, caps.n)
    for n in range(hg.n_nodes):
        if mv[n] >= 0 and pred[n] >= 0:
            assert sq[pred[n]] == sq[n] - 1


def test_events_validity_initially_violating_state():
    """Start with every node in one partition (size violation everywhere):
    only prefixes that *repair* the violation may be applied."""
    K, kcap, omega, delta = 3, 8, 5, 100
    hg = generate.random_kuniform(n_nodes=12, n_edges=10, k=3, seed=9,
                                  weighted=True)
    caps = H.Caps.for_host(hg)
    d = H.device_from_host(hg, caps)
    parts0 = np.zeros(hg.n_nodes, np.int32)  # size 12 > omega=5
    parts = jnp.asarray(np.pad(parts0, (0, caps.n - hg.n_nodes)))
    params = R.RefineParams(omega=omega, delta=delta)

    # move nodes 0..7 round-robin to partitions 1,2 → end sizes (4,4,4)
    mv = np.full(hg.n_nodes, -1, np.int32)
    mv[:8] = [1, 2, 1, 2, 1, 2, 1, 2]
    sq = np.full(hg.n_nodes, IMAX, np.int64)
    sq[:8] = np.arange(8)
    gains = np.zeros(hg.n_nodes, np.float32)
    gains[:8] = 0.25

    _, pins_in = R.pins_matrix(d, parts, caps, kcap)
    pad_n = caps.n - hg.n_nodes
    apply_mask, applied_gain = R.events_validity(
        d, parts, pins_in,
        jnp.asarray(np.pad(mv, (0, pad_n), constant_values=-1)),
        jnp.asarray(np.pad(sq.astype(np.int32), (0, pad_n),
                           constant_values=IMAX)),
        jnp.asarray(np.pad(gains, (0, pad_n))),
        caps, kcap, params)

    expect, expect_gain = _brute_force(hg, parts0, mv, sq, gains,
                                       omega, delta, kcap)
    got = set(np.where(np.asarray(apply_mask)[: hg.n_nodes])[0])
    # the source partition only becomes feasible once >= 7 nodes left it;
    # gains are uniform-positive, so the best valid prefix is the full
    # 8-move sequence
    assert got == expect == set(range(8))
    assert abs(float(applied_gain) - expect_gain) < 1e-4
