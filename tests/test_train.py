"""Training substrate: loss decreases, checkpoint/restart exactness,
elastic restore, straggler watchdog, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import SyntheticLM
from repro.dist.ft import StepWatchdog, TrainSupervisor
from repro.train import optimizer as opt
from repro.train.grad_compress import compressed_psum_grads, quantize_int8
from repro.train.loop import train


def test_loss_decreases_smoke():
    cfg = get_config("qwen2-1.5b").smoke()
    res = train(cfg, steps=30, global_batch=8, seq_len=64, log_every=1,
                seed=0)
    first = np.mean([l for _, l in res.losses[:3]])
    last = np.mean([l for _, l in res.losses[-3:]])
    assert last < first - 0.1, (first, last)


def test_checkpoint_resume_bitexact(tmp_path):
    cfg = get_config("qwen2-1.5b").smoke()
    # one LR schedule for all runs (the schedule depends on total_steps)
    ocfg = opt.OptConfig(total_steps=8, warmup=2)
    d1 = str(tmp_path / "a")
    # run 8 steps straight
    r_full = train(cfg, steps=8, global_batch=4, seq_len=32, log_every=1,
                   ckpt_dir=d1, ckpt_every=4, seed=3, ocfg=ocfg)
    # run 4 steps, then resume to 8 from the checkpoint
    d2 = str(tmp_path / "b")
    train(cfg, steps=4, global_batch=4, seq_len=32, log_every=1,
          ckpt_dir=d2, ckpt_every=4, seed=3, ocfg=ocfg)
    r_resumed = train(cfg, steps=8, global_batch=4, seq_len=32, log_every=1,
                      ckpt_dir=d2, ckpt_every=4, seed=3, resume=True,
                      ocfg=ocfg)
    np.testing.assert_allclose(r_full.losses[-1][1], r_resumed.losses[-1][1],
                               rtol=1e-5)


def test_checkpoint_atomic_and_keep_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": np.arange(6, dtype=np.float32), "b": {"c": np.ones(3)}}
    for s in (1, 2, 3):
        mgr.save(s, tree)
    assert mgr.all_steps() == [2, 3]
    step, restored, _ = mgr.restore(tree)
    assert step == 3
    np.testing.assert_array_equal(restored["a"], tree["a"])


def test_data_pipeline_deterministic_and_sharded():
    ds = SyntheticLM(vocab=128, seq_len=16, global_batch=8, seed=7)
    b1, b2 = ds.batch_at(5), ds.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # shards partition the batch deterministically
    sh0 = SyntheticLM(vocab=128, seq_len=16, global_batch=8, seed=7,
                      n_shards=2, shard=0).batch_at(5)
    sh1 = SyntheticLM(vocab=128, seq_len=16, global_batch=8, seed=7,
                      n_shards=2, shard=1).batch_at(5)
    assert sh0["tokens"].shape == (4, 16)
    assert not np.array_equal(sh0["tokens"], sh1["tokens"])


def test_watchdog_fires_on_stall():
    import time
    fired = []
    wd = StepWatchdog(0.1, fired.append)
    wd.arm(7)
    time.sleep(0.4)
    wd.stop()
    assert fired == [7]


def test_supervisor_restarts_from_checkpoint():
    saved = {}

    def save(step, state):
        saved["s"] = (step, state)

    def restore():
        return saved["s"]

    crashes = {"n": 2}

    def step_fn(state, step):
        if step == 5 and crashes["n"] > 0:
            crashes["n"] -= 1
            raise RuntimeError("injected node failure")
        return state + 1

    sup = TrainSupervisor(lambda: 0, save, restore, max_restarts=3)
    step, state = sup.run(step_fn, n_steps=10, ckpt_every=2)
    assert step == 10 and sup.restarts == 2
    assert state == 10  # every step applied exactly once post-restore


def test_grad_compression_error_feedback_converges():
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
    grads = {"w": g_true}
    res = {"w": jnp.zeros_like(g_true)}
    acc = jnp.zeros_like(g_true)
    for _ in range(50):
        deq, res = compressed_psum_grads(grads, res, None)
        acc = acc + deq["w"]
    # with error feedback the accumulated compressed grads track 50*g
    np.testing.assert_allclose(np.asarray(acc) / 50, np.asarray(g_true),
                               atol=0.02)


def test_quantize_int8_roundtrip_bound():
    x = jnp.linspace(-3, 3, 255)
    q, s = quantize_int8(x)
    err = jnp.max(jnp.abs(q.astype(jnp.float32) * s - x))
    assert float(err) <= float(s) * 0.51


def test_adamw_decreases_quadratic():
    w = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init_state(w)
    cfg = opt.OptConfig(lr_peak=0.1, warmup=1, total_steps=200,
                        weight_decay=0.0)
    for _ in range(100):
        grads = {"w": state.master["w"]}  # grad of 0.5||w||^2
        state = opt.adamw_update(state, grads, cfg)
    assert float(jnp.abs(state.master["w"]).max()) < 1.0
