"""Streaming repartitioning: incremental `GraphDelta` application, the
warm refine-only solve path, and the drift / audit cold fallbacks.

Contracts pinned here:

* `apply_delta` matches a plain-python oracle (pin edits, node tombstones,
  edge delete/insert, node growth) and accumulates the drift metric.
* A zero-delta `repartition()` is bit-identical to `refine_from()` — the
  warm path is *exactly* standalone refinement, nothing else.
* The warm path skips coarsening: its span tree has NO ``coarsen_level``
  spans and the result reports ``n_levels == 0``; past the drift threshold
  the fallback demonstrably takes the full-V-cycle branch (``coarsen_level``
  spans present, drift reset).
* `dist.graph.apply_delta_sharded` leaves the striped device arrays equal
  to a fresh re-pack of the mutated host mirror (numpy oracle), keeping
  stripe shapes; the 8-forced-device variant additionally pins warm-start
  race=False parity on a (2, 4) mesh.
* The service's keyed `submit`/`resubmit` routes follow-ups through the
  warm lane and records the ``repartition.*`` series.
"""
import numpy as np
import pytest

from repro.core import generate, metrics
from repro.core.hypergraph import (Caps, CapacityError, GraphDelta,
                                   HostHypergraph, apply_delta,
                                   check_fits_caps)
from repro.core.partitioner import (WarmCache, _extend_parts, partition,
                                    refine_from, repartition)
from repro.obs import trace as otrace

_GRAPH = dict(n_layers=4, width=24, fanout=6, seed=3)
_CONSTRAINTS = dict(omega=16, delta=64, theta=4)


def _mkgraph():
    return generate.snn_layered(**_GRAPH)


# --------------------------------------------------------------- delta apply
def _edges_of(hg: HostHypergraph):
    return [(list(map(int, hg.edge(e))), int(hg.edge_nsrc[e]),
             float(hg.edge_w[e])) for e in range(hg.n_edges)]


def test_apply_delta_numpy_oracle():
    """Every delta op against a hand-evaluated plain-python oracle."""
    hg = _mkgraph()
    before = _edges_of(hg)
    n0, p0 = hg.n_nodes, hg.n_pins
    e0_pins = before[0][0]
    dl = GraphDelta(
        add_nodes=2,
        del_nodes=(5,),
        del_edges=(3, 7),
        add_edges=((np.array([1, 2, n0], np.int32), 1, 2.5),),
        add_pins=((0, n0 + 1),),
        del_pins=((0, e0_pins[0]),),
    )
    touched = apply_delta(hg, dl)

    # oracle: replay the documented order on the snapshot
    exp = [(list(p), s, w) for p, s, w in before]
    # del_pins first: e0_pins[0] was a source pin (nsrc decrements)
    was_src = 0 < before[0][1]
    exp[0] = (exp[0][0][1:], exp[0][1] - (1 if was_src else 0), exp[0][2])
    # tombstone node 5 everywhere
    t_tomb = 0
    for i, (p, s, w) in enumerate(exp):
        if 5 in p:
            t_tomb += sum(1 for v in p if v == 5)
            s -= sum(1 for j, v in enumerate(p) if v == 5 and j < s)
            exp[i] = ([v for v in p if v != 5], s, w)
    # add_pins appends as dst
    exp[0][0].append(n0 + 1)
    # edge deletions shift ids down
    t_del = len(exp[3][0]) + len(exp[7][0])
    exp = [e for i, e in enumerate(exp) if i not in (3, 7)]
    # then insertions append
    exp.append(([1, 2, n0], 1, 2.5))

    assert hg.n_nodes == n0 + 2
    assert _edges_of(hg) == exp
    assert touched == 1 + t_tomb + 1 + t_del + 3
    assert hg.drift_pins == touched
    assert hg.drift == pytest.approx(min(1.0, touched / hg.n_pins))
    hg.validate()
    hg.reset_drift()
    assert hg.drift == 0.0

    # malformed deltas fail loudly, not half-silently
    with pytest.raises(ValueError):
        apply_delta(hg, GraphDelta(del_edges=(hg.n_edges,)))
    with pytest.raises(ValueError):
        apply_delta(hg, GraphDelta(del_pins=((0, 10 ** 6),)))


def test_check_fits_caps_is_the_resize_trigger():
    hg = _mkgraph()
    caps = Caps.for_host(hg)
    check_fits_caps(hg, caps)  # freshly sized: fits
    big = np.arange(3, dtype=np.int32)
    for _ in range(64):  # grow edges until some capacity trips
        apply_delta(hg, GraphDelta(add_edges=((big, 1, 1.0),)))
    with pytest.raises(CapacityError):
        check_fits_caps(hg, caps)


def test_perturb_delta_deterministic():
    hg = _mkgraph()
    d1 = generate.perturb_delta(hg, n_edges=5, seed=9)
    d2 = generate.perturb_delta(hg, n_edges=5, seed=9)
    assert d1.del_edges == d2.del_edges
    assert len(d1.add_edges) == len(d1.del_edges) == 5
    for (p1, s1, w1), (p2, s2, w2) in zip(d1.add_edges, d2.add_edges):
        assert np.array_equal(p1, p2) and s1 == s2 and w1 == w2


# ----------------------------------------------------------------- warm path
def test_zero_delta_repartition_bit_identical_to_refine_from():
    hg = _mkgraph()
    cold = partition(hg, **_CONSTRAINTS)
    assert cold.mode == "cold"

    hg_a, hg_b = _mkgraph(), _mkgraph()
    a = refine_from(hg_a, cold.parts, _CONSTRAINTS["omega"],
                    _CONSTRAINTS["delta"], theta=_CONSTRAINTS["theta"])
    b = repartition(hg_b, cold.parts, _CONSTRAINTS["omega"],
                    _CONSTRAINTS["delta"], theta=_CONSTRAINTS["theta"])
    assert b.mode == "warm" and b.n_levels == 0
    assert np.array_equal(a.parts, b.parts)
    assert a.audit == b.audit
    # warm quality never regresses below the audit bar of the cold solve
    assert b.audit["size_ok"] and b.audit["inbound_ok"]


def test_warm_path_skips_coarsening_span_tree():
    hg = _mkgraph()
    cold = partition(hg, **_CONSTRAINTS)
    otrace.reset()
    res = repartition(_mkgraph(), cold.parts, _CONSTRAINTS["omega"],
                      _CONSTRAINTS["delta"], theta=_CONSTRAINTS["theta"],
                      deltas=generate.perturb_delta(_mkgraph(), 3, seed=1),
                      drift_threshold=0.9)
    assert res.mode == "warm"
    assert res.n_levels == 0
    root = otrace.last_root()
    assert root.name == "partition"
    assert not root.find("coarsen_level")  # no coarsening, by construction
    assert root.find("refine_level")
    assert res.kernel_path["coarsen"] == []
    assert res.timings["coarsen"] == 0.0
    # level_stats carry the single refined level
    assert len(res.level_stats) == 1


def test_drift_fallback_takes_full_vcycle_branch():
    hg = _mkgraph()
    cold = partition(hg, **_CONSTRAINTS)
    hg2 = _mkgraph()
    dl = generate.perturb_delta(hg2, n_edges=4, seed=1)
    otrace.reset()
    res = repartition(hg2, cold.parts, _CONSTRAINTS["omega"],
                      _CONSTRAINTS["delta"], theta=_CONSTRAINTS["theta"],
                      deltas=dl, drift_threshold=0.0)
    assert res.mode == "fallback-drift"
    assert res.n_levels > 0
    assert otrace.last_root().find("coarsen_level")  # the cold branch ran
    assert hg2.drift == 0.0  # cold solve consolidates: drift resets
    assert res.audit["size_ok"] and res.audit["inbound_ok"]


def test_audit_fallback():
    """A warm start that refinement cannot repair (every node in one
    partition: k=1 admits no moves, so the size audit fails) must take the
    fallback-audit branch and return a valid cold solution."""
    hg = _mkgraph()
    res = repartition(hg, np.zeros(hg.n_nodes, np.int64),
                      _CONSTRAINTS["omega"], _CONSTRAINTS["delta"],
                      theta=_CONSTRAINTS["theta"])
    assert res.mode == "fallback-audit"
    assert res.n_levels > 0
    assert res.audit["size_ok"] and res.audit["inbound_ok"]
    assert hg.drift == 0.0


def test_warm_cache_reuse_and_node_growth():
    hg = _mkgraph()
    cold = partition(hg, **_CONSTRAINTS)
    cache = WarmCache()
    r1 = repartition(hg, cold.parts, _CONSTRAINTS["omega"],
                     _CONSTRAINTS["delta"], theta=_CONSTRAINTS["theta"],
                     cache=cache)
    assert r1.mode == "warm"
    assert cache.caps is not None and cache.d is not None
    d_before = cache.d
    # second zero-delta warm solve reuses the cached device graph object
    r2 = repartition(hg, r1.parts, _CONSTRAINTS["omega"],
                     _CONSTRAINTS["delta"], theta=_CONSTRAINTS["theta"],
                     cache=cache)
    assert r2.mode == "warm" and cache.d is d_before

    # a delta that adds nodes: prev_parts extends by least-loaded placement
    n0 = hg.n_nodes
    dl = GraphDelta(add_nodes=3,
                    add_edges=((np.array([0, n0, n0 + 1], np.int32),
                                1, 1.0),))
    r3 = repartition(hg, r2.parts, _CONSTRAINTS["omega"],
                     _CONSTRAINTS["delta"], theta=_CONSTRAINTS["theta"],
                     deltas=dl, drift_threshold=0.9, cache=cache)
    assert r3.mode == "warm"
    assert len(r3.parts) == n0 + 3


def test_extend_parts_least_loaded():
    prev = np.array([0, 0, 0, 1], np.int64)
    out = _extend_parts(prev, 6, 2)
    assert np.array_equal(out[:4], prev)
    # loads (3,1): both new nodes flow to partition 1 (then tie -> 0? no:
    # after one add loads are (3,2), still least-loaded is 1)
    assert out[4] == 1 and out[5] == 1


# ------------------------------------------------------------- sharded delta
def test_apply_delta_sharded_oracle_single_device():
    """Numpy oracle on a 1x1 mesh (runs everywhere): after
    `apply_delta_sharded` the striped device arrays equal a fresh re-pack
    of the mutated host mirror, and stripe shapes hold."""
    import jax
    from repro.core.hypergraph import packed_host_arrays
    from repro.dist import graph as dist_graph
    from repro.dist.sharding import Plan

    n = len(jax.devices())
    mesh = jax.make_mesh((1, n), ("data", "model"))
    plan = Plan.make(mesh)
    hg, hg_ref = _mkgraph(), _mkgraph()
    caps = Caps.for_host(hg)
    sh = dist_graph.sharded_from_host(hg, caps, plan)
    dl = generate.perturb_delta(hg, n_edges=4, seed=5)

    sh2 = dist_graph.apply_delta_sharded(sh, hg, dl, caps, plan)
    apply_delta(hg_ref, dl)
    assert hg.drift == hg_ref.drift > 0.0
    ptot = dist_graph.stripe_total(caps, n)
    ref = packed_host_arrays(hg_ref, caps, pcap=ptot)
    for f in dist_graph.PINS_FIELDS:
        got = np.asarray(getattr(sh2.g, f))
        assert got.shape[0] == ptot, f
        assert np.array_equal(got, ref[f]), f
    for f in ("edge_off", "edge_nsrc", "edge_w", "node_off", "node_nin",
              "node_size"):
        np.testing.assert_array_equal(np.asarray(getattr(sh2.g, f)),
                                      ref[f], err_msg=f)

    # capacity overflow raises BEFORE device state changes, host mirror
    # stays mutated (the caller rebuilds at fresh caps)
    big = GraphDelta(add_edges=tuple(
        (np.arange(3, dtype=np.int32) + i % 7, 1, 1.0)
        for i in range(2 * caps.e)))
    e_before = hg.n_edges
    with pytest.raises(CapacityError):
        dist_graph.apply_delta_sharded(sh2, hg, big, caps, plan)
    assert hg.n_edges == e_before + 2 * caps.e


# -------------------------------------------------------------------- kway
def test_repartition_kway_warm_and_pinned_ids():
    from repro.core.kway import partition_kway, repartition_kway

    hg = _mkgraph()
    cold = partition_kway(hg, k=4, theta=4)
    assert "pins" in cold.kernel_path  # shared refine loop reports pins too
    dl = generate.perturb_delta(hg, n_edges=4, seed=2)
    warm = repartition_kway(hg, cold.parts, k=4, deltas=dl,
                            drift_threshold=0.9, theta=4)
    assert warm.mode == "warm" and warm.n_levels == 0
    assert warm.n_parts == 4  # pinned id space, no compaction
    assert warm.audit["size_ok"]
    assert "balance_eps" in warm.audit
    fb = repartition_kway(hg, warm.parts, k=4,
                          deltas=generate.perturb_delta(hg, 4, seed=3),
                          drift_threshold=0.0, theta=4)
    assert fb.mode == "fallback-drift" and fb.n_levels > 0


# ------------------------------------------------------------------ service
def test_service_warm_lane():
    from repro.serve.partition_service import PartitionService

    svc = PartitionService(batch_slots=2, route_threshold=2048, theta=4)
    try:
        hg = _mkgraph()
        rid0 = svc.submit(hg, _CONSTRAINTS["omega"], _CONSTRAINTS["delta"],
                          key="tenant-a")
        out0 = svc.drain()
        assert out0[rid0].route in ("bucket", "vcycle")

        dl = generate.perturb_delta(hg, n_edges=3, seed=4)
        rid1 = svc.resubmit("tenant-a", deltas=dl)
        out1 = svc.drain()
        assert out1[rid1].route == "warm"
        assert out1[rid1].n_levels == 0  # refine-only, no coarsening
        assert out1[rid1].audit["size_ok"] and out1[rid1].audit["inbound_ok"]

        r = svc.registry
        assert r.value("repartition.submitted") == 1
        assert r.value("repartition.solves", mode="warm") == 1
        snap = r.snapshot()
        assert "repartition.solve_latency.s" in snap["histograms"]
        lat = snap["histograms"]["repartition.solve_latency.s"]
        assert sum(s["count"] for s in lat) == 1

        with pytest.raises(KeyError):
            svc.resubmit("nobody")
    finally:
        svc.close()


def test_service_warm_metrics_preregistered():
    """A dump taken before any warm solve still carries the repartition
    catalogue (the schema test validates exactly this shape)."""
    from repro.serve.partition_service import PartitionService

    svc = PartitionService()
    snap = svc.registry.snapshot()
    assert "repartition.submitted" in snap["counters"]
    modes = {s["labels"]["mode"]
             for s in snap["counters"]["repartition.solves"]}
    assert modes == {"warm", "fallback-drift", "fallback-audit"}
    hist = snap["histograms"]["repartition.solve_latency.s"]
    assert len(hist) == 1 and hist[0]["count"] == 0
    svc.close()


# --------------------------------------------------------------- forced-8dev
@pytest.mark.slow
def test_repartition_sharded_parity_inprocess_8dev():
    """Warm-start parity on real meshes (CI's forced-8 step): with race
    off, a sharded zero-delta `repartition` on (2, 4) and (1, 8) meshes is
    bit-identical to the single-device warm solve, and the sharded
    delta-apply path feeds a warm solve that matches a host-rebuilt one."""
    import jax

    if len(jax.devices()) < 8:
        pytest.skip("needs XLA_FLAGS=--xla_force_host_platform_device_count=8")
    from repro.dist import graph as dist_graph
    from repro.dist.sharding import Plan

    hg = _mkgraph()
    cold = partition(hg, **_CONSTRAINTS)
    r_host = repartition(_mkgraph(), cold.parts, _CONSTRAINTS["omega"],
                         _CONSTRAINTS["delta"], theta=_CONSTRAINTS["theta"])
    assert r_host.mode == "warm"
    for shape in ((2, 4), (1, 8)):
        plan = Plan.make(jax.make_mesh(shape, ("data", "model")))
        r_mesh = repartition(_mkgraph(), cold.parts, _CONSTRAINTS["omega"],
                             _CONSTRAINTS["delta"],
                             theta=_CONSTRAINTS["theta"], plan=plan,
                             race=False, shard_graph=True)
        assert r_mesh.mode == "warm", shape
        assert np.array_equal(r_host.parts, r_mesh.parts), shape
        assert r_host.audit == r_mesh.audit, shape

    # sharded incremental path: cache holds ShardedHypergraph, the delta
    # applies by stripe-local scatters, and the warm solve from the
    # scattered storage matches the host-rebuilt warm solve bit-for-bit
    plan = Plan.make(jax.make_mesh((2, 4), ("data", "model")))
    hg_s, hg_h = _mkgraph(), _mkgraph()
    caps = Caps.for_host(hg_s)
    cache = WarmCache(caps=caps,
                      d=dist_graph.sharded_from_host(hg_s, caps, plan))
    dl = generate.perturb_delta(hg_s, n_edges=4, seed=5)
    r_s = repartition(hg_s, cold.parts, _CONSTRAINTS["omega"],
                      _CONSTRAINTS["delta"], theta=_CONSTRAINTS["theta"],
                      deltas=dl, drift_threshold=0.9, cache=cache,
                      plan=plan, race=False, shard_graph=True)
    assert r_s.mode == "warm"
    assert isinstance(cache.d, dist_graph.ShardedHypergraph)
    dl_h = generate.perturb_delta(hg_h, n_edges=4, seed=5)
    r_h = repartition(hg_h, cold.parts, _CONSTRAINTS["omega"],
                      _CONSTRAINTS["delta"], theta=_CONSTRAINTS["theta"],
                      deltas=dl_h, drift_threshold=0.9)
    assert np.array_equal(r_s.parts, r_h.parts)
    assert r_s.audit == r_h.audit
