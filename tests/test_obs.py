"""Telemetry layer: registry semantics, span tree, watchdog counters,
per-level V-cycle stats, and the bit-exactness contract (telemetry on/off
must not change any computed result)."""
import json
import threading

import jax
import numpy as np
import pytest

from repro.core import generate
from repro.core.partitioner import partition
from repro.dist.ft import StepWatchdog
from repro.obs import metrics as obs_metrics
from repro.obs import trace as otrace


# ------------------------------------------------------------------ registry
def test_registry_counter_gauge_labeled_series():
    r = obs_metrics.Registry()
    r.counter("c", route="a")
    r.counter("c", 2.0, route="a")
    r.counter("c", route="b")
    r.gauge("g", 7.5, k="x")
    assert r.value("c", route="a") == 3.0
    assert r.value("c", route="b") == 1.0
    assert r.value("c", route="missing") == 0.0
    assert r.total("c") == 4.0
    assert r.value("g", k="x") == 7.5
    # label order must not split series
    r.counter("c2", a="1", b="2")
    r.counter("c2", b="2", a="1")
    assert r.value("c2", a="1", b="2") == 2.0


def test_registry_zero_preregisters_series():
    r = obs_metrics.Registry()
    r.counter("c", 0, route="bucket")
    snap = r.snapshot()
    assert snap["counters"]["c"] == [
        dict(labels=dict(route="bucket"), value=0.0)]


def test_registry_histogram_bucket_edges():
    r = obs_metrics.Registry()
    # edges fixed at first observation; +inf appended automatically
    r.observe("h", 0.5, buckets=(1.0, 2.0))
    r.observe("h", 1.0)    # on-edge lands in the <= 1.0 bucket
    r.observe("h", 1.5)
    r.observe("h", 99.0)   # overflow lands in +inf
    (s,) = r.snapshot()["histograms"]["h"]
    assert s["edges"] == [1.0, 2.0, "inf"]
    assert s["counts"] == [2, 1, 1]
    assert s["count"] == 4 and s["sum"] == pytest.approx(102.0)


def test_registry_series_overflow_collapses_not_crashes():
    r = obs_metrics.Registry(max_series=4)
    for i in range(10):
        r.counter("c", rid=i)
        r.observe("h", float(i), rid=i)
    snap = r.snapshot()
    assert len(snap["counters"]["c"]) <= 5  # 4 real + 1 overflow
    labels = [s["labels"] for s in snap["counters"]["c"]]
    assert {"overflow": "true"} in labels
    assert r.total("obs.series_overflow") > 0
    assert r.total("c") == 10.0  # no event dropped, only labels collapsed


def test_registry_thread_safety_hammering():
    r = obs_metrics.Registry()
    n_threads, n_iters = 8, 500

    def hammer(tid):
        for i in range(n_iters):
            r.counter("c", worker=tid % 2)
            r.gauge("g", float(i), worker=tid % 2)
            r.observe("h", 0.01 * (i % 7), worker=tid % 2)

    threads = [threading.Thread(target=hammer, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert r.total("c") == n_threads * n_iters
    hists = r.snapshot()["histograms"]["h"]
    assert sum(s["count"] for s in hists) == n_threads * n_iters


def test_registry_jsonl_and_prometheus_goldens():
    r = obs_metrics.Registry()
    r.counter("svc.reqs", 3, route="bucket")
    r.gauge("svc.pending", 2)
    r.observe("svc.lat.s", 0.02, buckets=(0.01, 0.1))
    r.observe("svc.lat.s", 0.2)
    lines = [json.loads(ln) for ln in r.to_jsonl().splitlines()]
    assert lines == [
        dict(kind="counter", name="svc.reqs",
             labels=dict(route="bucket"), value=3.0),
        dict(kind="gauge", name="svc.pending", labels={}, value=2.0),
        dict(kind="histogram", name="svc.lat.s", labels={},
             edges=[0.01, 0.1, "inf"], counts=[0, 1, 1],
             sum=pytest.approx(0.22), count=2),
    ]
    assert r.render() == (
        "# TYPE svc_reqs counter\n"
        'svc_reqs{route="bucket"} 3\n'
        "# TYPE svc_pending gauge\n"
        "svc_pending 2\n"
        "# TYPE svc_lat_s histogram\n"
        'svc_lat_s_bucket{le="0.01"} 0\n'
        'svc_lat_s_bucket{le="0.1"} 1\n'
        'svc_lat_s_bucket{le="+Inf"} 2\n'
        "svc_lat_s_sum 0.22\n"
        "svc_lat_s_count 2\n")


def test_registry_reset_and_dump_json(tmp_path):
    r = obs_metrics.Registry()
    r.counter("c")
    path = tmp_path / "m.json"
    doc = obs_metrics.dump_json(str(path), r)
    loaded = json.loads(path.read_text())
    assert loaded["metrics"]["counters"]["c"][0]["value"] == 1.0
    assert set(doc) == {"ts", "metrics", "spans"}
    r.reset()
    assert r.snapshot() == dict(counters={}, gauges={}, histograms={})


# --------------------------------------------------------------------- spans
def test_span_tree_nesting_and_attribution():
    otrace.reset()
    with otrace.span("outer", level=0) as sp_out:
        with otrace.span("inner_a") as sp_a:
            pass
        with otrace.span("inner_b"):
            pass
    assert sp_out.t1 is not None
    assert [c.name for c in sp_out.children] == ["inner_a", "inner_b"]
    assert sp_out.find("inner_b") is sp_out.children[1]
    assert sp_out.duration >= sp_a.duration
    # self time excludes children; all non-negative
    assert 0 <= sp_out.self_time <= sp_out.duration
    assert otrace.last_root("outer") is sp_out
    agg = {a["name"]: a for a in otrace.aggregate()}
    assert agg["outer"]["count"] == 1 and agg["inner_a"]["count"] == 1
    assert agg["outer"]["total_s"] == pytest.approx(sp_out.duration)


def test_span_sync_blocks_device_value():
    with otrace.span("devwork") as sp:
        x = sp.sync(jax.numpy.arange(8) * 2)
    assert sp._sync is None  # drained at exit
    np.testing.assert_array_equal(np.asarray(x), np.arange(8) * 2)


def test_span_observes_metrics_registry():
    obs_metrics.REGISTRY.reset()
    with otrace.span("phasex"):
        pass
    hists = obs_metrics.REGISTRY.snapshot()["histograms"]
    assert "span.phasex.s" in hists
    assert hists["span.phasex.s"][0]["count"] == 1


def test_span_roots_bounded():
    otrace.reset()
    for i in range(otrace.MAX_ROOTS + 10):
        with otrace.span("r", i=i):
            pass
    assert len(otrace.roots()) == otrace.MAX_ROOTS
    assert otrace.roots()[-1].attrs["i"] == otrace.MAX_ROOTS + 9


def test_span_chrome_trace_export(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TRACE_DIR", str(tmp_path))
    with otrace.span("traced_root"):
        with otrace.span("traced_child"):
            pass
    (path,) = tmp_path.glob("trace-*.trace.json")
    text = path.read_text()
    # chrome trace array format tolerates the missing close bracket
    events = json.loads(text.rstrip().rstrip(",") + "]")
    names = [e["name"] for e in events]
    assert "traced_root" in names and "traced_child" in names
    assert all(e["ph"] == "X" and e["dur"] >= 0 for e in events)


# ------------------------------------------------------------------ watchdog
def test_watchdog_bounded_fired_steps_and_reset():
    fired = []
    ev = threading.Event()

    def on_stall(step):
        fired.append(step)
        ev.set()

    wd = StepWatchdog(0.0, on_stall, max_fired=4)  # fires immediately
    for step in range(10):
        ev.clear()
        with wd.watch(step):
            assert ev.wait(5.0), f"watchdog never fired for step {step}"
    assert len(wd.fired_steps) == 4  # bounded deque kept the newest
    assert list(wd.fired_steps) == [6, 7, 8, 9]
    assert fired == list(range(10))
    wd.reset()
    assert not wd.fired_steps
    wd.stop()


def test_watchdog_registry_counters_and_stall_histogram():
    r = obs_metrics.Registry()
    ev = threading.Event()
    wd = StepWatchdog(0.05, lambda s: ev.set(), registry=r)
    with wd.watch(0):
        assert ev.wait(5.0)
    wd.stop()
    assert r.total("watchdog.stalls") == 1.0
    (h,) = r.snapshot()["histograms"]["watchdog.stall.s"]
    assert h["count"] == 1 and h["sum"] >= 0.05


# ----------------------------------------------------- partitioner telemetry
HG = generate.snn_smallworld(n_nodes=96, fanout=6, seed=3)
OM, DL = 24, 96


def test_partition_timings_is_span_view():
    """Transition contract: the legacy timings dict is a thin view over the
    span tree — identical floats, not merely close."""
    res = partition(HG, omega=OM, delta=DL, theta=4)
    root = otrace.last_root("partition")
    assert root is not None
    assert res.timings["total"] == root.duration
    assert res.timings["coarsen"] == root.find("coarsen").duration
    assert res.timings["refine"] == root.find("refine").duration
    assert {c.name for c in root.children} >= {"setup", "coarsen",
                                               "refine", "audit"}
    n_rl = len([s for s in root.find("refine").children
                if s.name == "refine_level"])
    assert n_rl == res.n_levels + 1


def test_partition_level_stats_structural():
    res = partition(HG, omega=OM, delta=DL, theta=4)
    ls = res.level_stats
    assert len(ls) == res.n_levels + 1
    assert ls[0].level == 0 and ls[0].nodes == HG.n_nodes
    assert ls[0].edges == HG.n_edges and ls[0].pins == HG.n_pins
    # node counts shrink as the V-cycle coarsens
    for a, b in zip(ls, ls[1:]):
        assert b.nodes <= a.nodes
    for s in ls[:-1]:
        assert s.pairs_live is not None and 0 <= s.pair_occupancy <= 1
        assert s.nbr_entries is not None and 0 <= s.nbr_occupancy <= 1
        assert s.kernel_coarsen in (0, 1)
    for s in ls:
        assert s.kernel_refine is not None
        assert s.connectivity is None  # quality gated off by default
    d = ls[0].to_dict()
    assert d["level"] == 0 and "pair_occupancy" in d


def test_partition_collect_stats_quality_matches_audit():
    res = partition(HG, omega=OM, delta=DL, theta=4, collect_stats=True)
    ls = res.level_stats
    for s in ls:
        assert s.connectivity is not None and s.cut_net is not None
        assert s.max_size is not None and s.max_size <= OM
        assert s.size_slack == OM - s.max_size
        assert s.max_inbound is not None and s.max_inbound <= DL
        assert s.inbound_slack == DL - s.max_inbound
    # level 0 quality is the final partition: must equal the host audit
    assert ls[0].connectivity == pytest.approx(res.connectivity)
    assert ls[0].cut_net == pytest.approx(res.cut_net)
    assert ls[0].max_size == res.audit["max_size"]


def test_partition_telemetry_parity_bit_exact():
    """The bit-exactness contract: collect_stats on/off (and spans, which
    are always on) change no computed result."""
    base = partition(HG, omega=OM, delta=DL, theta=4)
    stats = partition(HG, omega=OM, delta=DL, theta=4, collect_stats=True)
    np.testing.assert_array_equal(base.parts, stats.parts)
    assert base.connectivity == stats.connectivity
    assert base.cut_net == stats.cut_net
    assert base.audit == stats.audit


def test_kway_timings_and_level_stats():
    from repro.core.kway import partition_kway
    res = partition_kway(HG, k=4, theta=4, collect_stats=True)
    root = otrace.last_root("partition_kway")
    assert res.timings["total"] == root.duration
    assert root.find("initial_kway") is not None
    ls = res.level_stats
    assert len(ls) == res.n_levels + 1
    assert ls[0].connectivity == pytest.approx(res.connectivity)
    # Delta is +inf in k-way mode: inbound slack still finite/meaningful
    assert ls[0].max_inbound is not None


@pytest.mark.slow
def test_obs_parity_inprocess_8dev():
    """Forced-8 acceptance: telemetry on/off is bit-identical through the
    mesh-sharded race=False path too."""
    if len(jax.devices()) < 8:
        pytest.skip("needs XLA_FLAGS=--xla_force_host_platform_device_count=8")
    from repro.dist.sharding import Plan
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    plan = Plan.make(mesh)
    hg = generate.snn_smallworld(n_nodes=200, fanout=10, seed=7)
    kw = dict(omega=32, delta=128, theta=8, plan=plan, shard_graph=True,
              race=False)
    base = partition(hg, **kw)
    stats = partition(hg, collect_stats=True, **kw)
    np.testing.assert_array_equal(base.parts, stats.parts)
    assert base.audit == stats.audit
    # sharded storage: structural stats present, quality side stays None
    assert stats.level_stats and stats.level_stats[0].nodes == hg.n_nodes
    assert stats.level_stats[0].connectivity is None
