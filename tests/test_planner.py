"""Placement planner: the paper's partitioner improving MoE all-to-all."""
import numpy as np

from repro.configs import get_config
from repro.core import planner


def test_expert_placement_beats_identity():
    cfg = get_config("llama4-scout-17b-16e").smoke()  # 4 experts smoke
    import dataclasses
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, n_experts=16, top_k=2))
    out = planner.plan_expert_placement(cfg, n_shards=4, seed=0, theta=4)
    perm = out["perm"]
    assert sorted(perm.tolist()) == list(range(16))  # a permutation
    # each shard owns exactly E/k slots
    shard_of = out["parts"]
    counts = np.bincount(shard_of, minlength=4)
    assert (counts == 4).all()
    assert out["report"]["a2a_reduction"] >= 1.0  # no worse than identity


def test_stage_assignment_balanced():
    cfg = get_config("qwen2-1.5b")
    out = planner.plan_stage_assignment(cfg, n_stages=4, theta=2)
    st = out["stage_of_layer"]
    assert len(st) == cfg.n_layers
    counts = np.bincount(st, minlength=4)
    assert counts.max() <= np.ceil(cfg.n_layers / 4 * 1.25)
