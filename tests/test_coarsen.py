"""Coarsening: eta/inter oracle, constraint validity per level, coarse
hypergraph structural invariants (paper Secs. V-B/C/E)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import generate, metrics
from repro.core import hypergraph as H
from repro.core.coarsen import CoarsenParams, coarsen_step, propose, score_slots
from repro.core.contract import contract


def eta_inter_oracle(hg):
    """Numpy histogram exactly as Eq. 5 + inter counter (Fig. 3)."""
    eta, inter = {}, {}
    for e in range(hg.n_edges):
        pins = hg.edge(e)
        dst = set(hg.dst(e).tolist())
        w = hg.edge_w[e] / len(pins)
        for a in pins:
            for b in pins:
                if a == b:
                    continue
                eta[(a, b)] = eta.get((a, b), 0.0) + w
                if a in dst and b in dst:
                    inter[(a, b)] = inter.get((a, b), 0) + 1
    return eta, inter


def test_eta_inter_match_oracle():
    hg = generate.random_kuniform(30, 40, 5, seed=2, n_src=2, weighted=True)
    caps = H.Caps.for_host(hg)
    d = H.device_from_host(hg, caps)
    pairs = H.build_pairs(d, caps)
    nbrs = H.build_neighbors(pairs, d, caps)
    eta, inter = score_slots(d, nbrs, pairs, caps)
    eta_o, inter_o = eta_inter_oracle(hg)
    off, ids = np.asarray(nbrs.off), np.asarray(nbrs.ids)
    eta_np, inter_np = np.asarray(eta), np.asarray(inter)
    for n in range(hg.n_nodes):
        for s in range(off[n], off[n + 1]):
            m = ids[s]
            assert abs(eta_np[s] - eta_o.get((n, m), 0.0)) < 1e-4
            assert inter_np[s] == inter_o.get((n, m), 0)


def test_coarsening_levels_respect_constraints():
    hg = generate.snn_smallworld(n_nodes=120, fanout=6, seed=5)
    caps = H.Caps.for_host(hg)
    d = H.device_from_host(hg, caps)
    params = CoarsenParams(omega=12, delta=40)
    for lvl in range(6):
        match, n_pairs, _ = coarsen_step(d, caps, params)
        if int(n_pairs) == 0:
            break
        d2, gamma = contract(d, match, caps)
        n = int(d.n_nodes)
        g = np.asarray(gamma)[:n]
        host = H.host_from_device(d)
        sizes, inbound = metrics.partition_loads(
            host, g, np.asarray(d.node_size)[:n])
        assert (sizes <= params.omega).all()
        assert (inbound <= params.delta).all()
        # device bookkeeping must agree with host recomputation
        nn = int(d2.n_nodes)
        np.testing.assert_array_equal(np.asarray(d2.node_size)[:nn], sizes)
        np.testing.assert_array_equal(np.asarray(d2.node_nin)[:nn], inbound)
        d = d2


def test_contract_structural_invariants():
    hg = generate.ispd_like(n_nodes=150, seed=7)
    caps = H.Caps.for_host(hg)
    d = H.device_from_host(hg, caps)
    params = CoarsenParams(omega=8, delta=2**20)
    match, _, _ = coarsen_step(d, caps, params)
    d2, gamma = contract(d, match, caps)
    h2 = H.host_from_device(d2)
    h2.validate()  # unique pins per edge, valid offsets
    # edge identity/weights preserved
    assert h2.n_edges == hg.n_edges
    np.testing.assert_array_equal(h2.edge_w, hg.edge_w)
    # pin sets are gamma images
    g = np.asarray(gamma)[: hg.n_nodes]
    for e in range(0, hg.n_edges, 17):
        assert set(h2.edge(e).tolist()) == {int(g[p]) for p in hg.edge(e)}
        # src pins that also appear as dst are dropped from src (paper V-E)
        src2 = set(h2.src(e).tolist())
        dst2 = set(h2.dst(e).tolist())
        assert not (src2 & dst2)


def test_coarsen_params_rejects_unknown_matching():
    """An unknown matching mode used to silently fall through to the exact
    DP (the `else` branch in `run_matching_rounds`); it must raise."""
    with pytest.raises(ValueError, match="matching"):
        CoarsenParams(omega=8, delta=16, matching="bogus")
    # the two documented modes still construct
    CoarsenParams(omega=8, delta=16, matching="exact")
    CoarsenParams(omega=8, delta=16, matching="greedy")


def test_propose_respects_validity_mask():
    hg = generate.random_kuniform(40, 60, 4, seed=3)
    caps = H.Caps.for_host(hg)
    d = H.device_from_host(hg, caps)
    pairs = H.build_pairs(d, caps)
    nbrs = H.build_neighbors(pairs, d, caps)
    params = CoarsenParams(omega=1, delta=2**20)  # size 1 => nothing valid
    props = propose(d, nbrs, pairs, caps, params)
    assert (np.asarray(props.cand_ids)[0][: hg.n_nodes] == -1).all()
