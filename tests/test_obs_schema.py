"""Metrics-dump schema contract: the ``--metrics-json`` document shape is
pinned by ``tests/data/metrics_schema.json`` (a JSON-Schema subset checked
by the hand-rolled validator below — no jsonschema dependency).

Two modes:

* ``REPRO_METRICS_DUMP=<path>`` (the CI metrics-smoke step sets it after
  running ``benchmarks.run --only partition_service --smoke
  --metrics-json``): validates that file — either the per-lane
  ``{"lanes": {...}}`` wrapper or a bare ``{ts, metrics, spans}`` document.
* no env: generates a dump in-process (a tiny service flood into a private
  registry) and validates that, so the contract is enforced even where the
  benchmark has not run.

The ``x-required-metrics`` section of the schema pins the series a
`PartitionService` lane must carry; a rename in the service silently
breaking dashboards fails here first.
"""
import json
import os
import pathlib

import pytest

SCHEMA_PATH = pathlib.Path(__file__).parent / "data" / "metrics_schema.json"

_TYPES = {
    "object": dict,
    "array": list,
    "string": str,
    "boolean": bool,
    "null": type(None),
}


def validate(instance, schema, path="$"):
    """Minimal JSON-Schema-subset validator: type, required, properties,
    additionalProperties, items, anyOf, enum. Raises AssertionError with
    the failing path."""
    if "anyOf" in schema:
        errs = []
        for sub in schema["anyOf"]:
            try:
                validate(instance, sub, path)
                break
            except AssertionError as e:
                errs.append(str(e))
        else:
            raise AssertionError(f"{path}: no anyOf branch matched: {errs}")
        return
    if "enum" in schema:
        assert instance in schema["enum"], \
            f"{path}: {instance!r} not in enum {schema['enum']}"
        return
    t = schema.get("type")
    if t == "number":
        assert isinstance(instance, (int, float)) \
            and not isinstance(instance, bool), f"{path}: not a number"
    elif t == "integer":
        assert isinstance(instance, int) and not isinstance(instance, bool), \
            f"{path}: not an integer"
    elif t is not None:
        assert isinstance(instance, _TYPES[t]), f"{path}: not {t}"
    if t == "object":
        props = schema.get("properties", {})
        for req in schema.get("required", []):
            assert req in instance, f"{path}: missing required {req!r}"
        addl = schema.get("additionalProperties", True)
        for k, v in instance.items():
            if k in props:
                validate(v, props[k], f"{path}.{k}")
            elif addl is False:
                raise AssertionError(f"{path}: unexpected property {k!r}")
            elif isinstance(addl, dict):
                validate(v, addl, f"{path}.{k}")
    elif t == "array" and "items" in schema:
        for i, v in enumerate(instance):
            validate(v, schema["items"], f"{path}[{i}]")


def _schema():
    return json.loads(SCHEMA_PATH.read_text())


def _check_required(doc, schema):
    req = schema["x-required-metrics"]
    for kind in ("counters", "gauges", "histograms"):
        missing = [n for n in req[kind] if n not in doc["metrics"][kind]]
        assert not missing, f"dump missing required {kind}: {missing}"
    span_names = {s["name"] for s in doc["spans"]}
    missing = [n for n in req["spans"] if n not in span_names]
    assert not missing, f"dump missing required spans: {missing}"


def _generate_dump(tmp_path) -> dict:
    from repro.core import generate
    from repro.obs import metrics as obs_metrics
    from repro.obs import trace as otrace
    from repro.serve import PartitionService

    reg = obs_metrics.Registry()
    svc = PartitionService(theta=4, batch_slots=2, bucket_base=64,
                           route_threshold=256, registry=reg)
    for i in range(2):
        svc.submit(generate.random_kuniform(40 + 4 * i, 60, 4, seed=i),
                   omega=16, delta=256)
    svc.drain()
    svc.close()
    path = tmp_path / "metrics.json"
    obs_metrics.dump_json(str(path), reg)
    del otrace  # spans section comes from the global trace via dump_json
    return json.loads(path.read_text())


# -------------------------------------------------------- validator itself
def test_validator_rejects_bad_documents():
    schema = _schema()
    with pytest.raises(AssertionError, match="missing required"):
        validate({"ts": 0.0, "metrics": {}}, schema)
    bad = {"ts": 0.0, "spans": [],
           "metrics": {"counters": {}, "gauges": {},
                       "histograms": {"h": [{"labels": {}, "edges": ["oops"],
                                             "counts": [], "sum": 0.0,
                                             "count": 0}]}}}
    with pytest.raises(AssertionError, match="anyOf"):
        validate(bad, schema)
    with pytest.raises(AssertionError, match="not a number"):
        validate({"ts": "late", "metrics": {"counters": {}, "gauges": {},
                                            "histograms": {}}, "spans": []},
                 schema)


# ------------------------------------------------------------ the contract
def test_metrics_dump_matches_schema(tmp_path):
    schema = _schema()
    env = os.environ.get("REPRO_METRICS_DUMP")
    if env:
        doc = json.loads(pathlib.Path(env).read_text())
        lanes = doc.get("lanes")
        if lanes is not None:
            assert lanes, "dump has an empty lanes table"
            for name, lane_doc in lanes.items():
                validate(lane_doc, schema, path=f"$.lanes.{name}")
            if "partition_service" in lanes:
                _check_required(lanes["partition_service"], schema)
        else:
            validate(doc, schema)
    else:
        doc = _generate_dump(tmp_path)
        validate(doc, schema)
        _check_required(doc, schema)
