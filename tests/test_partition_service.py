"""Multi-tenant partition service: bucketed-vmap batch solves, capacity
bumps, watchdog/fault requeue, routed V-cycle lane, and the batched device
entry's bit-exact parity with the host-driven solve."""
import time

import jax
import numpy as np
import pytest

from repro.core import generate
from repro.core.hypergraph import Caps, device_from_host
from repro.core.partitioner import (_next_pow2, partition,
                                    partition_batch_device)
from repro.serve import PartitionService, stack_device_batch

OMEGA, DELTA, THETA = 16, 256, 4


def _svc(**kw):
    kw.setdefault("theta", THETA)
    kw.setdefault("batch_slots", 4)
    kw.setdefault("bucket_base", 64)
    kw.setdefault("route_threshold", 256)
    return PartitionService(**kw)


def _flood(n_reqs, seed0=0, nodes=40):
    return [generate.random_kuniform(nodes + 4 * i, 60, 4, seed=seed0 + i)
            for i in range(n_reqs)]


# ----------------------------------------------------- batched device entry
def test_batched_entry_matches_partition_b1():
    """B=1 `partition_batch_device` at exact caps is bit-identical to the
    host-driven `partition()` with the matching kcap hint (the masked-scan
    V-cycle is the same algorithm with the level loop moved on-device)."""
    hg = generate.random_kuniform(48, 64, 4, seed=0)
    caps = Caps.for_host(hg)
    kcap = _next_pow2(caps.n)
    batch = jax.tree.map(lambda x: x[None], device_from_host(hg, caps))
    out = partition_batch_device(batch, np.array([8], np.int32),
                                 np.array([64], np.int32), caps, kcap,
                                 theta=THETA, max_levels=6)
    parts_b = np.asarray(out["parts"])[0][: hg.n_nodes]
    _, inv = np.unique(parts_b, return_inverse=True)
    res = partition(hg, omega=8, delta=64, theta=THETA, max_levels=6,
                    kcap_hint=kcap)
    np.testing.assert_array_equal(inv, res.parts)
    assert int(out["n_parts"][0]) == res.n_parts
    assert int(out["n_levels"][0]) == res.n_levels


def test_stack_device_batch_shapes():
    hgs = _flood(3)
    caps = Caps(n=64, e=128, p=512, pairs=2048, nbrs=2048)
    batch = stack_device_batch(hgs, caps)
    for leaf in jax.tree.leaves(batch):
        assert leaf.shape[0] == 3
    assert batch.edge_pins.shape == (3, caps.p)
    assert np.array_equal(np.asarray(batch.n_nodes),
                          [hg.n_nodes for hg in hgs])


# --------------------------------------------------------- service scheduler
def test_service_end_to_end_all_rids_valid():
    svc = _svc()
    hgs = _flood(5)
    rids = [svc.submit(hg, omega=OMEGA, delta=DELTA) for hg in hgs]
    res = svc.drain()
    svc.close()
    assert sorted(res) == sorted(rids)
    for rid, hg in zip(rids, hgs):
        r = res[rid]
        assert r.route == "bucket"
        assert r.parts.shape == (hg.n_nodes,)
        assert r.audit["size_ok"] and r.audit["inbound_ok"]
        assert r.n_parts == r.parts.max() + 1
    # 5 requests over 4 batch slots: at least two stacked device solves
    assert svc.stats["batch_solves"] >= 2
    assert svc.pending == 0 and svc.drain() == {}


def test_service_per_request_constraints_in_one_batch():
    """Omega/Delta are traced per-lane vectors: one device batch solves
    requests with different constraints, each audited against its own."""
    svc = _svc()
    hg = generate.random_kuniform(48, 64, 4, seed=3)
    r1 = svc.submit(hg, omega=8, delta=DELTA)
    r2 = svc.submit(hg, omega=24, delta=DELTA)
    res = svc.drain()
    svc.close()
    assert svc.stats["batch_solves"] == 1  # same bucket -> one solve
    assert res[r1].audit["max_size"] <= 8
    assert res[r2].audit["max_size"] <= 24
    # tighter Omega cannot yield fewer parts
    assert res[r1].n_parts >= res[r2].n_parts


def test_service_fault_injected_solve_requeues_no_lost_rids():
    """Acceptance: a killed solve restarts and every submitted rid still
    gets a result, with the restart visible in stats and per-result."""
    calls = {"n": 0}

    def hook(route, reqs):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("injected device loss")

    svc = _svc(fault_hook=hook, max_restarts=2)
    hgs = _flood(3, seed0=10)
    rids = [svc.submit(hg, omega=OMEGA, delta=DELTA) for hg in hgs]
    res = svc.drain()
    svc.close()
    assert sorted(res) == sorted(rids), "a killed solve lost rids"
    assert svc.stats["restarts"] == 3  # all three lanes of the killed batch
    assert all(res[r].restarts == 1 for r in rids)
    assert all(res[r].audit["size_ok"] for r in rids)
    # queue-wait accounting: a requeued request's wait clock restarts on
    # re-enqueue, so its total includes the re-queue time of the killed
    # attempt; solve_s accumulates across both attempts
    assert all(res[r].queue_wait_s > 0 for r in rids)
    assert all(res[r].solve_s > 0 for r in rids)
    # the registry histograms saw one observation per finished request
    hists = svc.registry.snapshot()["histograms"]
    (qw,) = hists["service.queue_wait.s"]
    assert qw["labels"] == {"route": "bucket"} and qw["count"] == 3
    assert svc.registry.total("service.submitted") == 3


def test_service_restart_budget_exhausted_raises():
    def hook(route, reqs):
        raise RuntimeError("injected device loss")

    svc = _svc(fault_hook=hook, max_restarts=1)
    svc.submit(generate.random_kuniform(40, 60, 4, seed=0),
               omega=OMEGA, delta=DELTA)
    with pytest.raises(RuntimeError, match="injected"):
        svc.drain()
    svc.close()


def test_service_watchdog_stall_requeues():
    """A solve that outlives the watchdog deadline is recorded as a stall
    and requeued (late result discarded); the retry delivers."""
    calls = {"n": 0}

    def hook(route, reqs):
        calls["n"] += 1
        if calls["n"] == 1:
            time.sleep(0.25)  # outlive the deadline inside the armed window

    svc = _svc(fault_hook=hook, deadline_s=0.05, max_restarts=2)
    rid = svc.submit(generate.random_kuniform(40, 60, 4, seed=1),
                     omega=OMEGA, delta=DELTA)
    res = svc.drain()
    svc.close()
    assert svc.stats["stalls"] >= 1
    assert svc.stall_log  # on_stall callback observed the stuck solve no.
    assert rid in res and res[rid].restarts >= 1
    assert res[rid].audit["size_ok"]
    # solve_s spans every attempt, so the stalled first solve's 0.25 s
    # sleep must be included; queue_wait_s includes the re-queue wait
    assert res[rid].solve_s >= 0.25
    assert res[rid].queue_wait_s > 0
    # the stall also landed in the watchdog's registry counter
    assert svc.registry.total("watchdog.stalls") >= 1


def test_service_bucket_bump_and_routing():
    """Placement: pair expansion over a bucket's cap bumps the request up
    the ladder (CapacityError audit), and over-threshold graphs skip the
    ladder for the routed V-cycle lane."""
    svc = _svc(route_threshold=2048)
    # 12-uniform edges: pair expansion (120 * 12 * 11 = 15840) exceeds the
    # pairs cap of every bucket below n=1024 (16n), so placement must bump
    # up the ladder even though the graph has only 60 nodes
    dense = generate.random_kuniform(60, 120, 12, seed=2)
    svc.submit(dense, omega=OMEGA, delta=DELTA)
    (bucket_i,) = svc._backlogs.keys()
    assert bucket_i > 0
    assert svc.bucket(bucket_i).caps.pairs >= 15840
    svc.close()
    svc = _svc()  # short ladder: route_threshold=256 tops out at pairs=8192
    svc.submit(dense, omega=OMEGA, delta=DELTA)  # fits no bucket -> routed
    big = generate.random_kuniform(300, 300, 4, seed=2)  # > route_threshold
    svc.submit(big, omega=64, delta=DELTA)
    assert not svc._backlogs and len(svc._routed) == 2
    svc.close()


@pytest.mark.slow
def test_service_routed_matches_direct_partition():
    """The routed lane is the existing host-driven solve: identical result
    to calling `partition()` directly with the service's solver params."""
    hg = generate.random_kuniform(64, 96, 4, seed=9)
    svc = _svc(route_threshold=32)  # force the routed lane
    rid = svc.submit(hg, omega=20, delta=512)
    res = svc.drain()
    svc.close()
    assert res[rid].route == "vcycle"
    direct = partition(hg, omega=20, delta=512, theta=THETA)
    np.testing.assert_array_equal(res[rid].parts, direct.parts)
    assert res[rid].audit == direct.audit


@pytest.mark.slow
def test_service_routed_sharded_inprocess_8dev():
    """Over-threshold requests route to the mesh-sharded V-cycle
    (`plan=`, `shard_graph=True`): same result as calling the sharded
    `partition()` directly. Runs only under the CI forced-8 step."""
    if len(jax.devices()) < 8:
        pytest.skip("needs XLA_FLAGS=--xla_force_host_platform_device_count=8")
    from repro.dist.sharding import Plan
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    plan = Plan.make(mesh)
    hg = generate.snn_smallworld(n_nodes=200, fanout=10, seed=7)
    svc = _svc(route_threshold=64, plan=plan, shard_graph=True, race=False,
               theta=8)
    rid = svc.submit(hg, omega=32, delta=128)
    res = svc.drain()
    svc.close()
    assert res[rid].route == "vcycle-sharded"
    direct = partition(hg, omega=32, delta=128, theta=8, plan=plan,
                       shard_graph=True, race=False)
    np.testing.assert_array_equal(res[rid].parts, direct.parts)
    assert res[rid].audit == direct.audit
    assert res[rid].audit["size_ok"] and res[rid].audit["inbound_ok"]
