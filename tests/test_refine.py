"""Refinement: pins matrix, isolation gains, in-sequence gains (exact vs
brute force AND vs a literal Eq. 14/15 oracle), events-based selection vs
step-by-step simulation (paper Sec. VI)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import generate, metrics
from repro.core import hypergraph as H
from repro.core import refine as R


def _setup(seed, n=36, e=54, k=4, K=5, kcap=8, omega=11, delta=40):
    rng = np.random.default_rng(seed)
    hg = generate.random_kuniform(n_nodes=n, n_edges=e, k=k, seed=seed,
                                  weighted=True)
    caps = H.Caps.for_host(hg)
    d = H.device_from_host(hg, caps)
    parts0 = rng.integers(0, K, size=hg.n_nodes).astype(np.int32)
    parts = jnp.asarray(np.pad(parts0, (0, caps.n - hg.n_nodes)))
    params = R.RefineParams(omega=omega, delta=delta, theta=1)
    return hg, caps, d, parts0, parts, params, K, kcap


def test_pins_matrix_oracle():
    hg, caps, d, parts0, parts, params, K, kcap = _setup(0)
    pins, pins_in = R.pins_matrix(d, parts, caps, kcap)
    p_np = np.zeros((kcap, caps.e), np.int32)
    pi_np = np.zeros((kcap, caps.e), np.int32)
    for e in range(hg.n_edges):
        for idx, p in enumerate(hg.edge(e)):
            p_np[parts0[p], e] += 1
            if idx >= hg.edge_nsrc[e]:
                pi_np[parts0[p], e] += 1
    np.testing.assert_array_equal(np.asarray(pins), p_np)
    np.testing.assert_array_equal(np.asarray(pins_in), pi_np)


def test_isolation_gains_match_connectivity_delta():
    hg, caps, d, parts0, parts, params, K, kcap = _setup(1)
    pins, _ = R.pins_matrix(d, parts, caps, kcap)
    move_to, gain_iso, _, _ = R.propose_moves(
        d, parts, pins, caps, kcap, params, jnp.asarray(False), jnp.int32(K))
    mv, gi = np.asarray(move_to), np.asarray(gain_iso)
    conn0 = metrics.connectivity(hg, parts0)
    for n in range(hg.n_nodes):
        if mv[n] >= 0:
            p2 = parts0.copy()
            p2[n] = mv[n]
            assert abs((conn0 - metrics.connectivity(hg, p2)) - gi[n]) < 1e-4


def _sequence(hg, caps, d, parts0, parts, params, K, kcap):
    pins, pins_in = R.pins_matrix(d, parts, caps, kcap)
    move_to, gain_iso, _, _ = R.propose_moves(
        d, parts, pins, caps, kcap, params, jnp.asarray(False), jnp.int32(K))
    seq, _ = R.build_sequence(d, parts, move_to, gain_iso, caps, kcap, params)
    gain_seq = R.inseq_gains(d, parts, pins, move_to, gain_iso, seq, caps,
                             kcap)
    return pins, pins_in, move_to, gain_iso, seq, gain_seq


def literal_eq14_15(hg, parts0, mv, gi, sq, pins_np):
    """The paper's OR-form: used to document where it under-counts."""
    node_off, node_edges, _, _ = hg.incidence()
    out = {}
    for n in range(hg.n_nodes):
        if mv[n] < 0:
            continue
        g = gi[n]
        ps_n, pd_n = parts0[n], mv[n]
        for idx in range(node_off[n], node_off[n + 1]):
            e = node_edges[idx]
            w = hg.edge_w[e]
            earlier = [m for m in hg.edge(e)
                       if m != n and mv[m] >= 0 and sq[m] < sq[n]]
            a_pd = sum(1 for m in earlier if parts0[m] == pd_n)
            b_pd = sum(1 for m in earlier if mv[m] == pd_n)
            a_ps = sum(1 for m in earlier if parts0[m] == ps_n)
            b_ps = sum(1 for m in earlier if mv[m] == ps_n)
            Ppd, Pps = pins_np[pd_n, e], pins_np[ps_n, e]
            c1 = ((a_pd - b_pd == Ppd) and Ppd > 0) or (b_ps > 0 and Pps == 1)
            c2 = ((a_ps - b_ps == Pps - 1) and Pps - 1 > 0) or \
                 (b_pd > 0 and Ppd == 0)
            g += (-w if c1 else 0) + (w if c2 else 0)
        out[n] = g
    return out


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_inseq_gains_exact_per_prefix(seed):
    hg, caps, d, parts0, parts, params, K, kcap = _setup(seed)
    _, _, move_to, _, seq, gain_seq = _sequence(
        hg, caps, d, parts0, parts, params, K, kcap)
    mv, sq, gs = np.asarray(move_to), np.asarray(seq), np.asarray(gain_seq)
    order = [n for n in np.argsort(sq[: hg.n_nodes]) if mv[n] >= 0]
    p_cur = parts0.copy()
    conn_prev = metrics.connectivity(hg, parts0)
    for n in order:
        p_cur[n] = mv[n]
        c = metrics.connectivity(hg, p_cur)
        assert abs((conn_prev - c) - gs[n]) < 1e-4
        conn_prev = c


def test_inseq_matches_literal_form_when_single_clause():
    """Where exactly one clause of Eq. 14/15 fires, our exact form equals
    the paper's literal OR-form (regression for the documented deviation)."""
    hg, caps, d, parts0, parts, params, K, kcap = _setup(5)
    pins, _, move_to, gain_iso, seq, gain_seq = _sequence(
        hg, caps, d, parts0, parts, params, K, kcap)
    mv, sq = np.asarray(move_to), np.asarray(seq)
    lit = literal_eq14_15(hg, parts0, mv, np.asarray(gain_iso), sq,
                          np.asarray(pins))
    gs = np.asarray(gain_seq)
    agree = sum(1 for n, v in lit.items() if abs(gs[n] - v) < 1e-4)
    # the OR-form agrees on the large majority of moves; the exact form
    # (ours) diverges precisely where both clauses fire (DESIGN.md §8.6)
    assert agree >= 0.7 * max(len(lit), 1)


@pytest.mark.parametrize("seed", [0, 2, 4])
def test_events_select_bruteforce_best_valid_prefix(seed):
    hg, caps, d, parts0, parts, params, K, kcap = _setup(seed)
    _, pins_in, move_to, _, seq, gain_seq = _sequence(
        hg, caps, d, parts0, parts, params, K, kcap)
    apply_mask, applied_gain = R.events_validity(
        d, parts, pins_in, move_to, seq, gain_seq, caps, kcap, params)
    mv, sq, gs = np.asarray(move_to), np.asarray(seq), np.asarray(gain_seq)
    order = [n for n in np.argsort(sq[: hg.n_nodes]) if mv[n] >= 0]
    p_cur = parts0.copy()
    viol, cum = [], []
    tot = 0.0
    for n in order:
        p_cur[n] = mv[n]
        a = metrics.audit(hg, p_cur.astype(np.int64), params.omega,
                          params.delta)
        viol.append(a["n_size_violations"] + a["n_inbound_violations"])
        tot += gs[n]
        cum.append(tot)
    cands = [t for t in range(len(order)) if viol[t] == 0]
    bt = max(cands, key=lambda t: (cum[t], -t)) if cands else None
    expect = set(order[: bt + 1]) if (bt is not None and cum[bt] > 0) else set()
    got = set(np.where(np.asarray(apply_mask)[: hg.n_nodes])[0])
    assert got == expect
    if expect:
        assert abs(float(applied_gain) - cum[bt]) < 1e-4


def test_refine_step_monotone_and_valid():
    hg, caps, d, parts0, parts, params, K, kcap = _setup(3, omega=12)
    conn0 = metrics.connectivity(hg, parts0)
    p = parts
    for rep in range(3):
        p, g, nmv, _, _ = R.refine_step(d, p, jnp.int32(K), caps, kcap,
                                        params, enforce_size=True)
    parts1 = np.asarray(p)[: hg.n_nodes]
    conn1 = metrics.connectivity(hg, parts1)
    assert conn1 <= conn0 + 1e-6
