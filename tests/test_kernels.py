"""Pallas kernels: shape/dtype sweeps vs pure-jnp oracles (interpret mode),
plus end-to-end equality of the kernel-routed partitioner paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.gains.kernel import gains_pallas
from repro.kernels.gains.ref import gains_ref
from repro.kernels.pair_scores.kernel import pair_scores_pallas
from repro.kernels.pair_scores.ref import pair_scores_ref
from repro.kernels.pins_count.kernel import pins_count_pallas
from repro.kernels.pins_count.ref import pins_count_ref


@pytest.mark.parametrize("e,d,k", [(8, 128, 8), (16, 256, 16), (32, 128, 4),
                                   (8, 384, 64)])
def test_pins_count_sweep(e, d, k, rng):
    parts = rng.integers(0, k + 1, size=(e, d)).astype(np.int32)
    dst = rng.integers(0, 2, size=(e, d)).astype(np.int32)
    p1, pi1 = pins_count_pallas(jnp.asarray(parts), jnp.asarray(dst), k,
                                te=8, dc=128)
    p2, pi2 = pins_count_ref(jnp.asarray(parts), jnp.asarray(dst), k)
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
    np.testing.assert_array_equal(np.asarray(pi1), np.asarray(pi2))


@pytest.mark.parametrize("n,u,l", [(8, 128, 128), (16, 128, 256),
                                   (8, 256, 384)])
@pytest.mark.parametrize("wdtype", [jnp.float32])
def test_pair_scores_sweep(n, u, l, wdtype, rng):
    nbr = rng.integers(0, 60, size=(n, u)).astype(np.int32)
    nbr[:, u // 2:] = -1
    m = rng.integers(0, 60, size=(n, l)).astype(np.int32)
    m[:, int(l * 0.8):] = -2
    w = rng.random((n, l)).astype(np.float32)
    dd = rng.integers(0, 2, size=(n, l)).astype(np.int32)
    e1, i1 = pair_scores_pallas(*map(jnp.asarray, (nbr, m, w, dd)),
                                tn=8, lc=128)
    e2, i2 = pair_scores_ref(*map(jnp.asarray, (nbr, m, w, dd)))
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


@pytest.mark.parametrize("n,h,e,k", [(8, 8, 16, 8), (16, 16, 64, 16),
                                     (8, 4, 32, 128)])
def test_gains_sweep(n, h, e, k, rng):
    inc = rng.integers(0, e, size=(n * h,)).astype(np.int32)
    w = rng.random((n, h)).astype(np.float32)
    pnz = (rng.random((e, k)) > 0.5).astype(np.float32)
    c1 = gains_pallas(jnp.asarray(inc), jnp.asarray(w), jnp.asarray(pnz), h=h)
    c2 = gains_ref(jnp.asarray(inc), jnp.asarray(w), jnp.asarray(pnz), h)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), atol=1e-5)


def test_kernel_routed_partitioner_matches_pure_jax():
    from repro.core import generate
    from repro.core.partitioner import partition
    hg = generate.snn_smallworld(n_nodes=90, fanout=5, seed=11)
    r0 = partition(hg, omega=12, delta=40, theta=2)
    r1 = partition(hg, omega=12, delta=40, theta=2, use_kernels=True)
    np.testing.assert_array_equal(r0.parts, r1.parts)
    assert r0.audit["size_ok"] and r0.audit["inbound_ok"]


def _cond_score_slots(d, nbrs, pairs, caps):
    """The exact `use_kernels=True` dispatch from `coarsen.propose`."""
    import jax
    from repro.core.coarsen import score_slots
    from repro.kernels.pair_scores import ops as ps_ops
    return jax.lax.cond(
        ps_ops.fits_kernel(d, nbrs, pairs, caps),
        lambda: ps_ops.score_slots_kernel(d, nbrs, pairs, caps),
        lambda: score_slots(d, nbrs, pairs, caps))


def test_pair_scores_cond_inside_tile_bounds(rng):
    """Graph within the level-0 tile bounds: the kernel branch is taken and
    must agree with `score_slots` (eta to fp tolerance — the kernel sums in
    a different order — inter exactly)."""
    from repro.core import generate
    from repro.core import hypergraph as H
    from repro.core.coarsen import score_slots
    from repro.kernels.pair_scores import ops as ps_ops

    hg = generate.random_kuniform(36, 50, 5, seed=4, n_src=2, weighted=True)
    caps = H.Caps.for_host(hg)
    d = H.device_from_host(hg, caps)
    pairs = H.build_pairs(d, caps)
    nbrs = H.build_neighbors(pairs, d, caps)
    assert bool(ps_ops.fits_kernel(d, nbrs, pairs, caps))
    eta_c, inter_c = _cond_score_slots(d, nbrs, pairs, caps)
    eta_k, inter_k = ps_ops.score_slots_kernel(d, nbrs, pairs, caps)
    eta_s, inter_s = score_slots(d, nbrs, pairs, caps)
    # cond took the kernel branch bit-for-bit
    np.testing.assert_array_equal(np.asarray(eta_c), np.asarray(eta_k))
    np.testing.assert_array_equal(np.asarray(inter_c), np.asarray(inter_k))
    np.testing.assert_allclose(np.asarray(eta_c), np.asarray(eta_s),
                               atol=1e-5)
    np.testing.assert_array_equal(np.asarray(inter_c), np.asarray(inter_s))


def test_pair_scores_cond_outside_tile_bounds_falls_back():
    """Graph whose per-node traversal/neighborhood exceed the (shrunken)
    level-0 tile bounds: `fits_kernel` must reject and the `lax.cond`
    fallback branch must produce bit-identical (eta, inter) to
    `score_slots` — the guard the coarse levels rely on when merged
    neighborhoods outgrow the level-0 caps."""
    import dataclasses
    from repro.core import generate
    from repro.core import hypergraph as H
    from repro.core.coarsen import score_slots
    from repro.kernels.pair_scores import ops as ps_ops

    # one 140-pin edge: every pin sees 139 unique neighbors > the 128-wide
    # tile that caps with u0 = l0 = 1 round up to
    hg = generate.random_kuniform(200, 3, 140, seed=1, n_src=2,
                                  weighted=True)
    caps0 = H.Caps.for_host(hg)
    caps = dataclasses.replace(caps0, u0=1, l0=1)
    d = H.device_from_host(hg, caps)
    pairs = H.build_pairs(d, caps)
    nbrs = H.build_neighbors(pairs, d, caps)
    assert not bool(ps_ops.fits_kernel(d, nbrs, pairs, caps))
    eta_c, inter_c = _cond_score_slots(d, nbrs, pairs, caps)
    eta_s, inter_s = score_slots(d, nbrs, pairs, caps)
    np.testing.assert_array_equal(np.asarray(eta_c), np.asarray(eta_s))
    np.testing.assert_array_equal(np.asarray(inter_c), np.asarray(inter_s))
