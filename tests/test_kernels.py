"""Pallas kernels: shape/dtype sweeps vs pure-jnp oracles (interpret mode),
plus end-to-end equality of the kernel-routed partitioner paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.gains.kernel import gains_pallas
from repro.kernels.gains.ref import gains_ref
from repro.kernels.pair_scores.kernel import pair_scores_pallas
from repro.kernels.pair_scores.ref import pair_scores_ref
from repro.kernels.pins_count.kernel import pins_count_pallas
from repro.kernels.pins_count.ref import pins_count_ref


@pytest.mark.parametrize("e,d,k", [(8, 128, 8), (16, 256, 16), (32, 128, 4),
                                   (8, 384, 64)])
def test_pins_count_sweep(e, d, k, rng):
    parts = rng.integers(0, k + 1, size=(e, d)).astype(np.int32)
    dst = rng.integers(0, 2, size=(e, d)).astype(np.int32)
    p1, pi1 = pins_count_pallas(jnp.asarray(parts), jnp.asarray(dst), k,
                                te=8, dc=128)
    p2, pi2 = pins_count_ref(jnp.asarray(parts), jnp.asarray(dst), k)
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
    np.testing.assert_array_equal(np.asarray(pi1), np.asarray(pi2))


@pytest.mark.parametrize("n,u,l", [(8, 128, 128), (16, 128, 256),
                                   (8, 256, 384)])
@pytest.mark.parametrize("wdtype", [jnp.float32])
def test_pair_scores_sweep(n, u, l, wdtype, rng):
    nbr = rng.integers(0, 60, size=(n, u)).astype(np.int32)
    nbr[:, u // 2:] = -1
    m = rng.integers(0, 60, size=(n, l)).astype(np.int32)
    m[:, int(l * 0.8):] = -2
    w = rng.random((n, l)).astype(np.float32)
    dd = rng.integers(0, 2, size=(n, l)).astype(np.int32)
    e1, i1 = pair_scores_pallas(*map(jnp.asarray, (nbr, m, w, dd)),
                                tn=8, lc=128)
    e2, i2 = pair_scores_ref(*map(jnp.asarray, (nbr, m, w, dd)))
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


@pytest.mark.parametrize("n,h,e,k", [(8, 8, 16, 8), (16, 16, 64, 16),
                                     (8, 4, 32, 128)])
def test_gains_sweep(n, h, e, k, rng):
    inc = rng.integers(0, e, size=(n * h,)).astype(np.int32)
    w = rng.random((n, h)).astype(np.float32)
    pnz = (rng.random((e, k)) > 0.5).astype(np.float32)
    c1 = gains_pallas(jnp.asarray(inc), jnp.asarray(w), jnp.asarray(pnz), h=h)
    c2 = gains_ref(jnp.asarray(inc), jnp.asarray(w), jnp.asarray(pnz), h)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), atol=1e-5)


def test_kernel_routed_partitioner_matches_pure_jax():
    from repro.core import generate
    from repro.core.partitioner import partition
    hg = generate.snn_smallworld(n_nodes=90, fanout=5, seed=11)
    r0 = partition(hg, omega=12, delta=40, theta=2)
    r1 = partition(hg, omega=12, delta=40, theta=2, use_kernels=True)
    np.testing.assert_array_equal(r0.parts, r1.parts)
    assert r0.audit["size_ok"] and r0.audit["inbound_ok"]
