"""End-to-end multi-level partitioner + k-way mode (paper Secs. III, VII-E)."""
import numpy as np

from repro.core import generate, metrics
from repro.core.kway import partition_kway
from repro.core.partitioner import partition


def test_snn_mode_valid_and_beats_trivial():
    hg = generate.snn_layered(n_layers=4, width=48, fanout=6, window=12,
                              seed=2)
    res = partition(hg, omega=24, delta=96, theta=4)
    assert res.audit["size_ok"] and res.audit["inbound_ok"]
    assert res.parts.min() >= 0
    assert len(np.unique(res.parts)) == res.n_parts
    # near-minimal partition count (paper: coarsening reaches ceil(N/Omega))
    assert res.n_parts <= 3 * int(np.ceil(hg.n_nodes / 24))


def test_snn_mode_deterministic():
    hg = generate.snn_smallworld(n_nodes=80, fanout=5, seed=9)
    r1 = partition(hg, omega=10, delta=36, theta=2)
    r2 = partition(hg, omega=10, delta=36, theta=2)
    np.testing.assert_array_equal(r1.parts, r2.parts)


def test_kway_balanced():
    hg = generate.ispd_like(n_nodes=400, seed=4)
    for k in (2, 4):
        res = partition_kway(hg, k=k, eps=0.05, theta=4, coarse_target=32)
        assert res.n_parts <= k
        assert res.audit["balance_eps"] <= 0.05 + 1e-6
        assert res.audit["size_ok"]


def test_refinement_improves_over_coarsening_only():
    hg = generate.snn_smallworld(n_nodes=150, fanout=7, seed=3)
    r_no = partition(hg, omega=16, delta=56, theta=1)
    r_ref = partition(hg, omega=16, delta=56, theta=6)
    assert r_ref.connectivity <= r_no.connectivity + 1e-6
