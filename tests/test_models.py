"""Per-architecture smoke tests (reduced configs): one forward/train step on
CPU asserting output shapes + finiteness, plus decode-vs-train consistency
for representative archs (deliverable (f))."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs
from repro.models import common
from repro.models import layers as L
from repro.models import transformer as T


def _batch(cfg, B=2, S=32):
    b = {"tokens": jnp.ones((B, S), jnp.int32),
         "labels": jnp.ones((B, S), jnp.int32)}
    if cfg.vision_dim:
        b["vision"] = jnp.ones((B, cfg.vision_tokens, cfg.vision_dim),
                               jnp.float32) * 0.01
    if cfg.encoder_layers:
        b["enc_frames"] = jnp.ones((B, 16, cfg.d_model), jnp.float32) * 0.01
    return b


@pytest.mark.parametrize("name", list_configs())
def test_arch_smoke_forward_and_train_step(name):
    cfg = get_config(name).smoke()
    params = common.materialize(T.lm_shapes(cfg), jax.random.PRNGKey(0))
    batch = _batch(cfg)
    x, _, aux = T.forward(params, batch["tokens"], cfg, mode="train",
                          remat=False, vision=batch.get("vision"),
                          enc_frames=batch.get("enc_frames"))
    S_out = batch["tokens"].shape[1] + (cfg.vision_tokens if cfg.vision_dim
                                        else 0)
    assert x.shape == (2, S_out, cfg.d_model)
    assert bool(jnp.isfinite(x).all())
    loss, m = T.loss_fn(params, batch, cfg, remat=False)
    assert bool(jnp.isfinite(loss))
    # one optimizer step
    from repro.train import optimizer as opt
    from repro.launch.steps import make_train_step
    state = opt.init_state(params)
    st2, metrics = make_train_step(cfg, None)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(st2.step) == 1


@pytest.mark.parametrize("name", ["qwen2-1.5b", "deepseek-v2-236b",
                                  "xlstm-350m", "jamba-v0.1-52b",
                                  "whisper-tiny"])
def test_decode_matches_full_forward(name):
    cfg = get_config(name).smoke()
    if cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    params = common.materialize(T.lm_shapes(cfg), jax.random.PRNGKey(0))
    B, S, CL = 2, 12, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0,
                              cfg.vocab)
    kw = {}
    if cfg.encoder_layers:
        kw["enc_frames"] = jnp.ones((B, 16, cfg.d_model), jnp.float32) * 0.01
    cache = jax.tree.map(jnp.zeros_like, common.materialize(
        T.cache_shapes(cfg, B, CL), jax.random.PRNGKey(2)))
    _, cache = T.prefill(params, toks[:, :S], cache, cfg, **kw)
    lg_d, _ = T.decode_step(params, toks[:, S:S + 1], jnp.int32(S), cache,
                            cfg)
    x, _, _ = T.forward(params, toks, cfg, mode="train", remat=False, **kw)
    lg_ref = L.unembed_apply(params["embed"], x[:, -1:], cfg)[:, 0]
    np.testing.assert_allclose(np.asarray(lg_d), np.asarray(lg_ref),
                               atol=2e-3, rtol=1e-3)
    # a uniform per-row position vector must be bit-identical to scalar pos
    cache2 = jax.tree.map(jnp.zeros_like, cache)
    _, cache2 = T.prefill(params, toks[:, :S], cache2, cfg, **kw)
    lg_v, _ = T.decode_step(params, toks[:, S:S + 1],
                            jnp.full((B,), S, jnp.int32), cache2, cfg)
    np.testing.assert_array_equal(np.asarray(lg_v), np.asarray(lg_d))


def test_flash_attention_matches_naive():
    rng = jax.random.PRNGKey(0)
    B, S, H, KV, Dh = 2, 64, 4, 2, 16
    q = jax.random.normal(rng, (B, S, H, Dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, Dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, Dh))
    out = L.flash_attention(q, k, v, causal=True, q_chunk=16, k_chunk=16)
    # naive reference
    g = H // KV
    qr = q.reshape(B, S, KV, g, Dh)
    s = jnp.einsum("bqkgd,bckd->bkgqc", qr, k) / np.sqrt(Dh)
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    a = jax.nn.softmax(s, axis=-1)
    ref = jnp.einsum("bkgqc,bckd->bqkgd", a, v).reshape(B, S, H, Dh)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_moe_capacity_and_balance_aux():
    cfg = get_config("llama4-scout-17b-16e").smoke()
    params = common.materialize(T.lm_shapes(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)) * 0.1
    p_moe = jax.tree.map(lambda a: a[0],
                         params["stack"]["slot0"]["ffn"])
    out, aux = L.moe_apply(p_moe, x, cfg)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all()) and float(aux) >= 0
