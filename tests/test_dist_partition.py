"""Mesh-sharded V-cycle (`dist.partition`) vs the single-device
partitioner.

Parity contract: with racing off every replica runs the identity tie-break
permutation, and every sharded reduction is either an integer psum, a
lexicographic (value, id) pmax, or a stripe-ordered gather + replicated
float reduction (see dist/partition.py), so the *full* distributed V-cycle
— sharded coarsening + contraction + sharded refinement — must reproduce
the single-device `partition` *bit-for-bit* (same parts array, same audit,
same level count). Memory-sharded graph storage (`shard_graph=True`,
`dist.graph.ShardedHypergraph`: pins-sized arrays as per-shard stripes
over "model") is pure layout, so the same bit-for-bit contract covers it
on both (2, 4) and (1, 8) meshes. The 8-forced-host-device variants run in a subprocess so
the main test session keeps its single-device view; CI's slow job
additionally runs this file with XLA_FLAGS already forcing 8 devices (see
.github/workflows/ci.yml), which the in-process tests pick up."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "src")

_GRAPH = dict(n_layers=4, width=24, fanout=6, window=8, seed=3)
_CONSTRAINTS = dict(omega=16, delta=64, theta=4)


def _parity_check():
    """Shared body: single-device partition vs dist partition on whatever
    mesh the current process supports. Returns (r_single, r_dist_norace,
    r_dist_race, r_dist_norace_sharded_storage)."""
    import jax
    from repro.core import generate
    from repro.core.partitioner import partition
    from repro.dist.sharding import Plan

    n = len(jax.devices())
    replicas = 2 if n >= 2 else 1
    mesh = jax.make_mesh((replicas, n // replicas), ("data", "model"))
    plan = Plan.make(mesh)
    hg = generate.snn_layered(**_GRAPH)
    r0 = partition(hg, **_CONSTRAINTS)
    r1 = partition(hg, **_CONSTRAINTS, plan=plan, race=False)
    r2 = partition(hg, **_CONSTRAINTS, plan=plan, race=True)
    r3 = partition(hg, **_CONSTRAINTS, plan=plan, race=False,
                   shard_graph=True)
    return r0, r1, r2, r3


def test_dist_partition_parity_single_device():
    """On a 1-device mesh the raced+sharded driver degenerates to exactly
    the single-device pipeline (fast, runs everywhere)."""
    import jax
    r0, r1, r2, r3 = _parity_check()
    assert np.array_equal(r0.parts, r1.parts)
    assert r0.audit["connectivity"] == r1.audit["connectivity"]
    assert r0.n_levels == r1.n_levels  # coarsening rode the mesh too
    # memory-sharded storage is pure layout: bit-exact in any mesh shape
    assert np.array_equal(r0.parts, r3.parts)
    assert r0.audit == r3.audit
    assert r0.n_levels == r3.n_levels
    if len(jax.devices()) == 1:
        # one replica -> replica 0 -> identity permutation even when racing
        assert np.array_equal(r0.parts, r2.parts)
    else:
        assert r2.audit["size_ok"] and r2.audit["inbound_ok"]


def test_coarsen_contract_level_parity():
    """`dist.partition.coarsen_level`/`contract_level` vs the single-device
    `coarsen_step`/`contract`, bit-exact field by field — on however many
    devices this session sees (8 in CI's forced-fan-out step)."""
    import dataclasses

    import jax
    from repro.core import generate
    from repro.core import hypergraph as H
    from repro.core.coarsen import CoarsenParams, coarsen_step
    from repro.core.contract import contract
    from repro.dist.sharding import Plan
    import repro.dist.partition as dp

    n = len(jax.devices())
    plan = Plan.make(jax.make_mesh((1, n), ("data", "model")))
    hg = generate.snn_layered(**_GRAPH)
    caps = H.Caps.for_host(hg)
    d = H.device_from_host(hg, caps)
    cp = CoarsenParams(omega=_CONSTRAINTS["omega"],
                       delta=_CONSTRAINTS["delta"])
    m0, np0, props0 = coarsen_step(d, caps, cp)
    m1, np1, ovf1 = dp.coarsen_level(d, caps, cp, plan)
    assert np.array_equal(np.asarray(m0), np.asarray(m1))
    assert int(np0) == int(np1)
    # overflow diagnostics agree with the single-device step and with caps
    assert int(props0.n_pairs_live) == int(ovf1[0]) <= caps.pairs
    assert int(props0.n_nbr_entries) == int(ovf1[1]) <= caps.nbrs
    d20, g0 = contract(d, m0, caps)
    d21, g1 = dp.contract_level(d, m1, caps, plan)
    assert np.array_equal(np.asarray(g0), np.asarray(g1))
    for f in dataclasses.fields(d20):
        np.testing.assert_array_equal(
            np.asarray(getattr(d20, f.name)),
            np.asarray(getattr(d21, f.name)), err_msg=f.name)


@pytest.mark.slow
def test_dist_partition_parity_inprocess_8dev():
    """Runs only when the session itself was launched with 8 forced host
    devices (the CI slow job's dedicated step)."""
    import jax
    if len(jax.devices()) < 8:
        pytest.skip("needs XLA_FLAGS=--xla_force_host_platform_device_count=8")
    r0, r1, r2, r3 = _parity_check()
    assert np.array_equal(r0.parts, r1.parts)
    assert r0.audit == r1.audit
    assert r2.audit["size_ok"] and r2.audit["inbound_ok"]
    # memory-sharded graph storage (pins stripes over "model", shared by
    # the racing replicas): bit-exact with the single-device run
    assert np.array_equal(r0.parts, r3.parts)
    assert r0.audit == r3.audit
    assert r0.n_levels == r3.n_levels


_MULTIDEV = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core import generate
    from repro.core.partitioner import partition
    from repro.dist.sharding import Plan
    from repro.models import common
    from repro.utils import segops

    assert len(jax.devices()) == 8

    # --- cross-shard segmented-scan carries on a real 8-way mesh ---------
    mesh1 = jax.make_mesh((8,), ("model",))
    rng = np.random.default_rng(0)
    vals = jnp.asarray(rng.integers(-5, 6, size=64).astype(np.int32))
    starts = rng.random(64) < 0.25
    starts[0] = True
    starts = jnp.asarray(starts)
    ctx = segops.ShardCtx(axis="model", nshards=8)
    def body(v, s):
        out, _ = ctx.segmented_scan(ctx.stripe(v), ctx.stripe(s))
        return ctx.gather(out)
    f = common.shard_map(body, mesh=mesh1, in_specs=(P(), P()),
                         out_specs=P())
    got = np.asarray(jax.jit(f)(vals, starts))
    exp = np.asarray(segops.segmented_scan(vals, starts))
    assert np.array_equal(got, exp), (got, exp)

    # --- full V-cycle parity (sharded coarsen + contract + refine): ------
    # 2 racing replicas x 4 pipeline shards and 1 x 8, race off; each mesh
    # also with memory-sharded graph storage (pins arrays striped over
    # "model", `dist.graph.ShardedHypergraph`) — still bit-exact
    hg = generate.snn_layered(n_layers=4, width=24, fanout=6, window=8,
                              seed=3)
    r0 = partition(hg, omega=16, delta=64, theta=4)
    for shape in ((2, 4), (1, 8)):
        mesh = jax.make_mesh(shape, ("data", "model"))
        plan = Plan.make(mesh)
        r1 = partition(hg, omega=16, delta=64, theta=4, plan=plan,
                       race=False)
        assert np.array_equal(r0.parts, r1.parts), shape
        assert r0.audit == r1.audit, shape
        assert r0.n_levels == r1.n_levels, shape  # coarsening on-mesh too
        rs = partition(hg, omega=16, delta=64, theta=4, plan=plan,
                       race=False, shard_graph=True)
        assert np.array_equal(r0.parts, rs.parts), ("sharded", shape)
        assert r0.audit == rs.audit, ("sharded", shape)
        assert r0.n_levels == rs.n_levels, ("sharded", shape)

    # --- sharded storage really stripes: each device holds 1/4 of the
    # pins lanes on the (2,4) mesh (replicated across the data axis)
    from repro.core.hypergraph import Caps
    from repro.dist import graph as dist_graph
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    plan = Plan.make(mesh)
    caps = Caps.for_host(hg)
    g = dist_graph.sharded_from_host(hg, caps, plan)
    per = -(-caps.p // 4)
    for f in dist_graph.PINS_FIELDS:
        arr = getattr(g.g, f)
        assert arr.shape[0] == per * 4, f
        for sh in arr.addressable_shards:
            assert sh.data.shape[0] == per, f
    assert g.pins_bytes_per_device() * 4 <= 9 * caps.p + 9 * 4  # ~1/4 + pad
    # racing replicas share the one sharded graph: raced run stays valid
    r4 = partition(hg, omega=16, delta=64, theta=4, plan=plan, race=True,
                   race_seed=1, shard_graph=True)
    assert r4.audit["size_ok"] and r4.audit["inbound_ok"]

    # --- ShardCtx.gread/gfull units on a real 8-way stripe ---------------
    mesh8 = jax.make_mesh((8,), ("model",))
    ctx8 = segops.ShardCtx(axis="model", nshards=8, graph_striped=True)
    rng8 = np.random.default_rng(1)
    col = jnp.asarray(rng8.integers(0, 100, 64).astype(np.int32))
    def gbody(c):
        t, ok = ctx8.lanes(64)
        own = ctx8.gread(ctx8.stripe(c), t, ok, -1)
        full = ctx8.gfull(ctx8.stripe(c))
        return ctx8.gather(own), full
    gf = common.shard_map(gbody, mesh=mesh8, in_specs=(P(),),
                          out_specs=(P(), P()))
    own8, full8 = jax.jit(gf)(col)
    assert np.array_equal(np.asarray(own8), np.asarray(col))
    assert np.array_equal(np.asarray(full8)[:64], np.asarray(col))

    # --- shard-only mesh (no data axis): racing must be skipped, not run
    # over the pipeline-shard axis (replicas diverging along "model" would
    # corrupt the psum'd pipelines) — parity holds even with race=True
    mesh = jax.make_mesh((8,), ("model",))
    plan = Plan.make(mesh)
    r3 = partition(hg, omega=16, delta=64, theta=4, plan=plan, race=True)
    assert np.array_equal(r0.parts, r3.parts)

    # --- racing replicas: valid audit, never worse than doing nothing ----
    mesh = jax.make_mesh((8, 1), ("data", "model"))
    plan = Plan.make(mesh)
    r2 = partition(hg, omega=16, delta=64, theta=4, plan=plan, race=True,
                   race_seed=1)
    assert r2.audit["size_ok"] and r2.audit["inbound_ok"]
    print("DIST_PARITY_OK", r0.connectivity, r2.connectivity)
""")


@pytest.mark.slow
def test_dist_partition_parity_8dev_subprocess(tmp_path):
    script = tmp_path / "dist_parity.py"
    script.write_text(_MULTIDEV)
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, str(script)], env=env,
                       capture_output=True, text=True, timeout=1800)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "DIST_PARITY_OK" in r.stdout
