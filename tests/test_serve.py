"""Serving engine: continuous batching, paged KV, greedy parity, EOS/PRNG
bug regressions. Parity tests use non-MoE archs: MoE capacity dispatch is
batch-global, the one documented exception to row independence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import common
from repro.models import transformer as T
from repro.serve import OutOfPagesError, ServeEngine


def _engine(name="qwen2-1.5b", cache_len=48, **kw):
    cfg = get_config(name).smoke()
    params = common.materialize(T.lm_shapes(cfg), jax.random.PRNGKey(0))
    return ServeEngine(cfg, params, cache_len=cache_len, **kw), cfg


def test_generate_batched_greedy_deterministic():
    eng, cfg = _engine()
    rng = np.random.default_rng(0)
    prompts = rng.integers(2, cfg.vocab, size=(3, 8), dtype=np.int32)
    o1 = eng.generate(prompts, max_new=8)
    o2 = eng.generate(prompts, max_new=8)
    np.testing.assert_array_equal(o1, o2)
    assert o1.shape == (3, 8)
    assert (o1 >= 0).all() and (o1 < cfg.vocab).all()


def test_generate_matches_stepwise_argmax():
    """Engine output must equal manually running prefill+decode."""
    eng, cfg = _engine()
    prompts = np.full((1, 6), 3, np.int32)
    out = eng.generate(prompts, max_new=4)
    cache = jax.tree.map(jnp.zeros_like, common.materialize(
        T.cache_shapes(cfg, 1, 48), jax.random.PRNGKey(0)))
    logits, cache = T.prefill(eng.params, jnp.asarray(prompts), cache, cfg)
    toks = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for i in range(4):
        toks.append(int(tok[0]))
        if int(tok[0]) == eng.eos_id:
            break
        logits, cache = T.decode_step(eng.params, tok[:, None],
                                      jnp.int32(6 + i), cache, cfg)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    np.testing.assert_array_equal(out[0][: len(toks)], toks)


# --------------------------------------------------------------- bug cluster
def test_prng_prefill_key_never_reused_for_decode():
    """Regression: the prefill-sample key used to be consumed twice (sampled
    from, then split for decode). Every sample must get a fresh split."""
    eng, cfg = _engine(temperature=1.0, record_keys=True)
    prompts = np.full((2, 4), 3, np.int32)
    eng.generate(prompts, max_new=6)
    keys = eng._keys_used
    assert any(tag == "prefill" for tag, _ in keys)
    assert any(tag == "decode" for tag, _ in keys)
    prefill = [k.tobytes() for tag, k in keys if tag == "prefill"]
    decode = [k.tobytes() for tag, k in keys if tag == "decode"]
    assert not set(prefill) & set(decode)
    allk = [k.tobytes() for _, k in keys]
    assert len(allk) == len(set(allk)), "a sample key was reused"


@pytest.mark.parametrize("policy", ["continuous", "static"])
def test_post_eos_tail_is_eos_on_early_break(policy):
    """Regression: when every row finished early the remaining out columns
    stayed 0 (pad) instead of eos_id."""
    eng, cfg = _engine(policy=policy)
    prompts = np.full((2, 4), 3, np.int32)
    eng.eos_id = cfg.vocab  # unreachable: probe the greedy first token
    t0 = int(eng.generate(prompts, max_new=1)[0, 0])
    eng.eos_id = t0  # both identical rows now finish at step 0
    out = eng.generate(prompts, max_new=6)
    assert (out == t0).all(), out


@pytest.mark.parametrize("policy", ["continuous", "static"])
def test_post_eos_tail_is_eos_mixed_lengths(policy):
    """Rows that hit EOS in-loop while others continue must pad with eos_id
    too (in-loop path, not the early-break path)."""
    eng, cfg = _engine(policy=policy)
    rng = np.random.default_rng(3)
    prompts = rng.integers(2, cfg.vocab, size=(4, 8), dtype=np.int32)
    eng.eos_id = cfg.vocab  # unreachable: record the full greedy streams
    ref = eng.generate(prompts, max_new=12)
    eng.eos_id = int(ref[0, 2])  # row 0 finishes by step 2
    out = eng.generate(prompts, max_new=12)
    assert eng.eos_id in out[0]
    assert not (out == eng.eos_id).all(), "want some rows running longer"
    for row in out:
        hits = np.flatnonzero(row == eng.eos_id)
        if hits.size:
            assert (row[hits[0]:] == eng.eos_id).all(), row


def test_cache_capacity_includes_vision_offset():
    """Regression: `assert S0 + max_new <= cache_len` ignored the
    vision-token offset, silently clamp-corrupting the last cache row."""
    cfg = get_config("internvl2-2b").smoke()
    params = common.materialize(T.lm_shapes(cfg), jax.random.PRNGKey(0))
    S0, max_new = 4, 4
    need = S0 + cfg.vision_tokens + max_new
    prompts = np.full((1, S0), 3, np.int32)
    eng = ServeEngine(cfg, params, cache_len=need - 1)
    with pytest.raises(ValueError, match="vision offset"):
        eng.generate(prompts, max_new=max_new)
    ok = ServeEngine(cfg, params, cache_len=need)
    out = ok.generate(prompts, max_new=max_new)
    assert out.shape == (1, max_new)


def test_generate_rejects_nonpositive_max_new():
    eng, _ = _engine()
    with pytest.raises(ValueError, match="max_new"):
        eng.generate(np.full((1, 4), 3, np.int32), max_new=0)


# ------------------------------------------------------- continuous batching
def _solo_tokens(eng, prompt, max_new):
    """Greedy-decode one prompt alone through the scheduler."""
    rid = eng.submit(prompt, max_new)
    return eng.drain()[rid]


@pytest.mark.parametrize("name", ["qwen2-1.5b", "xlstm-350m"])
def test_ragged_batch_matches_solo_decode(name):
    """Acceptance: greedy continuous-batch decode of a ragged batch is
    bit-identical to per-request solo decode (row independence)."""
    eng, cfg = _engine(name, n_slots=3)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(2, cfg.vocab, size=(n,), dtype=np.int32)
               for n in (4, 7, 11)]
    solo = [np.asarray(_solo_tokens(eng, p, 12)) for p in prompts]
    rids = [eng.submit(p, 12) for p in prompts]
    mixed = eng.drain()
    for rid, p, want in zip(rids, prompts, solo):
        np.testing.assert_array_equal(mixed[rid], want)


def test_slot_refill_matches_cold_submit():
    """A request admitted into a slot freed mid-decode must produce the same
    tokens as when it is the only request on a fresh engine."""
    eng, cfg = _engine(n_slots=2)
    rng = np.random.default_rng(2)
    prompts = [rng.integers(2, cfg.vocab, size=(n,), dtype=np.int32)
               for n in (5, 9, 6)]
    cold = np.asarray(_solo_tokens(eng, prompts[2], 8))
    # 2 slots, 3 requests: the third admits only after a slot frees
    rids = [eng.submit(prompts[0], 3), eng.submit(prompts[1], 10),
            eng.submit(prompts[2], 8)]
    out = eng.drain()
    assert len(out[rids[0]]) <= 3 and len(out[rids[1]]) <= 10
    np.testing.assert_array_equal(out[rids[2]], cold)


def test_generate_wrapper_matches_scheduler():
    """generate() is a thin wrapper over submit/drain: same tokens, with the
    eos_id tail padding applied."""
    eng, cfg = _engine(n_slots=4)
    rng = np.random.default_rng(4)
    prompts = rng.integers(2, cfg.vocab, size=(4, 6), dtype=np.int32)
    out = eng.generate(prompts, max_new=10)
    rids = [eng.submit(prompts[i], 10) for i in range(4)]
    res = eng.drain()
    for i, rid in enumerate(rids):
        t = res[rid]
        np.testing.assert_array_equal(out[i, :len(t)], t)
        assert (out[i, len(t):] == eng.eos_id).all()


def test_more_requests_than_slots_queue_and_finish():
    eng, cfg = _engine(n_slots=2)
    rng = np.random.default_rng(5)
    rids = [eng.submit(rng.integers(2, cfg.vocab, size=(4 + i,),
                                    dtype=np.int32), 3 + i)
            for i in range(5)]
    res = eng.drain()
    assert sorted(res) == sorted(rids)
    for i, rid in enumerate(rids):
        assert 1 <= len(res[rid]) <= 3 + i


def test_generate_ragged_matches_solo_decode():
    """Acceptance: generate() on a ragged pad-0 batch equals per-row solo
    decode — padded rows must be stripped to their true lengths before
    entering the continuous path (they used to decode at padded length)."""
    eng, cfg = _engine(n_slots=3)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(2, cfg.vocab, size=(n,), dtype=np.int32)
               for n in (4, 7, 11)]
    solo = [np.asarray(_solo_tokens(eng, p, 12)) for p in prompts]
    padded = np.zeros((3, 11), np.int32)
    for i, p in enumerate(prompts):
        padded[i, : len(p)] = p
    out = eng.generate(padded, max_new=12)
    for i, want in enumerate(solo):
        np.testing.assert_array_equal(out[i, : len(want)], want)
        assert (out[i, len(want):] == eng.eos_id).all()
    # explicit lengths= bypasses pad inference with the same result
    out2 = eng.generate(padded, max_new=12,
                        lengths=[len(p) for p in prompts])
    np.testing.assert_array_equal(out, out2)


def test_generate_refuses_reseed_with_inflight_stream():
    """Regression: generate() used to unconditionally reset self._rng,
    silently clobbering the sampling stream of in-flight streaming
    requests. It must refuse instead, leaving the stream untouched."""
    eng, cfg = _engine(n_slots=2, temperature=1.0, record_keys=True)
    eng.eos_id = cfg.vocab  # unreachable EOS: request stays in flight
    rng = np.random.default_rng(8)
    eng.submit(rng.integers(2, cfg.vocab, size=(5,), dtype=np.int32), 8)
    eng.step()
    n_keys = len(eng._keys_used)
    rng_before = np.asarray(eng._rng).tobytes()
    with pytest.raises(RuntimeError, match="reseed"):
        eng.generate(rng.integers(2, cfg.vocab, size=(1, 4),
                                  dtype=np.int32), max_new=4)
    assert np.asarray(eng._rng).tobytes() == rng_before
    assert len(eng._keys_used) == n_keys
    # the stream continues unperturbed and the engine drains clean
    res = eng.drain()
    assert len(res) == 1
    # finished-but-uncollected streaming results survive a generate() call
    rid = eng.submit(rng.integers(2, cfg.vocab, size=(4,), dtype=np.int32), 3)
    while rid not in eng._results:
        eng.step()
    eng.generate(rng.integers(2, cfg.vocab, size=(1, 4), dtype=np.int32),
                 max_new=3)
    assert rid in eng.drain()


def test_admission_lookahead_skips_page_starved_head():
    """Regression: a page-starved queue head used to block admission even
    when a later, smaller request fit the free pages. Bounded lookahead
    admits the small request past it; lookahead=0 keeps strict FIFO."""
    def run(lookahead):
        eng, cfg = _engine(n_slots=2, page_size=8, n_pages=3,
                           admit_lookahead=lookahead)
        eng.eos_id = cfg.vocab  # unreachable: deterministic lifetimes
        rng = np.random.default_rng(9)
        tok = lambda n: rng.integers(2, cfg.vocab, size=(n,), dtype=np.int32)
        eng.submit(tok(6), 10)   # 16 tokens -> 2 pages
        eng.step()               # active; 1 page (8 tokens) left
        big = eng.submit(tok(10), 10)   # 20 tokens -> 3 pages: starved
        small = eng.submit(tok(4), 3)   # 7 tokens -> 1 page: fits
        eng.step()
        admitted = {r.rid for r in eng._active.values()}
        res = eng.drain()
        assert sorted(res)[-2:] == [big, small]  # nobody starves forever
        return small in admitted

    assert run(lookahead=4), "small request must admit past starved head"
    assert not run(lookahead=0), "lookahead=0 must keep strict FIFO"


def test_out_of_pages_raises_when_idle():
    """A request that can never fit the page pool must raise, not deadlock."""
    eng, cfg = _engine(n_slots=2, page_size=16, n_pages=1)
    eng._ensure(2)
    eng.submit(np.full((8,), 3, np.int32), 12)  # needs 2 pages, pool has 1
    with pytest.raises(OutOfPagesError):
        eng.drain()


@pytest.mark.skipif(len(jax.devices()) < 8,
                    reason="needs 8 devices (forced-8 CI step)")
def test_serve_plan_sharded_paged_inprocess_8dev():
    """Plan-sharded paged engine on a real 2x4 mesh matches the unsharded
    engine bit-exactly under greedy decode."""
    from repro.dist.sharding import Plan
    cfg = get_config("qwen2-1.5b").smoke()
    params = common.materialize(T.lm_shapes(cfg), jax.random.PRNGKey(0))
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    plan = Plan.make(mesh)
    rng = np.random.default_rng(6)
    prompts = rng.integers(2, cfg.vocab, size=(3, 8), dtype=np.int32)
    host = ServeEngine(cfg, params, cache_len=48).generate(prompts, max_new=6)
    eng = ServeEngine(cfg, params, cache_len=48, plan=plan)
    np.testing.assert_array_equal(eng.generate(prompts, max_new=6), host)
    # static policy under a plan drives the seq-sharded flash-decode branch
    # with the per-row positions vector
    stat = ServeEngine(cfg, params, cache_len=48, plan=plan, policy="static")
    np.testing.assert_array_equal(stat.generate(prompts, max_new=6), host)
