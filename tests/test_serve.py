"""Serving engine: batched generation, greedy determinism, EOS handling."""
import jax
import numpy as np

from repro.configs import get_config
from repro.models import common
from repro.models import transformer as T
from repro.serve import ServeEngine


def _engine(name="qwen2-1.5b", **kw):
    cfg = get_config(name).smoke()
    params = common.materialize(T.lm_shapes(cfg), jax.random.PRNGKey(0))
    return ServeEngine(cfg, params, cache_len=48, **kw), cfg


def test_generate_batched_greedy_deterministic():
    eng, cfg = _engine()
    rng = np.random.default_rng(0)
    prompts = rng.integers(2, cfg.vocab, size=(3, 8), dtype=np.int32)
    o1 = eng.generate(prompts, max_new=8)
    o2 = eng.generate(prompts, max_new=8)
    np.testing.assert_array_equal(o1, o2)
    assert o1.shape == (3, 8)
    assert (o1 >= 0).all() and (o1 < cfg.vocab).all()


def test_generate_matches_stepwise_argmax():
    """Engine output must equal manually running prefill+decode."""
    eng, cfg = _engine()
    prompts = np.full((1, 6), 3, np.int32)
    out = eng.generate(prompts, max_new=4)
    import jax.numpy as jnp
    cache = jax.tree.map(jnp.zeros_like, common.materialize(
        T.cache_shapes(cfg, 1, 48), jax.random.PRNGKey(0)))
    logits, cache = T.prefill(eng.params, jnp.asarray(prompts), cache, cfg)
    toks = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for i in range(4):
        toks.append(int(tok[0]))
        if int(tok[0]) == eng.eos_id:
            break
        logits, cache = T.decode_step(eng.params, tok[:, None],
                                      jnp.int32(6 + i), cache, cfg)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    np.testing.assert_array_equal(out[0][: len(toks)], toks)
