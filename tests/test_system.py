"""End-to-end behaviour tests for the paper's system: full multi-level
partitioning vs the three baselines (quality ordering claims from Fig. 7),
and the framework integration (planner -> MoE routing permutation used in a
real forward pass)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines import (onepass_partition, overlap_partition,
                             sequential_multilevel)
from repro.configs import get_config
from repro.core import generate, metrics, planner
from repro.core.partitioner import partition


def test_full_system_beats_or_matches_baselines():
    """Paper Fig. 7 directional claim: ours <= baselines on connectivity
    at matched constraints (synthetic analogue, small scale)."""
    hg = generate.snn_layered(n_layers=4, width=56, fanout=7, window=14,
                              seed=8)
    om, dl = 28, 96
    ours = partition(hg, omega=om, delta=dl, theta=8)
    assert ours.audit["size_ok"] and ours.audit["inbound_ok"]
    seq_parts, _ = sequential_multilevel(hg, om, dl)
    ov_parts, _ = overlap_partition(hg, om, dl)
    op_parts, _ = onepass_partition(hg, om, dl)
    conn = {
        "ours": ours.connectivity,
        "seq-ml": metrics.connectivity(hg, seq_parts),
        "overlap": metrics.connectivity(hg, ov_parts),
        "onepass": metrics.connectivity(hg, op_parts),
    }
    # ours within 5% of the best baseline, never the worst
    best = min(conn["seq-ml"], conn["overlap"], conn["onepass"])
    worst = max(conn["seq-ml"], conn["overlap"], conn["onepass"])
    assert conn["ours"] <= best * 1.05 or conn["ours"] < worst, conn


def test_planner_perm_flows_into_model_forward():
    cfg = get_config("llama4-scout-17b-16e").smoke()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, n_experts=8, top_k=2))
    out = planner.plan_expert_placement(cfg, n_shards=2, seed=1, theta=2)
    perm = jnp.asarray(out["perm"])
    from repro.models import common, transformer as T
    params = common.materialize(T.lm_shapes(cfg), jax.random.PRNGKey(0))
    batch_tokens = jnp.ones((2, 16), jnp.int32)
    x, _, _ = T.forward(params, batch_tokens, cfg, mode="train",
                        remat=False, expert_perm=perm)
    assert bool(jnp.isfinite(x).all())
