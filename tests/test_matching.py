"""Exact matching DP vs brute force (paper Sec. V-D)."""
import itertools

import jax.numpy as jnp
import numpy as np

from repro.core.matching import match_pseudoforest


def brute_force(target, score):
    n = len(target)
    edges = {}
    for a in range(n):
        if target[a] < 0:
            continue
        b = target[a]
        key = (min(a, b), max(a, b))
        edges[key] = max(edges.get(key, -1e18), score[a])
    edges = list(edges.items())
    best = 0.0
    for r in range(len(edges) + 1):
        for comb in itertools.combinations(range(len(edges)), r):
            used, val, ok = set(), 0.0, True
            for ei in comb:
                (a, b), w = edges[ei]
                if a in used or b in used:
                    ok = False
                    break
                used.update((a, b))
                val += w
            if ok:
                best = max(best, val)
    return best


def proposal_graph(rng, n):
    """Invariant-respecting proposal graph from a symmetric eta matrix."""
    eta = np.zeros((n, n), np.float32)
    for a in range(n):
        for b in range(a + 1, n):
            if rng.random() < 0.6:
                eta[a, b] = eta[b, a] = np.float32(rng.integers(1, 20))
    target = np.full(n, -1, np.int32)
    score = np.zeros(n, np.float32)
    for a in range(n):
        if eta[a].max() > 0:
            cand = np.where(eta[a] == eta[a].max())[0]
            target[a] = cand.max()
            score[a] = eta[a].max()
    return target, score


def matched_value(target, score, m):
    val = 0.0
    for a in range(len(m)):
        if m[a] >= 0 and a < m[a]:
            val += score[a] if target[a] == m[a] else score[m[a]]
    return val


def test_matching_exact_vs_bruteforce(rng):
    for trial in range(25):
        n = int(rng.integers(3, 10))
        target, score = proposal_graph(rng, n)
        m = np.asarray(match_pseudoforest(
            jnp.asarray(target), jnp.asarray(score),
            jnp.ones(n, bool)))
        for a in range(n):
            if m[a] >= 0:
                assert m[m[a]] == a
                assert target[a] == m[a] or target[m[a]] == a
        assert abs(matched_value(target, score, m)
                   - brute_force(target, score)) < 1e-5


def test_matching_robust_on_arbitrary_functional_graphs(rng):
    """Broken-invariant graphs (long cycles) must terminate with a valid
    (mutual, disjoint, proposed-edges-only) matching via cycle cuts."""
    for trial in range(15):
        n = int(rng.integers(3, 40))
        target = rng.integers(0, n, size=n).astype(np.int32)
        target[target == np.arange(n)] = -1
        score = (rng.random(n) * 10).astype(np.float32)
        live = rng.random(n) < 0.9
        m = np.asarray(match_pseudoforest(
            jnp.asarray(target), jnp.asarray(score), jnp.asarray(live)))
        for a in range(n):
            if m[a] >= 0:
                assert m[m[a]] == a and live[a]
                assert target[a] == m[a] or target[m[a]] == a


def test_matching_deterministic(rng):
    n = 30
    target, score = proposal_graph(rng, n)
    args = (jnp.asarray(target), jnp.asarray(score), jnp.ones(n, bool))
    m1 = np.asarray(match_pseudoforest(*args))
    m2 = np.asarray(match_pseudoforest(*args))
    np.testing.assert_array_equal(m1, m2)


# ---------------------------------------------------------------------------
# mutation verification for the matching properties (hypothesis variants in
# tests/test_property.py): each seeded defect violates a property the real
# DP satisfies, demonstrating the properties discriminate.
# ---------------------------------------------------------------------------
def _greedy_mutual_only(target, score, live):
    """The seeded defect: `run_matching_rounds`' greedy ablation branch
    (mutual targets pair, everything else stays unmatched) in place of the
    exact DP."""
    n = len(target)
    m = np.full(n, -1, np.int64)
    for a in range(n):
        b = target[a]
        if live[a] and b >= 0 and live[b] and target[b] == a:
            m[a] = b
    return m


def test_optimality_property_catches_greedy_mutation():
    """Path proposal graph a-b-c-d with eta(a,b)=5, eta(b,c)=6, eta(c,d)=5:
    only b-c is mutual, so the greedy defect scores 6 while the optimum
    (and the DP) pairs a-b + c-d for 10. The brute-force-total property
    fails on the mutant and holds on the real DP."""
    target = np.array([1, 2, 1, 2], np.int32)
    score = np.array([5.0, 6.0, 6.0, 5.0], np.float32)
    live = np.ones(4, bool)
    best = brute_force(target, score)
    assert best == 10.0

    m_mut = _greedy_mutual_only(target, score, live)
    assert matched_value(target, score, m_mut) == 6.0  # defect caught
    assert abs(matched_value(target, score, m_mut) - best) > 1e-6

    m = np.asarray(match_pseudoforest(
        jnp.asarray(target), jnp.asarray(score), jnp.asarray(live)))
    assert abs(matched_value(target, score, m) - best) < 1e-6


def test_liveness_property_catches_ignored_live_mask():
    """The seeded defect of ignoring `live` pairs a dead node; the
    never-pairs-dead property fails on the mutant and holds on the DP."""
    target = np.array([1, 0], np.int32)
    score = np.array([3.0, 3.0], np.float32)
    live = np.array([True, False])

    m_mut = np.asarray(match_pseudoforest(
        jnp.asarray(target), jnp.asarray(score),
        jnp.ones(2, bool)))  # defect: live mask dropped
    assert m_mut[1] == 0 and not live[1]  # pairs a dead node -> caught

    m = np.asarray(match_pseudoforest(
        jnp.asarray(target), jnp.asarray(score), jnp.asarray(live)))
    assert (m == -1).all()
