"""Numpy brute-force oracle for `core.contract.contract` (paper Sec. V-E).

The oracle rebuilds the coarse hypergraph with nested loops and python
sets — pin dedup per edge, dst-kept-over-src role merge, src-first pin
layout with coarse ids ascending within each role, inbound-first incidence
ordered by edge id within each group, node-size and edge-weight
conservation — and every device-array field is compared exactly.

Mutation verification: the two seeded defects the oracle must catch are
demonstrated caught at the bottom of this file — a flipped `_role_key`
(src kept over dst on duplicate pins) and a dropped `starts` dedup mask
(duplicate coarse pins survive). Both run the *unjitted* `contract_impl`
under monkeypatch so the mutation is actually traced.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import generate
from repro.core import contract as C
from repro.core import hypergraph as H
from repro.core.coarsen import CoarsenParams, coarsen_step
from repro.utils import segops


def random_matching(n_nodes, rng, frac=0.7):
    """Random involution without fixed points over a subset of nodes."""
    match = np.full(n_nodes, -1, np.int64)
    perm = rng.permutation(n_nodes)
    for i in range(0, n_nodes - 1, 2):
        a, b = perm[i], perm[i + 1]
        if rng.random() < frac:
            match[a], match[b] = b, a
    return match


def contract_oracle(hg, node_size, match):
    """Nested-loop + python-set rebuild of the coarse hypergraph."""
    n = hg.n_nodes
    # clusters: representative = min(i, match[i]); coarse ids in rep order
    rep = [min(i, match[i]) if match[i] >= 0 else i for i in range(n)]
    reps = sorted({rep[i] for i in range(n)})
    newid = {r: k for k, r in enumerate(reps)}
    gamma = np.array([newid[rep[i]] for i in range(n)], np.int64)
    n_new = len(reps)

    size_new = np.zeros(n_new, np.int64)
    for i in range(n):
        size_new[gamma[i]] += node_size[i]

    # coarse edges: gamma images, set-dedup, dst role wins, src-first pins
    pins, nsrc, off = [], [], [0]
    for e in range(hg.n_edges):
        src = {int(gamma[p]) for p in hg.src(e)}
        dst = {int(gamma[p]) for p in hg.dst(e)}
        src -= dst  # a pin in both roles keeps only dst (paper V-E)
        pins.extend(sorted(src))
        pins.extend(sorted(dst))
        nsrc.append(len(src))
        off.append(len(pins))
    n_pins = len(pins)

    # incidence: inbound h-edges first per node, edge-id ascending per group
    inb = [[] for _ in range(n_new)]
    outb = [[] for _ in range(n_new)]
    for e in range(hg.n_edges):
        s, d0 = off[e] + nsrc[e], off[e + 1]
        for p in pins[off[e]: off[e] + nsrc[e]]:
            outb[p].append(e)
        for p in pins[s:d0]:
            inb[p].append(e)
    node_edges, node_is_in, node_off, node_nin = [], [], [0], []
    for v in range(n_new):
        node_edges.extend(inb[v])
        node_is_in.extend([True] * len(inb[v]))
        node_edges.extend(outb[v])
        node_is_in.extend([False] * len(outb[v]))
        node_off.append(len(node_edges))
        node_nin.append(len(inb[v]))
    return dict(gamma=gamma, n_nodes=n_new, n_edges=hg.n_edges,
                n_pins=n_pins, edge_off=np.asarray(off),
                edge_pins=np.asarray(pins, np.int64),
                edge_nsrc=np.asarray(nsrc), edge_w=hg.edge_w,
                node_off=np.asarray(node_off),
                node_edges=np.asarray(node_edges, np.int64),
                node_is_in=np.asarray(node_is_in, bool),
                node_nin=np.asarray(node_nin),
                node_size=size_new)


def assert_matches_oracle(hg, d2, gamma, orc):
    """Field-by-field comparison of the device contraction vs the oracle."""
    nn, ne, npn = orc["n_nodes"], orc["n_edges"], orc["n_pins"]
    assert int(d2.n_nodes) == nn
    assert int(d2.n_edges) == ne
    assert int(d2.n_pins) == npn
    np.testing.assert_array_equal(np.asarray(gamma)[: hg.n_nodes],
                                  orc["gamma"])
    np.testing.assert_array_equal(np.asarray(d2.edge_off)[: ne + 1],
                                  orc["edge_off"])
    np.testing.assert_array_equal(np.asarray(d2.edge_pins)[:npn],
                                  orc["edge_pins"])
    np.testing.assert_array_equal(np.asarray(d2.edge_nsrc)[:ne],
                                  orc["edge_nsrc"])
    np.testing.assert_array_equal(np.asarray(d2.edge_w)[:ne], orc["edge_w"])
    np.testing.assert_array_equal(np.asarray(d2.node_off)[: nn + 1],
                                  orc["node_off"])
    np.testing.assert_array_equal(np.asarray(d2.node_edges)[:npn],
                                  orc["node_edges"])
    np.testing.assert_array_equal(np.asarray(d2.node_is_in)[:npn],
                                  orc["node_is_in"])
    np.testing.assert_array_equal(np.asarray(d2.node_nin)[:nn],
                                  orc["node_nin"])
    np.testing.assert_array_equal(np.asarray(d2.node_size)[:nn],
                                  orc["node_size"])


def _pad_match(match, caps):
    return jnp.asarray(np.pad(match, (0, caps.n - len(match)),
                              constant_values=-1).astype(np.int32))


@pytest.mark.parametrize("seed", range(5))
def test_contract_matches_oracle_random_matchings(seed):
    rng = np.random.default_rng(seed)
    hg = generate.random_kuniform(n_nodes=24, n_edges=30, k=4, seed=seed,
                                  n_src=2, weighted=True)
    caps = H.Caps.for_host(hg)
    d = H.device_from_host(hg, caps)
    match = random_matching(hg.n_nodes, rng)
    d2, gamma = C.contract(d, _pad_match(match, caps), caps)
    orc = contract_oracle(hg, np.ones(hg.n_nodes, np.int64), match)
    assert_matches_oracle(hg, d2, gamma, orc)


@pytest.mark.parametrize("gen,seed", [("smallworld", 3), ("ispd", 11)])
def test_contract_matches_oracle_coarsen_matchings(gen, seed):
    """Same comparison on pipeline-produced matchings, two levels deep
    (level 2 exercises non-unit node sizes)."""
    if gen == "smallworld":
        hg = generate.snn_smallworld(n_nodes=60, fanout=5, seed=seed)
    else:
        hg = generate.ispd_like(n_nodes=80, seed=seed)
    caps = H.Caps.for_host(hg)
    d = H.device_from_host(hg, caps)
    params = CoarsenParams(omega=10, delta=2**20)
    for _ in range(2):
        match, n_pairs, _ = coarsen_step(d, caps, params)
        if int(n_pairs) == 0:
            break
        d2, gamma = C.contract(d, match, caps)
        host = H.host_from_device(d)
        sizes = np.asarray(d.node_size)[: host.n_nodes].astype(np.int64)
        orc = contract_oracle(host, sizes,
                              np.asarray(match)[: host.n_nodes])
        assert_matches_oracle(host, d2, gamma, orc)
        d = d2


# ---------------------------------------------------------------------------
# mutation verification: the oracle must catch the two seeded defects
# ---------------------------------------------------------------------------
def _both_roles_graph():
    """Edge 0 = src {0} + dst {1, 2}; matching 0-1 merges a src pin with a
    dst pin of the same edge, so the merged coarse pin holds both roles and
    the dst-over-src merge rule decides the result."""
    hg = H.HostHypergraph(n_nodes=4,
                          edge_off=np.array([0, 3, 5]),
                          edge_pins=np.array([0, 1, 2, 1, 3]),
                          edge_nsrc=np.array([1, 1]),
                          edge_w=np.array([1.0, 2.0]))
    match = np.array([1, 0, -1, -1], np.int64)
    return hg, match


def test_contract_oracle_catches_flipped_role_key(monkeypatch):
    hg, match = _both_roles_graph()
    caps = H.Caps.for_host(hg)
    d = H.device_from_host(hg, caps)
    orc = contract_oracle(hg, np.ones(hg.n_nodes, np.int64), match)

    # sanity: the unmutated contraction passes, and the defect site is live
    d2, gamma = C.contract_impl(d, _pad_match(match, caps), caps)
    assert_matches_oracle(hg, d2, gamma, orc)
    assert orc["edge_nsrc"][0] == 0  # merged pin kept its dst role

    monkeypatch.setattr(C, "_role_key",
                        lambda is_dst: jnp.where(is_dst, 1, 0))
    d2m, gammam = C.contract_impl(d, _pad_match(match, caps), caps)
    with pytest.raises(AssertionError):
        assert_matches_oracle(hg, d2m, gammam, orc)
    # the specific symptom: the merged pin was kept as src
    assert int(np.asarray(d2m.edge_nsrc)[0]) == 1


def test_contract_oracle_catches_dropped_dedup_mask(monkeypatch):
    hg, match = _both_roles_graph()
    caps = H.Caps.for_host(hg)
    d = H.device_from_host(hg, caps)
    orc = contract_oracle(hg, np.ones(hg.n_nodes, np.int64), match)

    orig = segops.segment_starts_from_sorted

    def no_dedup(keys):
        # drop the (edge, coarse-pin) duplicate mask; leave the single-key
        # edge-boundary call (rank-scan segments) intact
        s = orig(keys)
        return jnp.ones_like(s) if len(keys) == 2 else s

    monkeypatch.setattr(segops, "segment_starts_from_sorted", no_dedup)
    d2m, gammam = C.contract_impl(d, _pad_match(match, caps), caps)
    with pytest.raises(AssertionError):
        assert_matches_oracle(hg, d2m, gammam, orc)
    # the specific symptom: the duplicate coarse pin survived
    assert int(d2m.n_pins) == orc["n_pins"] + 1


def test_contract_oracle_is_selfconsistent_with_validate():
    """The oracle's coarse graph is itself a valid hypergraph (unique pins,
    src/dst disjoint) — guards the oracle against its own bugs."""
    rng = np.random.default_rng(0)
    hg = generate.random_kuniform(n_nodes=20, n_edges=25, k=4, seed=0,
                                  n_src=2)
    match = random_matching(hg.n_nodes, rng)
    orc = contract_oracle(hg, np.ones(hg.n_nodes, np.int64), match)
    h2 = H.HostHypergraph(n_nodes=orc["n_nodes"], edge_off=orc["edge_off"],
                          edge_pins=orc["edge_pins"],
                          edge_nsrc=orc["edge_nsrc"], edge_w=orc["edge_w"])
    h2.validate()
    no, ne2, nii, nin = h2.incidence()
    np.testing.assert_array_equal(no, orc["node_off"])
    np.testing.assert_array_equal(ne2, orc["node_edges"])
    np.testing.assert_array_equal(nii, orc["node_is_in"])
    np.testing.assert_array_equal(nin, orc["node_nin"])
