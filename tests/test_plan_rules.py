"""Plan rule-resolution unit tests beyond the seed's `test_dist.py` checks:
fsdp on/off, duplicate mesh axes, non-divisible dims replicating, and the
small-batch `_bsh` fallback. Uses AbstractMesh so multi-axis meshes resolve
without forcing host devices."""
import jax
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.dist.sharding import Plan
from repro.launch.steps import _bsh, _dp_size
from repro.models.common import Spec, _resolve_pspec


def _mesh(data=2, model=4, pod=None):
    shape = (("data", data), ("model", model))
    if pod is not None:
        shape = (("pod", pod),) + shape
    return AbstractMesh(shape)


def test_fsdp_on_shards_embed_over_data():
    plan = Plan.make(_mesh())
    s = Spec((64, 128), ("embed", "mlp"))
    assert _resolve_pspec(s, plan.rules, plan.mesh) == P("data", "model")


def test_fsdp_off_replicates_embed():
    plan = Plan.make(_mesh(), fsdp=False)
    assert plan.rules["embed"] is None
    s = Spec((64, 128), ("embed", "mlp"))
    assert _resolve_pspec(s, plan.rules, plan.mesh) == P(None, "model")


def test_duplicate_mesh_axis_earlier_dim_wins():
    # experts and mlp both map to "model": EP keeps it, the TP dim drops
    plan = Plan.make(_mesh())
    s = Spec((16, 64, 32), ("experts", "embed", "mlp"))
    assert _resolve_pspec(s, plan.rules, plan.mesh) == P("model", "data")


def test_non_divisible_dim_replicates():
    plan = Plan.make(_mesh(data=2, model=4))
    # 6 heads on a 4-way model axis -> replicated
    assert _resolve_pspec(Spec((6,), ("heads",)), plan.rules,
                          plan.mesh) == P()
    # qwen2 smoke: 2 KV heads on 4-way model -> replicated, 4 heads shard
    assert _resolve_pspec(Spec((2, 16), ("kv_heads", None)), plan.rules,
                          plan.mesh) == P()
    assert _resolve_pspec(Spec((4, 16), ("heads", None)), plan.rules,
                          plan.mesh) == P("model")


def test_multi_pod_batch_spans_pod_and_data():
    plan = Plan.make(_mesh(pod=2))
    assert tuple(plan.rules["batch"]) == ("pod", "data")
    assert _dp_size(plan) == 4
    assert plan.sharding("batch", None).spec == P(("pod", "data"))
    # fsdp stays intra-pod: params never all-gather over DCN
    assert plan.rules["embed"] == "data"


def test_bsh_small_batch_fallback():
    plan = Plan.make(_mesh(data=4, model=2))
    assert _dp_size(plan) == 4
    # divisible batch shards over DP
    assert _bsh(plan, 8, 2).spec == P("data")
    # non-divisible batch (e.g. long_500k B=1) falls back to replicated
    assert _bsh(plan, 1, 2).spec == P()
    assert _bsh(plan, 6, 3).spec == P()


def test_flag_rules_gate_model_features():
    plan = Plan.make(_mesh())
    assert plan.rules["kv_seq"] == "model"       # seq_shard_kv default on
    assert plan.rules["attn_seq"] is None
    assert not plan.rules.get("attn_p_bf16")
    assert not plan.rules.get("mla_flash")
    assert not plan.rules.get("moe_local_dispatch")
    plan2 = Plan.make(_mesh(), seq_shard_kv=False, seq_parallel_attn=True,
                      attn_p_bf16=True, mla_flash=True, moe_local=True)
    assert plan2.rules["kv_seq"] is None
    assert plan2.rules["attn_seq"] == "model"
    assert plan2.rules["attn_p_bf16"] and plan2.rules["mla_flash"]
    assert plan2.rules["moe_local_dispatch"]


def test_sharding_helpers_replicate_by_default():
    plan = Plan.make(_mesh())
    assert plan.sharding().spec == P()
    assert plan.pspec("batch", None, "mlp") == P("data", None, "model")
    # a mesh axis shards at most one dim in a single pspec
    assert plan.pspec("heads", "mlp") == P("model")
    assert plan.n_devices() == 8 and plan.dp_size() == 2


def test_param_shardings_tree_resolution():
    plan = Plan.make(_mesh())
    tree = {"w": Spec((8, 64), ("vocab", "embed")),
            "b": Spec((3,), ("heads",))}          # 3 % 4 != 0 -> replicated
    ps = plan.param_pspecs(tree)
    assert ps["w"] == P("model", "data")
    assert ps["b"] == P()
