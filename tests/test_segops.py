import jax.numpy as jnp
import numpy as np

from repro.utils import segops
from repro.utils.hashing import pair_noise


def test_segment_argmax_tiebreak_larger_id():
    vals = jnp.asarray([1.0, 3.0, 3.0, 2.0, 5.0])
    ids = jnp.arange(5, dtype=jnp.int32)
    seg = jnp.asarray([0, 0, 0, 1, 1])
    mx, arg = segops.segment_argmax(vals, ids, seg, 2)
    assert mx.tolist() == [3.0, 5.0]
    assert arg.tolist() == [2, 4]  # larger id wins the tie


def test_segmented_scan_matches_numpy():
    rng = np.random.default_rng(1)
    vals = rng.normal(size=64).astype(np.float32)
    starts = np.zeros(64, bool)
    starts[[0, 10, 11, 40]] = True
    out = np.asarray(segops.segmented_scan(jnp.asarray(vals),
                                           jnp.asarray(starts)))
    exp = vals.copy()
    seg_start = 0
    for i in range(64):
        if starts[i]:
            seg_start = i
        exp[i] = vals[seg_start: i + 1].sum()
    np.testing.assert_allclose(out, exp, rtol=1e-5)


def test_segmented_scan_int32_exact_beyond_float32():
    """Int deltas must scan in int32: float32 accumulation silently rounds
    once the running value passes 2**24 (regression for the events
    pipeline's size/distinct counts)."""
    vals = jnp.asarray([2 ** 24, 1, 1, 1], jnp.int32)
    starts = jnp.asarray([True, False, False, False])
    out = segops.segmented_scan(vals, starts)
    assert out.dtype == jnp.int32
    assert out.tolist() == [2 ** 24, 2 ** 24 + 1, 2 ** 24 + 2, 2 ** 24 + 3]
    # the old float32 path rounds (tree-order, so some +1s vanish) —
    # documents why the int path exists
    out_f32 = segops.segmented_scan(vals.astype(jnp.float32), starts)
    assert int(out_f32[-1]) != 2 ** 24 + 3


def test_sharded_scan_carry_chunks_match_full():
    """The cross-shard carry fold (scan_combine over per-chunk summaries +
    apply_scan_carry fixup) must reproduce the monolithic segmented scan
    when an array is split into contiguous chunks — the single-host model
    of what `sharded_segmented_scan` does across mesh devices."""
    rng = np.random.default_rng(3)
    vals = rng.integers(-7, 8, size=96).astype(np.int32)
    starts = rng.random(96) < 0.2
    starts[0] = True
    full = np.asarray(segops.segmented_scan(jnp.asarray(vals),
                                            jnp.asarray(starts)))
    for nchunks in (2, 3, 4, 8):
        got = []
        carry = (jnp.int32(0), jnp.int32(0))  # (has-start, value) summary
        for c in range(nchunks):
            lo, hi = c * 96 // nchunks, (c + 1) * 96 // nchunks
            v, s = jnp.asarray(vals[lo:hi]), jnp.asarray(starts[lo:hi])
            local = segops.segmented_scan(v, s)
            fixed = segops.apply_scan_carry(local, s, carry[1])
            got.append(np.asarray(fixed))
            carry = segops.scan_combine(
                carry, (jnp.max(s.astype(jnp.int32)), local[-1]))
        np.testing.assert_array_equal(np.concatenate(got), full)


def test_scatter_compact():
    data = jnp.asarray([5, 6, 7, 8, 9], jnp.int32)
    flags = jnp.asarray([True, False, True, True, False])
    out, cnt = segops.scatter_compact(data, flags, 5, -1)
    assert int(cnt) == 3
    assert out.tolist() == [5, 7, 8, -1, -1]


def test_rows_from_offsets_with_empty_segments():
    off = jnp.asarray([0, 2, 2, 5, 5], jnp.int32)
    rows = segops.rows_from_offsets(off, 5, 4)
    assert rows.tolist() == [0, 0, 2, 2, 2]


def test_searchsorted_segmented():
    vals = jnp.asarray([1, 3, 5, 2, 4, 9], jnp.int32)
    lo = jnp.asarray([0, 0, 3, 3], jnp.int32)
    hi = jnp.asarray([3, 3, 6, 6], jnp.int32)
    q = jnp.asarray([3, 5, 9, 2], jnp.int32)
    idx = segops.searchsorted_segmented(vals, lo, hi, q, 8)
    assert idx.tolist() == [1, 2, 5, 3]


def test_pair_noise_symmetric_and_bounded():
    a = np.arange(100, dtype=np.int32)
    b = (a * 7 + 3) % 100
    n1 = pair_noise(a, b, 1.0)
    n2 = pair_noise(b.astype(np.int32), a, 1.0)
    np.testing.assert_array_equal(n1, n2)
    assert (n1 >= 0).all() and (n1 < 1.0).all()
    jn = pair_noise(jnp.asarray(a), jnp.asarray(b), 1.0)
    np.testing.assert_allclose(np.asarray(jn), n1, rtol=1e-6)


def test_f32_sort_key_matches_lax_sort_total_order():
    """The uint32 key order must agree with `lax.sort`'s float key order —
    including its canonicalization: -0.0 == +0.0, and every NaN (any sign /
    payload) one equal class after +inf. These keys seed the distributed
    sample sort's splitters, so any disagreement would diverge the
    distributed and gathered sorts."""
    import jax

    x = np.array([1.0, -0.0, 0.0, np.nan, -np.nan, -np.inf, np.inf,
                  -1.0, 2**-126, -(2**-126), 3.3e38, -3.3e38], np.float32)
    # payload-threaded lax.sort = ground truth stable order
    (_, ), (perm,) = segops.sort_by(
        [jnp.asarray(x)], [jnp.arange(len(x), dtype=jnp.int32)])
    key = np.asarray(segops.f32_sort_key(jnp.asarray(x)))
    perm_key = np.lexsort((np.arange(len(x)), key))
    np.testing.assert_array_equal(np.asarray(perm), perm_key)
    # explicit edge classes
    k = lambda v: int(np.asarray(segops.f32_sort_key(jnp.float32(v))))
    assert k(-0.0) == k(0.0)
    nan_alt = np.array([0x7FC00001, 0xFFC00000], np.uint32).view(np.float32)
    assert k(np.nan) == k(nan_alt[0]) == k(nan_alt[1])  # one NaN class
    assert k(np.nan) > k(np.inf)                        # NaNs sort last
    assert k(-np.inf) < k(-1.0) < k(-0.0) < k(2**-126) < k(np.inf)
    del jax


def test_shardctx_boundary_helpers_single_device_degenerate():
    """edge_prev / edge_next / starts_from_sorted / cumsum / unstripe with
    axis=None must equal their whole-array definitions (the sharded events
    and contraction pipelines rely on this degenerate case)."""
    ctx = segops.ShardCtx()
    x = jnp.asarray([4, 4, 7, 7, 7, 9], jnp.int32)
    assert ctx.edge_prev(x, -1).tolist() == [-1, 4, 4, 7, 7, 7]
    assert ctx.edge_next(x, -1).tolist() == [4, 7, 7, 7, 9, -1]
    assert (ctx.starts_from_sorted([x]).tolist()
            == segops.segment_starts_from_sorted([x]).tolist())
    assert ctx.cumsum(x).tolist() == np.cumsum(x).tolist()
    assert ctx.unstripe(x).tolist() == x.tolist()
    np.testing.assert_array_equal(np.asarray(ctx.psum_compensated(x)),
                                  np.asarray(x))


def test_shardctx_sort_by_single_device_matches_sort_by():
    rng = np.random.default_rng(7)
    k1 = jnp.asarray(rng.integers(0, 5, 33).astype(np.int32))
    kf = jnp.asarray(rng.normal(size=33).astype(np.float32))
    p = jnp.arange(33, dtype=jnp.int32)
    gk, gp = segops.ShardCtx().sort_by([k1, kf], [p])
    ek, ep = segops.sort_by([k1, kf], [p])
    for g, e in zip(list(gk) + list(gp), list(ek) + list(ep)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(e))
