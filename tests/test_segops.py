import jax.numpy as jnp
import numpy as np

from repro.utils import segops
from repro.utils.hashing import pair_noise


def test_segment_argmax_tiebreak_larger_id():
    vals = jnp.asarray([1.0, 3.0, 3.0, 2.0, 5.0])
    ids = jnp.arange(5, dtype=jnp.int32)
    seg = jnp.asarray([0, 0, 0, 1, 1])
    mx, arg = segops.segment_argmax(vals, ids, seg, 2)
    assert mx.tolist() == [3.0, 5.0]
    assert arg.tolist() == [2, 4]  # larger id wins the tie


def test_segmented_scan_matches_numpy():
    rng = np.random.default_rng(1)
    vals = rng.normal(size=64).astype(np.float32)
    starts = np.zeros(64, bool)
    starts[[0, 10, 11, 40]] = True
    out = np.asarray(segops.segmented_scan(jnp.asarray(vals),
                                           jnp.asarray(starts)))
    exp = vals.copy()
    seg_start = 0
    for i in range(64):
        if starts[i]:
            seg_start = i
        exp[i] = vals[seg_start: i + 1].sum()
    np.testing.assert_allclose(out, exp, rtol=1e-5)


def test_scatter_compact():
    data = jnp.asarray([5, 6, 7, 8, 9], jnp.int32)
    flags = jnp.asarray([True, False, True, True, False])
    out, cnt = segops.scatter_compact(data, flags, 5, -1)
    assert int(cnt) == 3
    assert out.tolist() == [5, 7, 8, -1, -1]


def test_rows_from_offsets_with_empty_segments():
    off = jnp.asarray([0, 2, 2, 5, 5], jnp.int32)
    rows = segops.rows_from_offsets(off, 5, 4)
    assert rows.tolist() == [0, 0, 2, 2, 2]


def test_searchsorted_segmented():
    vals = jnp.asarray([1, 3, 5, 2, 4, 9], jnp.int32)
    lo = jnp.asarray([0, 0, 3, 3], jnp.int32)
    hi = jnp.asarray([3, 3, 6, 6], jnp.int32)
    q = jnp.asarray([3, 5, 9, 2], jnp.int32)
    idx = segops.searchsorted_segmented(vals, lo, hi, q, 8)
    assert idx.tolist() == [1, 2, 5, 3]


def test_pair_noise_symmetric_and_bounded():
    a = np.arange(100, dtype=np.int32)
    b = (a * 7 + 3) % 100
    n1 = pair_noise(a, b, 1.0)
    n2 = pair_noise(b.astype(np.int32), a, 1.0)
    np.testing.assert_array_equal(n1, n2)
    assert (n1 >= 0).all() and (n1 < 1.0).all()
    jn = pair_noise(jnp.asarray(a), jnp.asarray(b), 1.0)
    np.testing.assert_allclose(np.asarray(jn), n1, rtol=1e-6)
