"""Distributed sample sort (`repro.dist.sort` via `ShardCtx.sort_by`) vs
the gathered stable `lax.sort` and a numpy lexsort oracle.

Contract under test: bit-identity. The sort threads a global-rank tie key,
so every extended key is unique and the bucketed/exchanged order *is* the
stable order of the original keys — on any mesh, through both the
all_to_all exchange path and the capacity-overflow gathered fallback.

The in-process tests run on however many devices the session sees (1 on a
plain run — the degenerate local path; 8 in CI's forced-fan-out step, which
exercises the real exchange). The subprocess test forces 8 host devices and
additionally runs the mutation demo: dropping the global-rank tie key must
be *caught* by the stability oracle (equal keys then merge in buffer order,
not stripe order)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import common
from repro.utils import segops

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "src")


def _mesh_sort(keys_np, pays_np, striped=False, with_stats=False, **kw):
    """Run ShardCtx.sort_by under shard_map on a (n,)-model mesh over all
    visible devices; returns full sorted columns (+ fell_back)."""
    n = len(jax.devices())
    mesh = jax.make_mesh((n,), ("model",))
    ctx = segops.ShardCtx(axis="model", nshards=n)
    keys = [jnp.asarray(k) for k in keys_np]
    pays = [jnp.asarray(p) for p in pays_np]
    nk = len(keys)

    def body(*cols):
        ks, ps = list(cols[:nk]), list(cols[nk:])
        if striped or with_stats or kw:
            from repro.dist import sort as dist_sort
            ks = [ctx.stripe(c) for c in ks]
            ps = [ctx.stripe(c) for c in ps]
            out = dist_sort.sample_sort_stripes(ctx, ks, ps,
                                                with_stats=with_stats, **kw)
            ko, po = out[0], out[1]
            res = [ctx.gather(c) for c in ko + po]
            if with_stats:
                return (*res, out[2])
            return tuple(res)
        ko, po = ctx.sort_by(ks, ps)  # replicated in / replicated out
        return (*ko, *po)

    n_out = nk + len(pays) + (1 if with_stats else 0)
    f = jax.jit(common.shard_map(body, mesh=mesh,
                                 in_specs=tuple(P() for _ in keys + pays),
                                 out_specs=tuple(P() for _ in range(n_out))))
    out = [np.asarray(o) for o in f(*keys, *pays)]
    if with_stats:
        return out[:-1], bool(out[-1].reshape(-1)[0])
    return out


def _oracle(keys_np, pays_np):
    """Stable multi-key sort oracle: np.lexsort (stable, last key primary)."""
    order = np.lexsort(tuple(reversed([np.asarray(k) for k in keys_np])))
    return [np.asarray(c)[order] for c in list(keys_np) + list(pays_np)]


def _assert_cols_equal(got, exp, names=None):
    for i, (g, e) in enumerate(zip(got, exp)):
        np.testing.assert_array_equal(
            g, e, err_msg=f"column {names[i] if names else i}")


def test_dist_sort_matches_lexsort_oracle():
    rng = np.random.default_rng(0)
    n = 64 * len(jax.devices())
    k1 = rng.integers(0, 6, n).astype(np.int32)       # duplicate-heavy
    k2 = rng.integers(0, 4, n).astype(np.int32)
    pay = np.arange(n, dtype=np.int32)
    got = _mesh_sort([k1, k2], [pay])
    _assert_cols_equal(got, _oracle([k1, k2], [pay]), ["k1", "k2", "pay"])
    # and bitwise against the gathered stable lax.sort
    (e1, e2), (ep,) = segops.sort_by([jnp.asarray(k1), jnp.asarray(k2)],
                                     [jnp.asarray(pay)])
    _assert_cols_equal(got, [np.asarray(e1), np.asarray(e2), np.asarray(ep)])


def test_dist_sort_striped_in_out_matches_gathered():
    rng = np.random.default_rng(1)
    n = 32 * len(jax.devices())
    k1 = rng.integers(-100, 100, n).astype(np.int32)
    k2 = rng.integers(0, 3, n).astype(np.int32)
    p1 = rng.integers(0, 2**20, n).astype(np.int32)
    got = _mesh_sort([k1, k2], [p1], striped=True)
    exp_k, exp_p = segops.sort_by([jnp.asarray(k1), jnp.asarray(k2)],
                                  [jnp.asarray(p1)])
    _assert_cols_equal(got, [np.asarray(c) for c in list(exp_k) + list(exp_p)])


def test_dist_sort_stability_equal_keys_preserve_payload_order():
    rng = np.random.default_rng(2)
    n = 16 * len(jax.devices())
    key = rng.integers(0, 3, n).astype(np.int32)  # tiny key space: many ties
    pay = np.arange(n, dtype=np.int32)
    got_key, got_pay = _mesh_sort([key], [pay])
    for v in np.unique(key):
        grp = got_pay[got_key == v]
        assert np.all(np.diff(grp) > 0), (v, grp)  # input order preserved


def test_dist_sort_float_total_order_edge_cases():
    """-0.0/+0.0 and NaN placement must agree between the gathered and
    distributed sorts (the f32_sort_key canonicalization contract)."""
    rng = np.random.default_rng(3)
    n = 32 * len(jax.devices())
    pool = np.array([0.0, -0.0, np.nan, -np.nan, np.inf, -np.inf,
                     1.5, -1.5, 2**-126, -(2**-126)], np.float32)
    kf = pool[rng.integers(0, len(pool), n)]
    pay = np.arange(n, dtype=np.int32)
    got_key, got_pay = _mesh_sort([kf], [pay])
    (ek,), (ep,) = segops.sort_by([jnp.asarray(kf)], [jnp.asarray(pay)])
    # bitwise: original NaN payloads / zero signs survive in sorted output
    assert np.array_equal(got_key.view(np.uint32), np.asarray(ek).view(np.uint32))
    assert np.array_equal(got_pay, np.asarray(ep))


def test_dist_sort_skew_falls_back_and_stays_exact():
    """Adversarial skew overflows the static exchange capacity -> the
    uniform gathered branch runs; result must stay bit-identical. Uniform
    input takes the exchange path (fell_back False) on a real mesh."""
    n_dev = len(jax.devices())
    rng = np.random.default_rng(4)
    n = 512 * n_dev
    uni = rng.integers(-2**30, 2**30, n).astype(np.int32)
    pay = np.arange(n, dtype=np.int32)
    got, fb_uni = _mesh_sort([uni], [pay], with_stats=True)
    _assert_cols_equal(got, _oracle([uni], [pay]))
    rev = np.sort(uni)[::-1].copy()
    got, fb_rev = _mesh_sort([rev], [pay], with_stats=True)
    _assert_cols_equal(got, _oracle([rev], [pay]))
    if n_dev >= 8:
        assert not fb_uni          # exchange path actually exercised
        assert fb_rev              # fallback path actually exercised


def test_dist_sort_mutation_dropping_tie_rank_is_caught():
    """Mutation demo (repo convention): without the global-rank tie key,
    equal keys merge in buffer order instead of stripe order — the
    stability oracle must catch it."""
    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices for cross-shard duplicates")
    rng = np.random.default_rng(5)
    n = 64 * len(jax.devices())
    # moderate key cardinality: duplicates span every shard but the sort
    # stays on the exchange path (all-equal keys would overflow into the
    # gathered fallback, which is stable regardless of the tie key)
    key = rng.integers(0, 4 * len(jax.devices()), n).astype(np.int32)
    pay = np.arange(n, dtype=np.int32)
    (gk, gp), fb = _mesh_sort([key], [pay], with_stats=True,
                              _tie_rank=False)
    assert not fb
    exp = _oracle([key], [pay])
    assert not np.array_equal(gp, exp[1]), \
        "mutation not caught: tie-rank drop left the stable order intact"
    # control: with the tie key the same input is exactly the stable order
    (gk, gp), _ = _mesh_sort([key], [pay], with_stats=True)
    _assert_cols_equal([gk, gp], exp)


_MULTIDEV = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.models import common
    from repro.utils import segops

    assert len(jax.devices()) == 8
    rng = np.random.default_rng(0)

    def mesh_sort(mesh, ctx, keys_np, pays_np, **kw):
        keys = [jnp.asarray(k) for k in keys_np]
        pays = [jnp.asarray(p) for p in pays_np]
        nk = len(keys)
        def body(*cols):
            from repro.dist import sort as dist_sort
            ks = [ctx.stripe(c) for c in cols[:nk]]
            ps = [ctx.stripe(c) for c in cols[nk:]]
            out = dist_sort.sample_sort_stripes(ctx, ks, ps,
                                                with_stats=True, **kw)
            return (*[ctx.gather(c) for c in out[0] + out[1]], out[2])
        f = jax.jit(common.shard_map(
            body, mesh=mesh, in_specs=tuple(P() for _ in keys + pays),
            out_specs=tuple(P() for _ in range(nk + len(pays) + 1))))
        out = [np.asarray(o) for o in f(*keys, *pays)]
        return out[:-1], bool(out[-1].reshape(-1)[0])

    # both acceptance meshes: model axis of 4 (with a 2-replica data axis
    # present, as in the V-cycle) and of 8
    for shape, axes in (((2, 4), ("data", "model")), ((1, 8), ("data", "model"))):
        mesh = jax.make_mesh(shape, axes)
        s = shape[1]
        ctx = segops.ShardCtx(axis="model", nshards=s)
        n = 128 * s
        for trial in range(3):
            cols = [rng.integers(0, [6, 2**30, 12][trial], n).astype(np.int32)
                    for _ in range(2)]
            kf = rng.choice(np.array([0.0, -0.0, 1.0, np.nan, np.inf],
                                     np.float32), n)
            pay = np.arange(n, dtype=np.int32)
            got, fb = mesh_sort(mesh, ctx, [cols[0], kf, cols[1]], [pay])
            ek, ep = segops.sort_by(
                [jnp.asarray(cols[0]), jnp.asarray(kf), jnp.asarray(cols[1])],
                [jnp.asarray(pay)])
            for g, e in zip(got, list(ek) + list(ep)):
                e = np.asarray(e)
                if e.dtype.kind == "f":
                    assert np.array_equal(g.view(np.uint32),
                                          e.view(np.uint32)), (shape, trial)
                else:
                    assert np.array_equal(g, e), (shape, trial)

    # fallback + exchange paths both exact, and both actually taken
    mesh = jax.make_mesh((1, 8), ("data", "model"))
    ctx = segops.ShardCtx(axis="model", nshards=8)
    n = 512 * 8
    uni = rng.integers(-2**30, 2**30, n).astype(np.int32)
    pay = np.arange(n, dtype=np.int32)
    (gk, gp), fb = mesh_sort(mesh, ctx, [uni], [pay])
    assert not fb
    assert np.array_equal(gk, np.sort(uni))
    rev = np.sort(uni)[::-1].copy()
    (gk, gp), fb = mesh_sort(mesh, ctx, [rev], [pay])
    assert fb
    assert np.array_equal(gk, np.sort(rev))

    # mutation demo: drop the global-rank tie key -> stability lost, caught
    # (moderate cardinality keeps the exchange path; all-equal keys would
    # fall back to the gathered sort, which is stable regardless)
    key = rng.integers(0, 32, 256 * 8).astype(np.int32)
    pay = np.arange(256 * 8, dtype=np.int32)
    (gk, gp), fb = mesh_sort(mesh, ctx, [key], [pay], _tie_rank=False)
    assert not fb
    order = np.lexsort((pay, key))
    assert not np.array_equal(gp, pay[order]), "tie-rank mutation not caught"
    (gk, gp), _ = mesh_sort(mesh, ctx, [key], [pay])
    assert np.array_equal(gp, pay[order])

    # boundary helpers on a real mesh
    x = jnp.asarray(rng.integers(0, 100, 64).astype(np.int32))
    def bh(v):
        vs = ctx.stripe(v)
        return (ctx.gather(ctx.edge_prev(vs, -7)),
                ctx.gather(ctx.edge_next(vs, -9)),
                ctx.gather(ctx.cumsum(vs)),
                ctx.unstripe(vs))
    f = jax.jit(common.shard_map(bh, mesh=mesh, in_specs=(P(),),
                                 out_specs=(P(), P(), P(), P())))
    prev, nxt, cs, us = map(np.asarray, f(x))
    xn = np.asarray(x)
    assert np.array_equal(prev, np.concatenate([[-7], xn[:-1]]))
    assert np.array_equal(nxt, np.concatenate([xn[1:], [-9]]))
    assert np.array_equal(cs, np.cumsum(xn))
    assert np.array_equal(us, xn)
    print("DIST_SORT_OK")
""")


@pytest.mark.slow
def test_dist_sort_8dev_subprocess(tmp_path):
    script = tmp_path / "dist_sort_8dev.py"
    script.write_text(_MULTIDEV)
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, str(script)], env=env,
                       capture_output=True, text=True, timeout=1800)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "DIST_SORT_OK" in r.stdout
