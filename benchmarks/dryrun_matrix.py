"""Drive the full (arch x shape x mesh) dry-run matrix as subprocesses.

Each cell runs in its own process (fresh XLA state, bounded memory) and
writes results/dryrun/<arch>__<shape>.json containing both mesh passes.

  PYTHONPATH=src python benchmarks/dryrun_matrix.py [--only arch:shape,...]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT = os.path.join(ROOT, "results", "dryrun")

ARCHS = ["qwen2-1.5b", "minitron-8b", "phi4-mini-3.8b", "yi-34b",
         "xlstm-350m", "llama4-scout-17b-16e", "deepseek-v2-236b",
         "whisper-tiny", "internvl2-2b", "jamba-v0.1-52b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

# run a representative sample first so analysis can start early
PRIORITY = [
    ("qwen2-1.5b", "train_4k"), ("deepseek-v2-236b", "train_4k"),
    ("yi-34b", "decode_32k"), ("jamba-v0.1-52b", "train_4k"),
    ("minitron-8b", "prefill_32k"), ("xlstm-350m", "long_500k"),
]


def cells():
    seen = set()
    for c in PRIORITY:
        seen.add(c)
        yield c
    for a in ARCHS:
        for s in SHAPES:
            if (a, s) not in seen:
                yield (a, s)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--timeout", type=int, default=2400)
    ap.add_argument("--mesh", default="single,multi")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    os.makedirs(OUT, exist_ok=True)

    todo = list(cells())
    if args.only:
        want = set(tuple(x.split(":")) for x in args.only.split(","))
        todo = [c for c in todo if c in want]

    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    t00 = time.time()
    for i, (arch, shape) in enumerate(todo):
        out_json = os.path.join(OUT, f"{arch}__{shape}.json")
        if os.path.exists(out_json) and not args.force:
            print(f"[{i+1}/{len(todo)}] {arch} x {shape}: cached", flush=True)
            continue
        t0 = time.time()
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--mesh", args.mesh, "--json", out_json]
        try:
            r = subprocess.run(cmd, env=env, timeout=args.timeout,
                               capture_output=True, text=True)
            status = "ok" if r.returncode == 0 else f"rc={r.returncode}"
            if r.returncode != 0:
                with open(out_json + ".err", "w") as f:
                    f.write(r.stdout[-4000:] + "\n====\n" + r.stderr[-8000:])
        except subprocess.TimeoutExpired:
            status = "timeout"
        print(f"[{i+1}/{len(todo)}] {arch} x {shape}: {status} "
              f"({time.time()-t0:.0f}s, total {time.time()-t00:.0f}s)",
              flush=True)
    print("matrix done in %.0fs" % (time.time() - t00))


if __name__ == "__main__":
    main()
