"""Coarsen + refine wall-time vs device count (the paper's hierarchical-
parallelism claim, Secs. V-VI, measured on forced host devices).

Each device count runs in a fresh subprocess (XLA device topology is fixed
at backend init), partitions the same SNN hypergraph through
`dist.partition` with a (1, n)-mesh Plan — all devices shard the pins/pairs
pipelines of both coarsening and refinement, and the graph *storage* is
memory-sharded (`shard_graph=True`, `dist.graph.ShardedHypergraph`) — and
reports the second run's per-phase wall-times (first run pays compile): a
coarsen-phase column, a refine-phase column, a `sort_s` column (an
events-scale distributed sample sort in isolation, with the bytes/shard the
legacy gathered sort would have moved vs the splitter sample that travels
now), and a `graph_B` column (per-device live bytes of the pins-sized
storage arrays — sharded, scaling ~1/devices — next to `graph_repl_B`, the
bytes a replicated copy pins on every device) per device count. The V-cycle
runs with `use_kernels=True`, so the stripe-local Pallas hot loops are on
the measured path; a `kernel_levels` column (`coarsen_hit/levels +
refine_hit/levels`) reports how many levels actually dispatched to them.
On this CPU
container the "devices" are host threads, so the numbers chart
overhead/scaling shape rather than real speedup; on an accelerator mesh the
same harness measures the real thing.

  PYTHONPATH=src python -m benchmarks.dist_scaling
  PYTHONPATH=src python -m benchmarks.run --only dist
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

DEVICE_COUNTS = (1, 2, 4, 8)

_CHILD = textwrap.dedent("""
    import os, sys, json, time
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + sys.argv[1])
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core import generate
    from repro.core.hypergraph import Caps
    from repro.core.partitioner import partition
    from repro.dist.sharding import Plan
    from repro.models import common
    from repro.utils import segops

    n_dev = int(sys.argv[1])
    mesh = jax.make_mesh((1, n_dev), ("data", "model"))
    plan = Plan.make(mesh)
    hg = generate.snn_layered(n_layers=4, width=48, fanout=8, window=12,
                              seed=2)
    res = None
    for _ in range(2):   # second run: jit cache warm per caps signature
        res = partition(hg, omega=24, delta=96, theta=4, plan=plan,
                        race=False, shard_graph=True, use_kernels=True)
    kp = res.kernel_path
    kern = "{}/{}+{}/{}".format(
        sum(1 for v in kp["coarsen"] if v), len(kp["coarsen"]),
        sum(1 for v in kp["refine"] if v), len(kp["refine"]))

    # per-device live bytes of the pins-sized storage arrays: sharded
    # stripes (the new layout) vs the replicated copy every device used to
    # pin — the ~1/devices memory claim of the sharded storage
    from repro.dist import graph as dist_graph
    caps0 = Caps.for_host(hg)
    g = dist_graph.sharded_from_host(hg, caps0, plan)
    graph_B = g.pins_bytes_per_device()
    graph_repl_B = sum(
        np.dtype(dt).itemsize * caps0.p
        for dt in (np.int32, np.int32, np.bool_))  # pins/edges/is_in

    # events-scale distributed sort in isolation (PR 4): wall time plus the
    # bytes/shard the legacy gathered sort would have all-gathered vs the
    # splitter sample that now travels instead
    caps = Caps.for_host(hg)
    per = -(-caps.p // n_dev)
    L = 2 * per * n_dev            # inbound-events pipeline length
    ctx = (segops.ShardCtx(axis="model", nshards=n_dev) if n_dev > 1
           else segops.ShardCtx())
    rng = np.random.default_rng(0)
    ka = jnp.asarray(rng.integers(0, 8, L).astype(np.int32))
    kb = jnp.asarray(rng.integers(0, max(hg.n_edges, 1), L).astype(np.int32))
    ks = jnp.asarray(rng.permutation(L).astype(np.int32))
    pv = jnp.arange(L, dtype=jnp.int32)

    def body(a, b, c, p):
        ks_, ps_ = ctx.sort_by(
            [ctx.stripe(a), ctx.stripe(b), ctx.stripe(c)], [ctx.stripe(p)],
            striped_in=True, striped_out=True)
        return (*ks_, *ps_)

    f = jax.jit(common.shard_map(body, mesh=mesh, in_specs=(P(),) * 4,
                                 out_specs=(P("model"),) * 4))
    jax.block_until_ready(f(ka, kb, ks, pv))
    t0 = time.perf_counter()
    jax.block_until_ready(f(ka, kb, ks, pv))
    sort_s = time.perf_counter() - t0
    q = max(1, min(per * 2, 4 * n_dev))
    print(json.dumps(dict(refine_s=res.timings["refine"],
                          coarsen_s=res.timings["coarsen"],
                          total_s=res.timings["total"],
                          sort_s=sort_s,
                          sort_gather_B=int(L) * 4 * 4,
                          sort_splitter_B=n_dev * q * 4 * 4,
                          graph_B=int(graph_B),
                          graph_repl_B=int(graph_repl_B),
                          kernel_levels=kern,
                          connectivity=res.connectivity,
                          n_parts=res.n_parts)))
""")


def run() -> list[str]:
    from benchmarks.common import row

    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env = dict(os.environ, PYTHONPATH=src)
    env.pop("XLA_FLAGS", None)
    out, base = [], None
    for n in DEVICE_COUNTS:
        try:
            r = subprocess.run([sys.executable, "-c", _CHILD, str(n)],
                               env=env, capture_output=True, text=True,
                               timeout=1800)
        except subprocess.TimeoutExpired:
            out.append(row(f"dist_scaling/dev{n}", 0.0, "ERROR: timeout"))
            continue
        if r.returncode != 0:
            err = (r.stderr.strip().splitlines() or ["no stderr"])[-1]
            out.append(row(f"dist_scaling/dev{n}", 0.0,
                           f"ERROR: {err[:120]}"))
            continue
        m = json.loads(r.stdout.strip().splitlines()[-1])
        # rel_dev1 only once the dev-1 baseline itself succeeded
        if n == DEVICE_COUNTS[0]:
            base = m["refine_s"]
        rel = (f"rel_dev{DEVICE_COUNTS[0]}={m['refine_s'] / base:.2f}x"
               if base else "rel_dev1=n/a")
        out.append(row(
            f"dist_scaling/dev{n}", m["refine_s"] * 1e6,
            f"coarsen_s={m['coarsen_s']:.3f} refine_s={m['refine_s']:.3f} "
            f"sort_s={m['sort_s']:.4f} total_s={m['total_s']:.3f} "
            f"sort_gather_B={m['sort_gather_B']} "
            f"sort_splitter_B={m['sort_splitter_B']} "
            f"graph_B={m['graph_B']} graph_repl_B={m['graph_repl_B']} "
            f"kernel_levels={m['kernel_levels']} "
            f"conn={m['connectivity']:.0f} {rel}"))
    return out


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for line in run():
        print(line, flush=True)
