"""Paper Tab. II / Sec. VII-C: execution time scales linearly with pins
(work = |N| h d dominated). We time the full pipeline across a size sweep of
one topology family and report time-per-pin stability."""
from __future__ import annotations

from benchmarks.common import row, timed
from repro.core import generate
from repro.core.partitioner import partition


def run() -> list[str]:
    out = []
    prev = None
    for n in (192, 384, 640):
        hg = generate.snn_smallworld(n_nodes=n, fanout=10, seed=3)
        r, _ = timed(partition, hg, omega=32, delta=128, theta=4)
        r, t = timed(partition, hg, omega=32, delta=128, theta=4)
        pins = hg.n_pins
        tpp = t / pins * 1e6
        growth = ""
        if prev is not None:
            growth = (f"time_ratio={t/prev[0]:.2f} "
                      f"pins_ratio={pins/prev[1]:.2f}")
        out.append(row(f"tab2/n{n}", t * 1e6,
                       f"pins={pins} us_per_pin={tpp:.2f} {growth}"))
        prev = (t, pins)
    return out
