"""Paper Fig. 7: constrained SNN partitioning — ours vs the three
sequential baselines (hMETIS-like multi-level, overlap, one-pass) on
structurally matched synthetic SNN hypergraphs. Reports wall time,
connectivity, partition count, and validity. (Wall-clock on this CPU
container stands in for the paper's A100-vs-CPU comparison; the
*directional* quality claims are what we reproduce.)"""
from __future__ import annotations

from benchmarks.common import row, small_snn_suite, snn_constraints, timed
from repro.baselines import (onepass_partition, overlap_partition,
                             sequential_multilevel)
from repro.core import metrics
from repro.core.partitioner import partition


def run() -> list[str]:
    out = []
    for name, hg in small_snn_suite().items():
        om, dl = snn_constraints(name)
        ours, t_ours = timed(partition, hg, omega=om, delta=dl, theta=8)
        # exclude first-call compile by re-running (jit cached per caps)
        ours, t_ours = timed(partition, hg, omega=om, delta=dl, theta=8)
        rows = {"ours": (t_ours, ours.connectivity, ours.n_parts,
                         ours.audit["size_ok"] and ours.audit["inbound_ok"])}
        for bname, fn in (("seq-ml", sequential_multilevel),
                          ("overlap", overlap_partition),
                          ("onepass", onepass_partition)):
            (parts, info), t = timed(fn, hg, om, dl)
            aud = metrics.audit(hg, parts, om, dl)
            rows[bname] = (t, aud["connectivity"],
                           aud["n_parts"], aud["size_ok"] and aud["inbound_ok"])
        base = rows["seq-ml"]
        for m, (t, conn, k, ok) in rows.items():
            out.append(row(
                f"fig7/{name}/{m}", t * 1e6,
                f"conn={conn:.0f} parts={k} valid={ok} "
                f"conn_vs_seqml={conn/max(base[1],1e-9):.3f} "
                f"speedup_vs_seqml={base[0]/max(t,1e-9):.2f}x"))
    return out
