"""Benchmark driver: one module per paper table/figure. Prints
``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run [--only fig7,fig8,...] \
      [--smoke] [--metrics-json out.json]

--metrics-json captures one telemetry document per lane: the global metric
registry and span tree are reset before each lane and snapshotted after it,
so the written ``{"lanes": {name: {ts, metrics, spans}}}`` attributes every
series to the lane that produced it (the per-lane documents are the same
shape ``--metrics-json`` CLIs write; tests/data/metrics_schema.json pins
it). --smoke sets REPRO_BENCH_SMOKE=1 for lanes that honor it (CI runs
``--only partition_service --smoke`` as its metrics-smoke step).
"""
from __future__ import annotations

import argparse
import json
import os
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig7,fig8,fig15,fig16,tab2,roofline,"
                         "proofline,dist,dist_sort,serve_engine,"
                         "partition_service,repartition")
    ap.add_argument("--smoke", action="store_true",
                    help="shrink lanes that honor REPRO_BENCH_SMOKE "
                         "(CI metrics-smoke mode)")
    ap.add_argument("--metrics-json", default=None,
                    help="write per-lane telemetry snapshots to this path")
    args = ap.parse_args(argv)
    if args.smoke:
        os.environ["REPRO_BENCH_SMOKE"] = "1"

    from benchmarks import (dist_scaling, dist_sort, fig7_snn_comparison,
                            fig8_breakdown, fig15_kway, fig16_ablations,
                            partition_service, partitioner_roofline,
                            repartition, roofline, serve_engine,
                            tab2_work_span)
    mods = {
        "fig7": fig7_snn_comparison,
        "fig8": fig8_breakdown,
        "fig15": fig15_kway,
        "fig16": fig16_ablations,
        "tab2": tab2_work_span,
        "roofline": roofline,
        "proofline": partitioner_roofline,
        "dist": dist_scaling,
        "dist_sort": dist_sort,
        "serve_engine": serve_engine,
        "partition_service": partition_service,
        "repartition": repartition,
    }
    want = args.only.split(",") if args.only else list(mods)
    lanes: dict = {}
    if args.metrics_json:
        from repro.obs import metrics as obs_metrics
        from repro.obs import trace as obs_trace
    print("name,us_per_call,derived")
    for key in want:
        if args.metrics_json:
            # reset the global registry + span tree so the lane's snapshot
            # attributes every series to this lane alone
            obs_metrics.REGISTRY.reset()
            obs_trace.reset()
        t0 = time.time()
        try:
            for line in mods[key].run():
                print(line, flush=True)
        except Exception as e:  # keep the harness going; report the failure
            print(f"{key}/ERROR,0.0,{type(e).__name__}: {e}", flush=True)
        print(f"{key}/_elapsed,{(time.time()-t0)*1e6:.0f},", flush=True)
        if args.metrics_json:
            lanes[key] = dict(ts=time.time(),
                              metrics=obs_metrics.REGISTRY.snapshot(),
                              spans=obs_trace.aggregate())
    if args.metrics_json:
        with open(args.metrics_json, "w") as f:
            json.dump(dict(lanes=lanes), f, indent=2, sort_keys=True)


if __name__ == "__main__":
    main()
