"""Benchmark driver: one module per paper table/figure. Prints
``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run [--only fig7,fig8,...]
"""
from __future__ import annotations

import argparse
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig7,fig8,fig15,fig16,tab2,roofline,"
                         "proofline,dist,dist_sort,serve_engine,"
                         "partition_service")
    args = ap.parse_args(argv)

    from benchmarks import (dist_scaling, dist_sort, fig7_snn_comparison,
                            fig8_breakdown, fig15_kway, fig16_ablations,
                            partition_service, partitioner_roofline,
                            roofline, serve_engine, tab2_work_span)
    mods = {
        "fig7": fig7_snn_comparison,
        "fig8": fig8_breakdown,
        "fig15": fig15_kway,
        "fig16": fig16_ablations,
        "tab2": tab2_work_span,
        "roofline": roofline,
        "proofline": partitioner_roofline,
        "dist": dist_scaling,
        "dist_sort": dist_sort,
        "serve_engine": serve_engine,
        "partition_service": partition_service,
    }
    want = args.only.split(",") if args.only else list(mods)
    print("name,us_per_call,derived")
    for key in want:
        t0 = time.time()
        try:
            for line in mods[key].run():
                print(line, flush=True)
        except Exception as e:  # keep the harness going; report the failure
            print(f"{key}/ERROR,0.0,{type(e).__name__}: {e}", flush=True)
        print(f"{key}/_elapsed,{(time.time()-t0)*1e6:.0f},", flush=True)


if __name__ == "__main__":
    main()
