"""Paper Fig. 8: execution-time breakdown across algorithm steps
(candidates proposal, matching, coarse construction, gain calculation,
sequence construction, events validity, first neighbors construction).

Two sections: the per-kernel micro rows (each primitive jitted and timed
in isolation, as before), then a whole-V-cycle phase attribution read from
the span tree a full ``partition()`` run records (`repro.obs.trace`) — the
phase numbers the paper's stacked bars actually show. The legacy
``res.timings`` dict is a thin view over the same spans; the agreement is
asserted here and by ``tests/test_obs.py``."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, timed
from repro.core import generate
from repro.core import hypergraph as H
from repro.core import refine as R
from repro.core.coarsen import CoarsenParams, coarsen_step, propose
from repro.core.contract import contract
from repro.core.matching import match_pseudoforest


def run() -> list[str]:
    hg = generate.snn_smallworld(n_nodes=768, fanout=12, seed=5)
    caps = H.Caps.for_host(hg)
    d = H.device_from_host(hg, caps)
    om, dl = 48, 192
    params = CoarsenParams(omega=om, delta=dl)
    out = []

    blk = lambda x: jax.block_until_ready(x)

    pairs_fn = jax.jit(lambda dd: H.build_pairs(dd, caps))
    blk(pairs_fn(d))
    pairs, t_pairs = timed(lambda: blk(pairs_fn(d)))

    nbrs_fn = jax.jit(lambda pp, dd: H.build_neighbors(pp, dd, caps))
    blk(nbrs_fn(pairs, d))
    nbrs, t_nbrs = timed(lambda: blk(nbrs_fn(pairs, d)))

    prop_fn = jax.jit(lambda dd, nn, pp: propose(dd, nn, pp, caps, params))
    blk(prop_fn(d, nbrs, pairs))
    props, t_prop = timed(lambda: blk(prop_fn(d, nbrs, pairs)))

    match_fn = jax.jit(lambda t, s, l: match_pseudoforest(t, s, l))
    live = jnp.arange(caps.n) < d.n_nodes
    blk(match_fn(props.cand_ids[0], props.cand_scores[0], live))
    _, t_match = timed(
        lambda: blk(match_fn(props.cand_ids[0], props.cand_scores[0], live)))

    match, _, _ = coarsen_step(d, caps, params)
    blk(contract(d, match, caps))
    _, t_contract = timed(lambda: blk(contract(d, match, caps)))

    # refinement parts
    kcap = 32
    parts = jnp.arange(caps.n, dtype=jnp.int32) % 24
    rparams = R.RefineParams(omega=om, delta=dl, theta=1)
    pins_fn = jax.jit(lambda dd, pp: R.pins_matrix(dd, pp, caps, kcap))
    blk(pins_fn(d, parts))
    (pins, pins_in), t_pins = timed(lambda: blk(pins_fn(d, parts)))

    gains_fn = jax.jit(lambda dd, pp, pi: R.propose_moves(
        dd, pp, pi, caps, kcap, rparams, jnp.asarray(False), jnp.int32(24)))
    blk(gains_fn(d, parts, pins))
    (mv, gi, _, _), t_gains = timed(lambda: blk(gains_fn(d, parts, pins)))

    seq_fn = jax.jit(lambda dd, pp, m, g: R.build_sequence(
        dd, pp, m, g, caps, kcap, rparams))
    blk(seq_fn(d, parts, mv, gi))
    (seq, _), t_seq = timed(lambda: blk(seq_fn(d, parts, mv, gi)))

    ev_fn = jax.jit(lambda dd, pp, pi, m, s, g: R.events_validity(
        dd, pp, pi, m, s, g, caps, kcap, rparams))
    gain_seq = R.inseq_gains(d, parts, pins, mv, gi, seq, caps, kcap)
    blk(ev_fn(d, parts, pins_in, mv, seq, gain_seq))
    _, t_ev = timed(lambda: blk(ev_fn(d, parts, pins_in, mv, seq, gain_seq)))

    total = (t_pairs + t_nbrs + t_prop + t_match + t_contract + t_pins
             + t_gains + t_seq + t_ev)
    for name, t in [("first_neighbors(pairs)", t_pairs),
                    ("first_neighbors(dedup)", t_nbrs),
                    ("candidates_proposal", t_prop),
                    ("nodes_matching", t_match),
                    ("coarse_construction", t_contract),
                    ("pins_matrix", t_pins),
                    ("gain_calculation", t_gains),
                    ("moves_sequence", t_seq),
                    ("events_validity", t_ev)]:
        out.append(row(f"fig8/{name}", t * 1e6,
                       f"frac={t/total:.2f}"))

    # whole-V-cycle phase attribution from the span tree of a full run (a
    # smaller instance than the micro rows above: the host-driven exact-caps
    # driver recompiles per level, and two runs of the 768-node graph would
    # dominate the lane's wall time)
    from repro.core.partitioner import partition
    from repro.obs import trace as otrace

    hg_v = generate.snn_smallworld(n_nodes=256, fanout=8, seed=5)
    om, dl = 32, 128
    partition(hg_v, omega=om, delta=dl, theta=4)  # warmup: compile
    res = partition(hg_v, omega=om, delta=dl, theta=4)
    root = otrace.last_root("partition")
    # the timings dict is a view over these spans — must agree exactly
    assert root is not None and res.timings["total"] == root.duration
    assert res.timings["coarsen"] == root.find("coarsen").duration
    assert res.timings["refine"] == root.find("refine").duration
    for child in root.children:
        out.append(row(f"fig8/vcycle_{child.name}", child.duration * 1e6,
                       f"frac={child.duration / root.duration:.2f}"))
    return out
