"""Partition-service throughput: sequential vs bucketed-vmap vs routed.

Pushes a mixed-shape flood of small partition requests (four tenant shape
classes, two graphs each) through four policies and reports sustained
requests/sec:

* sequential — the service with `batch_slots=1`: every request is padded
  into its capacity bucket and solved one at a time (one device V-cycle
  per request, single jit signature, no batching);
* bucketed-vmap — the same service with `batch_slots` lanes: up to four
  requests stack into one vmapped device batch sharing that jit cache
  entry, amortising per-solve dispatch/stack/audit overhead;
* exact-caps — one `core.partitioner.partition()` call per request (the
  pre-service baseline). Its host-driven loop repacks every coarsened
  level to data-dependent exact caps, so each *novel* caps chain pays a
  fresh multi-second XLA compile. A same-shape-class warmup flood does
  not cover the timed flood's chains (coarse-level pair counts depend on
  the data, not just the shape), so sustained mixed traffic keeps paying
  the recompile tax — which is the pathology the fixed-caps buckets
  remove;
* routed — the service with `route_threshold` below the request sizes
  (every request takes the host-driven V-cycle lane through the
  scheduler), isolating scheduler overhead from the batching win.

Warmup (compile) is excluded: each policy first solves a throwaway flood
drawn from the same shape classes. The derived column is sustained req/s;
the acceptance comparison is bucketed_vmap vs sequential.

Smoke mode (REPRO_BENCH_SMOKE=1, set by ``benchmarks.run --smoke``) shrinks
the flood to two shape classes and runs only the sequential + bucketed
service lanes (skipping the compile-heavy exact-caps and routed lanes) —
the CI metrics-smoke step uses it to produce a real ``--metrics-json``
dump in seconds instead of minutes.

  PYTHONPATH=src python -m benchmarks.run --only partition_service
"""
from __future__ import annotations

import os
import time

from benchmarks.common import row

# (nodes, edges, pins-per-edge) per tenant shape class; two requests each.
# All four classes place into the smallest service bucket (n=64), so the
# bucketed policy runs the flood as two full four-lane batches.
SHAPES = [(40, 56, 3), (48, 64, 4), (56, 60, 4), (64, 64, 3)]
OMEGA, DELTA = 16, 256
THETA = 4
BATCH_SLOTS = 4


def _smoke() -> bool:
    return bool(os.environ.get("REPRO_BENCH_SMOKE"))


def _shapes():
    return SHAPES[:2] if _smoke() else SHAPES


def _n_req() -> int:
    return 2 * len(_shapes())


def _flood(seed0: int):
    from repro.core.generate import random_kuniform
    return [random_kuniform(n, e, p, seed=seed0 + i)
            for i, (n, e, p) in enumerate(_shapes() * 2)]


def _run_exact_caps(hgs):
    from repro.core.partitioner import partition
    return [partition(hg, omega=OMEGA, delta=DELTA, theta=THETA)
            for hg in hgs]


def _run_service(hgs, batch_slots, route_threshold=2048):
    from repro.obs import metrics as obs_metrics
    from repro.serve import PartitionService
    # record into the global registry so `benchmarks.run --metrics-json`
    # lane snapshots carry the service series
    svc = PartitionService(theta=THETA, batch_slots=batch_slots,
                           route_threshold=route_threshold,
                           registry=obs_metrics.REGISTRY)
    rids = [svc.submit(hg, omega=OMEGA, delta=DELTA) for hg in hgs]
    res = svc.drain()
    svc.close()
    assert sorted(res) == sorted(rids), "lost rids"
    return res


def _bench(name, runner, note=""):
    runner(_flood(1000))  # warmup: compile this policy's solve path
    t0 = time.perf_counter()
    res = runner(_flood(0))
    dt = time.perf_counter() - t0
    assert len(res) == _n_req()
    derived = f"req_per_s={_n_req() / dt:.1f}"
    return row(f"serve/partition_{name}", dt / _n_req() * 1e6,
               derived + (f" {note}" if note else ""))


def run():
    yield _bench("sequential",
                 lambda hgs: _run_service(hgs, batch_slots=1))
    yield _bench("bucketed_vmap",
                 lambda hgs: _run_service(hgs, batch_slots=BATCH_SLOTS))
    if _smoke():
        return  # skip the compile-heavy baseline lanes in smoke mode
    yield _bench("exact_caps", _run_exact_caps,
                 note="recompiles-per-novel-caps-chain")
    # route_threshold below the request sizes: every request takes the
    # host-driven V-cycle lane through the service scheduler
    yield _bench("routed",
                 lambda hgs: _run_service(hgs, batch_slots=BATCH_SLOTS,
                                          route_threshold=32))
