"""Paper Fig. 15: k-way balanced partitioning on ISPD-like netlists,
k in {2,4}, eps=0.03 — cut-net + time, plus the paper's "no measurable
overhead from constraints handling" claim (Delta-checks on vs off)."""
from __future__ import annotations

from benchmarks.common import row, timed
from repro.core import generate
from repro.core.kway import partition_kway


def run() -> list[str]:
    out = []
    suite = {
        "ibm-like-s": generate.ispd_like(n_nodes=1024, seed=11),
        "ibm-like-m": generate.ispd_like(n_nodes=1536, seed=12),
    }
    for name, hg in suite.items():
        for k in (2, 4):
            res, t = timed(partition_kway, hg, k=k, eps=0.03, theta=8,
                           coarse_target=64)
            res, t = timed(partition_kway, hg, k=k, eps=0.03, theta=8,
                           coarse_target=64)  # warm jit
            out.append(row(
                f"fig15/{name}/k{k}", t * 1e6,
                f"cut={res.cut_net:.0f} conn={res.connectivity:.0f} "
                f"eps={res.audit['balance_eps']:.3f} "
                f"valid={res.audit['size_ok']}"))
        # constraints-logic overhead: identical run with Delta checks active
        # (constrained events path) vs the same Omega-only problem
        r1, t1 = timed(partition_kway, hg, k=2, eps=0.03, theta=8,
                       coarse_target=64, check_delta=True)
        r2, t2 = timed(partition_kway, hg, k=2, eps=0.03, theta=8,
                       coarse_target=64, check_delta=False)
        out.append(row(f"fig15/{name}/delta_overhead", (t1 - t2) * 1e6,
                       f"t_with={t1:.2f}s t_without={t2:.2f}s "
                       f"ratio={t1/max(t2,1e-9):.3f}"))
    return out
