"""Aggregate results/dryrun/*.json into the roofline table (EXPERIMENTS.md
section Roofline) and CSV rows for benchmarks.run."""
from __future__ import annotations

import glob
import json
import os

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DRY = os.path.join(ROOT, "results", "dryrun")


def load_cells() -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(DRY, "*.json"))):
        try:
            with open(path) as f:
                cells.extend(json.load(f))
        except Exception:
            continue
    return cells


def markdown_table(cells: list[dict], mesh: str = "single") -> str:
    hdr = ("| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
           "bottleneck | useful-FLOP ratio | roofline fraction |\n"
           "|---|---|---|---|---|---|---|---|\n")
    rows = []
    for c in cells:
        if c.get("mesh") != mesh:
            continue
        if c.get("status") == "skipped":
            rows.append(f"| {c['arch']} | {c['shape']} | — | — | — | "
                        f"skipped | — | — |")
            continue
        if c.get("status") != "ok":
            continue
        dom = max(c["compute_s"], c["memory_s"], c["collective_s"])
        frac = c["compute_s"] / dom if dom else 0.0
        rows.append(
            f"| {c['arch']} | {c['shape']} | {c['compute_s']*1e3:.1f} | "
            f"{c['memory_s']*1e3:.1f} | {c['collective_s']*1e3:.1f} | "
            f"{c['dominant']} | {c['useful_flop_ratio']:.2f} | "
            f"{frac:.2f} |")
    return hdr + "\n".join(rows) + "\n"


def run() -> list[str]:
    cells = load_cells()
    out = []
    ok = [c for c in cells if c.get("status") == "ok"]
    skipped = [c for c in cells if c.get("status") == "skipped"]
    for c in ok:
        dom = max(c["compute_s"], c["memory_s"], c["collective_s"])
        out.append(
            f"roofline/{c['arch']}/{c['shape']}/{c['mesh']},"
            f"{dom*1e6:.1f},"
            f"bound={c['dominant']} compute_ms={c['compute_s']*1e3:.1f} "
            f"mem_ms={c['memory_s']*1e3:.1f} "
            f"coll_ms={c['collective_s']*1e3:.1f} "
            f"useful={c['useful_flop_ratio']:.2f}")
    out.append(f"roofline/summary,0.0,ok={len(ok)} skipped={len(skipped)}")
    return out


if __name__ == "__main__":
    cells = load_cells()
    print(markdown_table(cells))
