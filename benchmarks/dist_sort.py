"""Distributed sample sort vs gathered `lax.sort` wall-time per device
count (the communication pattern the PR 4 refactor replaced, measured in
isolation from the V-cycle).

Each device count runs in a fresh subprocess (XLA device topology fixes at
backend init) on a (1, n)-mesh. Both sides sort the same three-int-key +
payload columns under `shard_map`: the distributed side through
`ShardCtx.sort_by` (stripes in / stripes out — splitter samples are the
only gathered keys), the baseline through the legacy gather -> replicated
`lax.sort` -> stripe pattern. Second run timed (first pays compile). On
this CPU container the "devices" are host threads, so the columns chart
overhead/scaling shape; on a real mesh the same harness measures actual
traffic savings. `fell_back` counts capacity-overflow fallbacks (0 on this
workload).

  PYTHONPATH=src python -m benchmarks.dist_sort
  PYTHONPATH=src python -m benchmarks.run --only dist_sort
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

DEVICE_COUNTS = (1, 2, 4, 8)
N_PER_SHARD = 1 << 15

_CHILD = textwrap.dedent("""
    import os, sys, json, time
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + sys.argv[1])
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.models import common
    from repro.utils import segops

    n_dev = int(sys.argv[1])
    n = int(sys.argv[2]) * n_dev
    mesh = jax.make_mesh((n_dev,), ("model",))
    ctx = segops.ShardCtx(axis="model", nshards=n_dev)
    rng = np.random.default_rng(0)
    cols = [jnp.asarray(rng.integers(0, hi, n).astype(np.int32))
            for hi in (1 << 20, 1 << 10, 1 << 4)]
    pay = jnp.arange(n, dtype=jnp.int32)

    def dist_body(a, b, c, p):
        ks = [ctx.stripe(x) for x in (a, b, c)]
        from repro.dist import sort as dist_sort
        ko, po, fb = dist_sort.sample_sort_stripes(
            ctx, ks, [ctx.stripe(p)], with_stats=True)
        return (*ko, *po, fb)

    def gath_body(a, b, c, p):
        ks = [ctx.gather(ctx.stripe(x)) for x in (a, b, c)]
        (s1, s2, s3), (sp,) = segops.sort_by(ks, [ctx.gather(ctx.stripe(p))])
        return (ctx.stripe(s1), ctx.stripe(s2), ctx.stripe(s3),
                ctx.stripe(sp), jnp.asarray(False))

    out = {}
    for name, body in (("dist", dist_body), ("gather", gath_body)):
        f = jax.jit(common.shard_map(
            body, mesh=mesh, in_specs=(P(),) * 4,
            out_specs=(P("model"),) * 4 + (P(),)))
        r = f(*cols, pay)
        jax.block_until_ready(r)
        t0 = time.perf_counter()
        r = f(*cols, pay)
        jax.block_until_ready(r)
        out[name + "_s"] = time.perf_counter() - t0
        out[name + "_fell_back"] = bool(np.asarray(r[-1]).reshape(-1)[0])
    out["n"] = n
    print(json.dumps(out))
""")


def run() -> list[str]:
    from benchmarks.common import row

    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env = dict(os.environ, PYTHONPATH=src)
    env.pop("XLA_FLAGS", None)
    out = []
    for n in DEVICE_COUNTS:
        try:
            r = subprocess.run(
                [sys.executable, "-c", _CHILD, str(n), str(N_PER_SHARD)],
                env=env, capture_output=True, text=True, timeout=1800)
        except subprocess.TimeoutExpired:
            out.append(row(f"dist_sort/dev{n}", 0.0, "ERROR: timeout"))
            continue
        if r.returncode != 0:
            err = (r.stderr.strip().splitlines() or ["no stderr"])[-1]
            out.append(row(f"dist_sort/dev{n}", 0.0, f"ERROR: {err[:120]}"))
            continue
        m = json.loads(r.stdout.strip().splitlines()[-1])
        out.append(row(
            f"dist_sort/dev{n}", m["dist_s"] * 1e6,
            f"dist_s={m['dist_s']:.4f} gather_s={m['gather_s']:.4f} "
            f"rel_gather={m['dist_s'] / max(m['gather_s'], 1e-9):.2f}x "
            f"n={m['n']} fell_back={int(m['dist_fell_back'])}"))
    return out


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for line in run():
        print(line, flush=True)
