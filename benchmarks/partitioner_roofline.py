"""Roofline terms for the partitioner's own level-step programs (the
paper's Fig. 11 analogue, derived from compiled HLO instead of measured
counters): lower + compile coarsen_step / refine_step, walk the HLO with
trip correction, report compute vs memory terms against v5e-class peaks.

Two lanes ride along with the HLO rows:
  * kernel-path coverage — runs a small V-cycle with ``use_kernels=True``
    and reports, per phase, how many levels actually dispatched to the
    Pallas kernels (``PartitionResult.kernel_path``). A roofline for
    kernels that never fire is fiction; this row keeps the dispatch
    honest.
  * GPU-mesh lane — on an accelerator backend, times the same V-cycle
    under a ``Plan`` over all local devices with the kernels *compiled*
    (``pallas_interpret()`` is False there). On host backends the row is
    emitted as ``skipped`` so CSV consumers see a stable schema.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, timed
from repro.core import generate
from repro.core import hypergraph as H
from repro.core import refine as R
from repro.core.coarsen import CoarsenParams, coarsen_step
from repro.launch import hlo_cost
from repro.launch.dryrun import HBM_BW, PEAK_FLOPS

ACCEL_BACKENDS = ("tpu", "gpu", "cuda", "rocm")


def _coverage(kernel_path: dict) -> str:
    c, r = kernel_path["coarsen"], kernel_path["refine"]
    return (f"coarsen_kernel_levels={sum(1 for v in c if v)}/{len(c)} "
            f"refine_kernel_levels={sum(1 for v in r if v)}/{len(r)}")


def kernel_coverage_rows(hg, omega: int, delta: int) -> list[str]:
    """Per-level kernel-path coverage for a kernels-on V-cycle."""
    from repro.core.partitioner import partition

    res, dt = timed(partition, hg, omega=omega, delta=delta, theta=2,
                    use_kernels=True)
    return [row("partitioner_roofline/kernel_coverage", dt * 1e6,
                _coverage(res.kernel_path))]


def gpu_mesh_rows(hg, omega: int, delta: int) -> list[str]:
    """Kernels-on V-cycle on a device mesh, compiled Pallas — accelerator
    backends only (the CPU backend has no compiled Pallas path)."""
    backend = jax.default_backend()
    if backend not in ACCEL_BACKENDS:
        return [row("partitioner_roofline/gpu_mesh", 0.0,
                    f"skipped backend={backend}")]
    from repro.core.partitioner import partition
    from repro.dist.sharding import Plan

    n_dev = len(jax.devices())
    plan = Plan.make(jax.make_mesh((1, n_dev), ("data", "model")))
    kw = dict(omega=omega, delta=delta, theta=2, use_kernels=True,
              plan=plan, race=False)
    timed(partition, hg, **kw)  # warm the compile caches
    res, dt = timed(partition, hg, **kw)
    return [row("partitioner_roofline/gpu_mesh", dt * 1e6,
                f"backend={backend} devices={n_dev} "
                + _coverage(res.kernel_path))]


def _terms(lowered_compiled) -> dict:
    w = hlo_cost.analyze(lowered_compiled.as_text())
    return dict(compute_s=w["flops"] / PEAK_FLOPS,
                memory_s=w["bytes"] / HBM_BW,
                flops=w["flops"], bytes=w["bytes"])


def run() -> list[str]:
    out = []
    hg = generate.snn_smallworld(n_nodes=768, fanout=12, seed=5)
    caps = H.Caps.for_host(hg)
    d = H.device_from_host(hg, caps)
    cp = CoarsenParams(omega=48, delta=192)

    comp = jax.jit(coarsen_step, static_argnames=("caps", "params")).lower(
        d, caps, cp).compile()
    t = _terms(comp)
    dom = "memory" if t["memory_s"] > t["compute_s"] else "compute"
    out.append(row("partitioner_roofline/coarsen_step",
                   max(t["compute_s"], t["memory_s"]) * 1e6,
                   f"compute_ms={t['compute_s']*1e3:.3f} "
                   f"mem_ms={t['memory_s']*1e3:.3f} bound={dom}"))

    kcap = 32
    parts = jnp.arange(caps.n, dtype=jnp.int32) % 24
    rp = R.RefineParams(omega=48, delta=192, theta=1)
    comp2 = jax.jit(R.refine_step,
                    static_argnames=("caps", "kcap", "params",
                                     "enforce_size")).lower(
        d, parts, jnp.int32(24), caps, kcap, rp, True).compile()
    t2 = _terms(comp2)
    dom2 = "memory" if t2["memory_s"] > t2["compute_s"] else "compute"
    out.append(row("partitioner_roofline/refine_step",
                   max(t2["compute_s"], t2["memory_s"]) * 1e6,
                   f"compute_ms={t2['compute_s']*1e3:.3f} "
                   f"mem_ms={t2['memory_s']*1e3:.3f} bound={dom2}"))

    hg_small = generate.snn_smallworld(n_nodes=192, fanout=8, seed=5)
    out += kernel_coverage_rows(hg_small, omega=24, delta=96)
    out += gpu_mesh_rows(hg_small, omega=24, delta=96)
    return out
