"""Roofline terms for the partitioner's own level-step programs (the
paper's Fig. 11 analogue, derived from compiled HLO instead of measured
counters): lower + compile coarsen_step / refine_step, walk the HLO with
trip correction, report compute vs memory terms against v5e-class peaks."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row
from repro.core import generate
from repro.core import hypergraph as H
from repro.core import refine as R
from repro.core.coarsen import CoarsenParams, coarsen_step
from repro.launch import hlo_cost
from repro.launch.dryrun import HBM_BW, PEAK_FLOPS


def _terms(lowered_compiled) -> dict:
    w = hlo_cost.analyze(lowered_compiled.as_text())
    return dict(compute_s=w["flops"] / PEAK_FLOPS,
                memory_s=w["bytes"] / HBM_BW,
                flops=w["flops"], bytes=w["bytes"])


def run() -> list[str]:
    out = []
    hg = generate.snn_smallworld(n_nodes=768, fanout=12, seed=5)
    caps = H.Caps.for_host(hg)
    d = H.device_from_host(hg, caps)
    cp = CoarsenParams(omega=48, delta=192)

    comp = jax.jit(coarsen_step, static_argnames=("caps", "params")).lower(
        d, caps, cp).compile()
    t = _terms(comp)
    dom = "memory" if t["memory_s"] > t["compute_s"] else "compute"
    out.append(row("partitioner_roofline/coarsen_step",
                   max(t["compute_s"], t["memory_s"]) * 1e6,
                   f"compute_ms={t['compute_s']*1e3:.3f} "
                   f"mem_ms={t['memory_s']*1e3:.3f} bound={dom}"))

    kcap = 32
    parts = jnp.arange(caps.n, dtype=jnp.int32) % 24
    rp = R.RefineParams(omega=48, delta=192, theta=1)
    comp2 = jax.jit(R.refine_step,
                    static_argnames=("caps", "kcap", "params",
                                     "enforce_size")).lower(
        d, parts, jnp.int32(24), caps, kcap, rp, True).compile()
    t2 = _terms(comp2)
    dom2 = "memory" if t2["memory_s"] > t2["compute_s"] else "compute"
    out.append(row("partitioner_roofline/refine_step",
                   max(t2["compute_s"], t2["memory_s"]) * 1e6,
                   f"compute_ms={t2['compute_s']*1e3:.3f} "
                   f"mem_ms={t2['memory_s']*1e3:.3f} bound={dom2}"))
    return out
