"""Serving throughput: static vs continuous batching, uniform vs ragged.

Decodes a backlog of requests through `ServeEngine` under both batch
policies. `eos_id` is set past the vocab so every request runs exactly its
own `max_new` steps — lengths are deterministic, and the *useful* token
count (sum of per-request max_new) is identical across policies. Static
batching decodes each chunk of `n_slots` requests for the chunk's longest
max_new (finished rows burn idle lanes); continuous batching refills freed
slots from the backlog, so ragged lengths stop costing straggler time.

  PYTHONPATH=src python -m benchmarks.run --only serve_engine
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import row

N_REQ = 12
N_SLOTS = 4
PROMPT_LEN = 16
CACHE_LEN = 96
RAGGED = [4, 8, 16, 24, 40, 64]  # cycled over requests
UNIFORM = [24]


def _requests(cfg, lengths):
    rng = np.random.default_rng(0)
    return [(rng.integers(2, cfg.vocab, size=(PROMPT_LEN,), dtype=np.int32),
             lengths[i % len(lengths)]) for i in range(N_REQ)]


def _run_static(eng, reqs):
    """Chunked static batches: each chunk decodes max(chunk max_new)."""
    done = 0
    for i in range(0, len(reqs), eng.n_slots):
        chunk = reqs[i:i + eng.n_slots]
        prompts = np.stack([p for p, _ in chunk])
        out = eng.generate(prompts, max_new=max(m for _, m in chunk))
        done += out.shape[0]
    return done


def _run_continuous(eng, reqs):
    rids = [eng.submit(p, m) for p, m in reqs]
    res = eng.drain()
    return len([res[r] for r in rids])


def _bench(policy, lengths, cfg, params):
    from repro.serve import ServeEngine
    eng = ServeEngine(cfg, params, cache_len=CACHE_LEN, n_slots=N_SLOTS,
                      policy=policy, eos_id=cfg.vocab)  # unreachable EOS
    reqs = _requests(cfg, lengths)
    runner = _run_static if policy == "static" else _run_continuous
    runner(eng, reqs[:N_SLOTS])  # warmup: compile prefill/decode/insert
    t0 = time.perf_counter()
    runner(eng, reqs)
    jax.effects_barrier()
    dt = time.perf_counter() - t0
    useful = sum(m for _, m in reqs)
    return dt, useful


def run():
    from repro.configs import get_config
    from repro.models import common
    from repro.models import transformer as T

    cfg = get_config("qwen2-1.5b").smoke()
    params = common.materialize(T.lm_shapes(cfg), jax.random.PRNGKey(0))
    for kind, lengths in (("uniform", UNIFORM), ("ragged", RAGGED)):
        for policy in ("static", "continuous"):
            dt, useful = _bench(policy, lengths, cfg, params)
            yield row(f"serve_engine/{policy}_{kind}", dt * 1e6,
                      f"tok_s={useful / dt:.1f} useful={useful}")
