"""Shared benchmark helpers."""
from __future__ import annotations

import time

import numpy as np


def timed(fn, *args, repeat: int = 1, **kw):
    """Wall-time fn, draining the async dispatch queue each iteration —
    without the block, jitted callees return futures and the loop times
    dispatch latency instead of execution."""
    import jax

    outs = None
    t0 = time.perf_counter()
    for _ in range(repeat):
        outs = jax.block_until_ready(fn(*args, **kw))
    dt = (time.perf_counter() - t0) / repeat
    return outs, dt


def row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"


def small_snn_suite():
    from repro.core import generate
    return {
        "model-s": generate.snn_layered(n_layers=4, width=96, fanout=8,
                                        window=16, seed=1),
        "model-m": generate.snn_layered(n_layers=5, width=144, fanout=10,
                                        window=20, seed=2),
        "rand-s": generate.snn_smallworld(n_nodes=384, fanout=10, seed=4),
        "rand-m": generate.snn_smallworld(n_nodes=768, fanout=12, seed=5),
    }


def snn_constraints(name: str):
    return (32, 128) if name.endswith("-s") else (48, 192)
