"""Streaming repartitioning: cold V-cycle vs warm refine-only re-solve.

One medium SNN graph takes a stream of small `GraphDelta` batches (edge
churn, `generate.perturb_delta`). Lanes:

* cold — steady-state `partition()` wall time on the graph (compile
  excluded by a warmup solve): the price of ignoring the previous solution;
* warm — `repartition()` per delta window with a persistent `WarmCache`
  (device storage + caps reused, jit cache stays hot). Each window is
  asserted to take the refine-only path: ``mode == "warm"``,
  ``n_levels == 0``, NO ``coarsen_level`` span in the trace tree, and the
  same Omega/Delta + distinct-incident-hyperedge audit as the cold solve —
  the acceptance contract of the streaming-repartitioning PR. The derived
  column reports the warm:cold speedup (steady-state windows, best-of);
* drift ramp — growing delta batches against the default drift threshold,
  reporting which mode (`warm` / `fallback-drift`) each drift level takes;
  the ramp must end in the fallback branch.

Smoke mode (REPRO_BENCH_SMOKE=1) shrinks the graph and window count.

  PYTHONPATH=src python -m benchmarks.run --only repartition [--smoke]
"""
from __future__ import annotations

import os
import time

from benchmarks.common import row

OMEGA, DELTA = 16, 64
THETA = 4
N_WINDOWS = 4
DELTA_EDGES = 4


def _smoke() -> bool:
    return bool(os.environ.get("REPRO_BENCH_SMOKE"))


def _mkgraph():
    from repro.core import generate
    width = 16 if _smoke() else 40
    return generate.snn_layered(n_layers=4 if _smoke() else 5, width=width,
                                fanout=6, seed=3)


def run():
    from repro.core import generate
    from repro.core.partitioner import WarmCache, partition, repartition
    from repro.obs import trace as otrace

    windows = 2 if _smoke() else N_WINDOWS
    hg = _mkgraph()

    # ---- cold lane: steady-state full V-cycle (compile excluded) ---------
    partition(hg, omega=OMEGA, delta=DELTA, theta=THETA)  # warmup/compile
    t0 = time.perf_counter()
    cold = partition(hg, omega=OMEGA, delta=DELTA, theta=THETA)
    t_cold = time.perf_counter() - t0
    assert cold.audit["size_ok"] and cold.audit["inbound_ok"]
    yield row("repartition/cold_vcycle", t_cold * 1e6,
              f"levels={cold.n_levels}")

    # ---- warm lane: delta windows through the persistent cache -----------
    cache = WarmCache()
    warm0 = repartition(hg, cold.parts, OMEGA, DELTA, theta=THETA,
                        cache=cache)  # zero-delta warmup: compiles refine
    assert warm0.mode == "warm"
    parts = warm0.parts
    times = []
    for w in range(windows):
        dl = generate.perturb_delta(hg, n_edges=DELTA_EDGES, seed=100 + w)
        otrace.reset()
        t0 = time.perf_counter()
        res = repartition(hg, parts, OMEGA, DELTA, theta=THETA, deltas=dl,
                          drift_threshold=0.9, cache=cache)
        dt = time.perf_counter() - t0
        # the acceptance contract: refine-only, no coarsening, same audit
        assert res.mode == "warm", res.mode
        assert res.n_levels == 0
        root = otrace.last_root()
        assert root is not None and not root.find("coarsen_level")
        assert res.audit["size_ok"] and res.audit["inbound_ok"]
        parts = res.parts
        times.append(dt)
    t_warm = min(times)  # best steady-state window (no cache rebuild)
    assert t_warm < t_cold, (
        f"warm repartition ({t_warm:.3f}s) must beat the cold V-cycle "
        f"({t_cold:.3f}s)")
    yield row("repartition/warm_refine_only", t_warm * 1e6,
              f"speedup={t_cold / t_warm:.2f}x windows={windows}")

    # ---- drift ramp: growing churn against the default threshold ---------
    hg2 = _mkgraph()
    base = partition(hg2, omega=OMEGA, delta=DELTA, theta=THETA)
    parts2 = base.parts
    ramp = [2, 8] if _smoke() else [2, 8, 24, 48]
    modes = []
    for i, n_edges in enumerate(ramp):
        n_edges = min(n_edges, hg2.n_edges - 1)
        dl = generate.perturb_delta(hg2, n_edges=n_edges, seed=200 + i)
        t0 = time.perf_counter()
        res = repartition(hg2, parts2, OMEGA, DELTA, theta=THETA,
                          deltas=dl)  # default drift_threshold
        dt = time.perf_counter() - t0
        modes.append(res.mode)
        parts2 = res.parts
        yield row(f"repartition/drift_ramp_{n_edges}edges", dt * 1e6,
                  f"mode={res.mode} drift_after={hg2.drift:.3f}")
    assert modes[-1].startswith("fallback"), (
        f"the ramp must end in the cold fallback, got {modes}")
