"""Paper Fig. 16 + parameter studies: ablations of the three algorithmic
contributions (neighborhood materialization, exact matching, move chaining)
and the Pi / Theta parameter sweeps."""
from __future__ import annotations

import jax

from benchmarks.common import row, timed
from repro.core import generate
from repro.core import hypergraph as H
from repro.core.coarsen import CoarsenParams, propose
from repro.core.partitioner import partition


def run() -> list[str]:
    out = []
    hg = generate.snn_smallworld(n_nodes=320, fanout=8, seed=6)
    om, dl = 32, 128

    # warm + baseline (exact matching, chaining on, Pi=4, Theta=8)
    base, _ = timed(partition, hg, omega=om, delta=dl, theta=8)
    base, t_base = timed(partition, hg, omega=om, delta=dl, theta=8)
    out.append(row("fig16/baseline", t_base * 1e6,
                   f"conn={base.connectivity:.0f} parts={base.n_parts}"))

    # --- exact vs greedy matching (ablation 2) -----------------------------
    g, _ = timed(partition, hg, omega=om, delta=dl, theta=8,
                 matching="greedy")
    g, t_g = timed(partition, hg, omega=om, delta=dl, theta=8,
                   matching="greedy")
    out.append(row("fig16/greedy_matching", t_g * 1e6,
                   f"conn={g.connectivity:.0f} "
                   f"conn_ratio={g.connectivity/max(base.connectivity,1e-9):.3f} "
                   f"levels={g.n_levels} vs {base.n_levels}"))

    # --- chaining off (ablation 3: sequence by gain only) ------------------
    c, _ = timed(partition, hg, omega=om, delta=dl, theta=8, chain_rounds=0)
    c, t_c = timed(partition, hg, omega=om, delta=dl, theta=8,
                   chain_rounds=0)
    out.append(row("fig16/no_chaining", t_c * 1e6,
                   f"conn={c.connectivity:.0f} "
                   f"conn_ratio={c.connectivity/max(base.connectivity,1e-9):.3f}"))

    # --- neighborhood materialization amortization (ablation 1) ------------
    caps = H.Caps.for_host(hg)
    d = H.device_from_host(hg, caps)
    params = CoarsenParams(omega=om, delta=dl)
    blk = jax.block_until_ready
    pairs_fn = jax.jit(lambda dd: H.build_pairs(dd, caps))
    nbrs_fn = jax.jit(lambda pp, dd: H.build_neighbors(pp, dd, caps))
    prop_fn = jax.jit(lambda dd, nn, pp: propose(dd, nn, pp, caps, params))
    pairs = blk(pairs_fn(d))
    nbrs = blk(nbrs_fn(pairs, d))
    blk(prop_fn(d, nbrs, pairs))
    _, t_once = timed(lambda: blk(prop_fn(d, nbrs, pairs)))
    _, t_dedup = timed(lambda: blk(nbrs_fn(pairs, d)))

    def unmaterialized():  # re-deduplicate per proposal round (Pi rounds)
        for _ in range(params.n_cands):
            nn = nbrs_fn(pairs, d)
            prop_fn(d, nn, pairs)
        return blk(nn)

    _, t_unmat = timed(unmaterialized)
    t_mat = t_dedup + t_once
    out.append(row("fig16/materialization", (t_unmat - t_mat) * 1e6,
                   f"materialized={t_mat:.3f}s rebuilt_per_round={t_unmat:.3f}s "
                   f"slowdown={t_unmat/max(t_mat,1e-9):.2f}x"))

    # --- Pi sweep -----------------------------------------------------------
    for pi in (1, 4, 16):
        r, _ = timed(partition, hg, omega=om, delta=dl, theta=4, n_cands=pi)
        r, t = timed(partition, hg, omega=om, delta=dl, theta=4, n_cands=pi)
        out.append(row(f"fig16/pi_{pi}", t * 1e6,
                       f"conn={r.connectivity:.0f} levels={r.n_levels} "
                       f"parts={r.n_parts}"))

    # --- Theta sweep ---------------------------------------------------------
    for th in (4, 16):
        r, _ = timed(partition, hg, omega=om, delta=dl, theta=th)
        r, t = timed(partition, hg, omega=om, delta=dl, theta=th)
        out.append(row(f"fig16/theta_{th}", t * 1e6,
                       f"conn={r.connectivity:.0f}"))
    return out
